"""cProfile microbenchmark (ISSUE 7 satellite): on a 512-node fabric the
compiled traffic plan must beat the interpreted per-event loop by >= 10x —
the margin that makes the 4096-node multi-day fleet trace
(`benchmarks/fleet_scale.py`) a seconds-scale run instead of an hours-scale
one. Marked slow: the interpreted side deliberately pays the full global
peek/min event loop."""
import cProfile
import pstats

import pytest

from repro.core.lccl import PodFabric
from repro.core.plan import compile_traffic_plan, steady_state_pattern
from repro.train.step import hierarchical_step_traffic

N_PODS, POD_SIZE = 8, 64               # 512 nodes, 512 ICI + 8 DCN edges
PERIOD = 10.0
N_STEPS = 3


def _fabric():
    return PodFabric(N_PODS, POD_SIZE, ici_bw=50e9, dcn_bw=5e9,
                     dcn_latency=1e-3, quantum=float(64 << 20))


def _profile_traffic():
    return hierarchical_step_traffic(2e11, N_PODS, POD_SIZE,
                                     state_bytes=float(128 << 20))


def _profiled(fn) -> float:
    prof = cProfile.Profile()
    prof.enable()
    fn()
    prof.disable()
    return pstats.Stats(prof).total_tt


@pytest.mark.slow
def test_compiled_plan_beats_event_loop_10x_on_512_nodes():
    profile = _profile_traffic()

    interp = _fabric()                 # exact global event loop
    pattern = steady_state_pattern(interp, profile)

    def run_interpreted():
        for s in range(N_STEPS):
            for e, subs in pattern.items():
                for kind, size, off in subs:
                    interp.links[e].submit(kind, size, s * PERIOD + off)
            interp.run(until=(s + 1) * PERIOD)

    compiled = _fabric()

    def run_compiled():
        plan = compile_traffic_plan(compiled, pattern, PERIOD)
        plan.apply(N_STEPS)

    t_interp = _profiled(run_interpreted)
    t_compiled = _profiled(run_compiled)
    # the replay really advanced the same simulation
    for e in pattern:
        assert compiled.links[e].now == interp.links[e].now
        assert compiled.links[e].n_finished == interp.links[e].n_finished
    speedup = t_interp / max(t_compiled, 1e-9)
    assert speedup >= 10.0, (
        f"compiled plan only {speedup:.1f}x faster than the event loop "
        f"({t_interp:.3f}s vs {t_compiled:.3f}s)")
