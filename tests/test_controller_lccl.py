"""Controller / LCCL control-plane coverage: role tables, ring peers, data
fan-out, heartbeat detection, HLO collective parsing, probe features."""
import numpy as np
import pytest

from repro.core.controller import StateController
from repro.core.lccl import LockFreeAddressArray, Role, RoleTable
from repro.roofline.analyze import parse_collectives


def test_role_table_ring_peers():
    t = RoleTable(dp=4, pp=2, tp=2)
    peers = t.ring_peers(Role(0, 0, 1))
    assert peers["dp_next"] == Role(1, 0, 1)
    assert peers["dp_prev"] == Role(3, 0, 1)
    assert peers["pp_next"] == Role(0, 1, 1)
    # <=4 inter-node connections per worker (paper §5.1 group-free claim)
    assert len(peers) == 4


def test_role_rebind_preserves_role_identity():
    t = RoleTable(dp=2, pp=1, tp=1)
    old_rank = t.role_to_rank[(1, 0, 0)]
    role = t.rebind(old_rank, 999)
    assert role == Role(1, 0, 0)
    assert t.role_to_rank[(1, 0, 0)] == 999
    assert t.rank_to_role[999] == role
    assert old_rank not in t.rank_to_role


def test_controller_fanout_targets_tp_rank0_only():
    c = StateController(dp=4, pp=2, tp=4, global_batch=16)
    targets = c.fanout_targets()
    # one per (dp, pp) group => dp*pp, not dp*pp*tp (paper §4.3)
    assert len(targets) == 8
    for r in targets:
        assert c.roles.rank_to_role[r].tp == 0


def test_controller_assignment_exact_cover_and_elastic():
    c = StateController(dp=4, pp=1, tp=1, global_batch=16)
    a = c.assignment(3, dataset_size=1024)
    spans = sorted(a.ranges.values())
    assert spans[0][0] == (3 * 16) % 1024
    total = sum(hi - lo for lo, hi in spans)
    assert total == 16
    c.shrink_dp([3])
    a2 = c.assignment(4, dataset_size=1024)
    assert len(a2.ranges) == 3
    assert sum(hi - lo for lo, hi in a2.ranges.values()) == 15  # 16//3*3


def test_controller_detects_silent_worker():
    c = StateController(dp=8, pp=1, tp=1, global_batch=8)
    for w in range(8):
        c.beat(w, now=10.0)
    for w in range(8):
        if w != 5:
            c.beat(w, now=11.5)
    assert c.detect_failures(now=11.5) == [5]
    assert c.detect_failures(now=10.5) == []


def test_controller_ckpt_version_resolution():
    c = StateController(dp=4, pp=1, tp=1, global_batch=8)
    for g, it in enumerate([100, 101, 100, 101]):
        c.report_ckpt(g, it)
    assert c.resolve_recovery_iteration() == 100


def test_lockfree_address_array():
    arr = LockFreeAddressArray(8)
    for r in range(8):
        arr.publish(r, 5000 + r)
    assert arr.connect_all(0, [1, 7]) == [5001, 5007]
    assert arr.try_read(3) == 5003


# ---------------- HLO collective parser ---------------- #
HLO_SAMPLE = """
  %ar = f32[64,128]{1,0} all-reduce(%x), replica_groups=[4,2]<=[8], to_apply=%add
  %ag = f32[64,256]{1,0} all-gather(%y), replica_groups=[2,4]<=[8], dimensions={1}
  %rs = f32[16,128]{1,0} reduce-scatter(%z), replica_groups=[2,4]<=[8], to_apply=%add
  %cp = bf16[32,32]{1,0} collective-permute(%w), source_target_pairs={{0,1},{1,0}}
  %start = (f32[8,8]{1,0}, f32[8,8]{1,0}) all-gather-start(%v), replica_groups=[4,2]<=[8]
  %done = f32[8,8]{1,0} all-gather-done(%start)
"""


def test_parse_collectives_semantics():
    out = parse_collectives(HLO_SAMPLE)
    by = out["bytes_by_kind"]
    # all-reduce operand = result = 64*128*4
    assert by["all-reduce"] == 64 * 128 * 4
    # all-gather operand = result / group_size(4)
    assert by["all-gather"] == (64 * 256 * 4) // 4 + (8 * 8 * 4) // 2
    # reduce-scatter operand = result * group_size(4)
    assert by["reduce-scatter"] == 16 * 128 * 4 * 4
    assert by["collective-permute"] == 32 * 32 * 2
    # -done line must not double count
    assert out["count_by_kind"]["all-gather"] == 2
    assert out["wire_bytes"] > 0


# ---------------- probe feature planning ---------------- #
def test_probe_plan_families():
    from repro.configs import get_arch
    from repro.roofline.probes import probe_plan
    cfgs, feats, target = probe_plan(get_arch("deepseek-67b"))
    assert [c.num_layers for c in cfgs] == [2, 4]
    assert target.tolist() == [1.0, 95.0]
    cfgs, feats, target = probe_plan(get_arch("zamba2-7b"))
    assert [c.num_layers for c in cfgs] == [6, 7, 12]
    assert target.tolist() == [1.0, 81.0, 13.0]  # 13 shared-attn applications
    cfgs, feats, target = probe_plan(get_arch("whisper-small"))
    assert all(c.encoder_layers == c.num_layers for c in cfgs)


def test_probe_extrapolation_is_exact_for_affine():
    """lstsq over (1, L) probes recovers an affine cost exactly."""
    feats = np.array([[1.0, 2.0], [1.0, 4.0]])
    y = np.array([10.0 + 3.0 * 2, 10.0 + 3.0 * 4])
    theta, *_ = np.linalg.lstsq(feats, y, rcond=None)
    assert np.isclose(np.array([1.0, 95.0]) @ theta, 10.0 + 3.0 * 95)
