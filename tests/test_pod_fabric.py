"""Hierarchical pod fabric (ISSUE 3 tentpole): ICI-ring × DCN-hop shape,
per-edge latency in completion times, tier-aware stream placement (DCN wins
only once the ICI ring is saturated), bidirectional ring routing halving an
idle-ring recovery, seeded failure storms darkening whole pods, and the
per-tier FCR closed form."""
import numpy as np
import pytest

from repro.ckpt.stream import ChunkedStream, StreamAssembler, TopologyTransport
from repro.core.fcr import (fcr, fcr_hidden_per_tier, fcr_per_tier, is_free)
from repro.core.lccl import (TIER_DCN, TIER_ICI, LinkScheduler, LinkTopology,
                             PodFabric, edge_key, inject_storm,
                             submit_chunked_path)
from repro.train.step import hierarchical_step_traffic, submit_step_traffic


# --------------------------------------------------------------------------- #
# fabric shape + tiers
# --------------------------------------------------------------------------- #
def test_pod_fabric_shape_and_tiers():
    fab = PodFabric(3, 4, ici_bw=50e9, dcn_bw=5e9)
    assert fab.n == 12
    assert fab.pod_of(0) == 0 and fab.pod_of(5) == 1 and fab.pod_of(11) == 2
    assert fab.pod_nodes(1) == [4, 5, 6, 7]
    assert [fab.gateway(p) for p in range(3)] == [0, 4, 8]
    ici = fab.tier_edges(TIER_ICI)
    dcn = fab.tier_edges(TIER_DCN)
    assert len(ici) == 12              # 3 pods x 4-node ring
    assert sorted(dcn) == [(0, 4), (0, 8), (4, 8)]
    assert fab.tier(0, 4) == TIER_DCN and fab.tier(0, 1) == TIER_ICI
    assert all(fab.edge(*e).bw == 50e9 for e in ici)
    assert all(fab.edge(*e).bw == 5e9 for e in dcn)
    assert fab.tiers() == [TIER_DCN, TIER_ICI]


def test_pod_fabric_degenerate_sizes():
    # two pods of two nodes: one ICI edge each, a single DCN edge
    fab = PodFabric(2, 2, 1e9, 1e8)
    assert sorted(fab.edges()) == [(0, 1), (0, 2), (2, 3)]
    assert fab.tier(0, 2) == TIER_DCN
    # single pod: plain ICI ring, no DCN
    solo = PodFabric(1, 4, 1e9, 1e8)
    assert sorted(solo.tier_edges(TIER_ICI)) == [(0, 1), (0, 3), (1, 2),
                                                 (2, 3)]
    assert solo.tier_edges(TIER_DCN) == []
    # pods of one node: a pure DCN gateway ring
    gw = PodFabric(4, 1, 1e9, 1e8)
    assert sorted(gw.edges()) == [(0, 1), (0, 3), (1, 2), (2, 3)]
    assert all(fabt == TIER_DCN for fabt in gw.edge_tier.values())


def test_cross_pod_path_rides_gateways():
    fab = PodFabric(3, 4, 50e9, 5e9)
    # node 5 (pod 1) -> node 2 (pod 0): ICI to gateway 4, DCN 4->0, ICI 0->2
    path = fab.path(5, 2)
    assert (0, 4) in path
    tiers = [fab.tier(*e) for e in path]
    assert TIER_DCN in tiers and TIER_ICI in tiers


# --------------------------------------------------------------------------- #
# latency
# --------------------------------------------------------------------------- #
def test_latency_adds_to_single_chunk_completion():
    sched = LinkScheduler(1e6, quantum=1 << 20, latency=0.5)
    tr = sched.submit("STATE", 1e6, 0.0)
    sched.drain()
    assert tr.t_finish == pytest.approx(1.0 + 0.5, rel=1e-9)
    # TRAIN pays it too
    tr2 = sched.submit("TRAIN", 2e6, sched.now)
    sched.drain()
    assert tr2.t_finish - tr2.t_start == pytest.approx(2.0 + 0.5, rel=1e-9)


def test_latency_does_not_hold_the_link():
    """Latency delays DELIVERY, not the next transfer: two back-to-back
    chunks finish one transmission apart, each shifted by the latency."""
    sched = LinkScheduler(1e6, quantum=1 << 20, latency=0.5)
    a = sched.submit("STATE", 1e6, 0.0)
    b = sched.submit("STATE", 1e6, 0.0)
    sched.drain()
    assert a.t_finish == pytest.approx(1.5, rel=1e-9)
    assert b.t_finish == pytest.approx(2.5, rel=1e-9)


def test_latency_accrues_per_hop_on_fabric():
    fab = PodFabric(3, 2, 1e6, 1e6, dcn_latency=0.25, quantum=1e4)
    path = fab.path(1, 3)              # 1-0 (ici), 0-2 (dcn), 2-3 (ici)
    assert [fab.tier(*e) for e in path] == [TIER_ICI, TIER_DCN, TIER_ICI]
    pts = submit_chunked_path(fab, "STATE", 1e4, 0.0, path, quantum=1e4)
    fab.drain()
    # 3 hops of 0.01 s transmission + one 0.25 s DCN delivery latency
    assert pts[0].t_finish == pytest.approx(0.03 + 0.25, rel=1e-6)


# --------------------------------------------------------------------------- #
# tier-aware placement: DCN wins only when the ICI ring is saturated
# --------------------------------------------------------------------------- #
def test_dcn_beats_ici_only_when_ici_saturated():
    fab = PodFabric(2, 4, ici_bw=50e9, dcn_bw=5e9)
    # idle fabric: the fast tier wins placement
    assert fab.tier(*fab.least_loaded_edge()) == TIER_ICI
    # TRAIN backlog on every ICI edge: the slack DCN tier wins
    for e in fab.tier_edges(TIER_ICI):
        fab.edge(*e).submit("TRAIN", 10e9, 0.0)
    assert fab.tier(*fab.least_loaded_edge()) == TIER_DCN
    # ... but only while the backlog outweighs the bandwidth gap: a light
    # ICI load (drains faster than an idle DCN tie-break) keeps ICI
    fab2 = PodFabric(2, 4, ici_bw=50e9, dcn_bw=5e9)
    loaded = fab2.tier_edges(TIER_ICI)[0]
    fab2.edge(*loaded).submit("TRAIN", 10e9, 0.0)
    pick = fab2.least_loaded_edge()
    assert fab2.tier(*pick) == TIER_ICI and edge_key(*pick) != loaded


def test_full_artifact_spills_to_dcn_under_train_pressure():
    fab = PodFabric(2, 2, ici_bw=1e6, dcn_bw=1e6)
    tp = TopologyTransport(fab)
    for e in fab.tier_edges(TIER_ICI):
        tp.submit_train_edge(*e, 5e6, 0.0)
    arr = np.arange(256, dtype=np.float32)
    cs = ChunkedStream.from_array("full", arr, quantum=256)
    asm = StreamAssembler.for_stream(cs)
    tp.send(cs, 0.0, assembler=asm)    # no src/dst: least-loaded placement
    assert fab.edge(0, 2).pending_bytes("STATE") > 0   # the DCN edge
    tp.drain()
    assert asm.complete
    np.testing.assert_array_equal(asm.to_array(), arr)


# --------------------------------------------------------------------------- #
# bidirectional ring routing
# --------------------------------------------------------------------------- #
def test_split_bytes_even_on_idle_symmetric_ring():
    topo = LinkTopology(8, 1e6)
    paths = topo.disjoint_paths(0, 1)
    assert len(paths) == 2 and len(paths[0]) == 1 and len(paths[1]) == 7
    shares = topo.split_bytes(paths, 1e6)
    assert shares == pytest.approx([5e5, 5e5])


def test_split_bytes_weighs_rate_and_backlog():
    topo = LinkTopology(4, 1e6)
    topo.set_bandwidth(1, 2, 2e6)      # cw path 0-1-2 bottlenecked at 1e6
    paths = [topo.path(0, 2), [edge_key(0, 3), edge_key(2, 3)]]
    shares = topo.split_bytes(paths, 3e6)
    assert shares == pytest.approx([1.5e6, 1.5e6])   # equal bottlenecks
    # backlog on one direction shifts bytes to the other
    topo.edge(0, 3).submit("TRAIN", 1e6, 0.0)        # 1 s of backlog
    shares = topo.split_bytes(paths, 3e6)
    assert shares[0] - shares[1] == pytest.approx(1e6)
    assert sum(shares) == pytest.approx(3e6)


def test_bidirectional_split_halves_idle_ring_recovery():
    """Acceptance: on an idle symmetric ring the bidirectional policy moves
    a recovery in ~half the single-direction time, and strictly beats it."""
    nbytes, bw, q = 4 << 20, 1e6, 1 << 12

    def recover(policy):
        topo = LinkTopology(8, bw, quantum=q)
        tp = TopologyTransport(topo)
        arr = np.zeros(nbytes // 8, dtype=np.float64)
        cs = ChunkedStream.from_array("r", arr, quantum=q)
        asm = StreamAssembler.for_stream(cs)
        ticket = tp.send(cs, 0.0, assembler=asm, src=0, dst=1, policy=policy)
        tp.drain()
        assert asm.complete
        return ticket.finish_time

    t_uni = recover("shortest")
    t_bi = recover("split")
    assert t_uni == pytest.approx(nbytes / bw, rel=1e-3)
    assert t_bi < t_uni                                  # strictly better
    assert t_bi == pytest.approx(t_uni / 2, rel=0.05)    # ~halved


def test_bidirectional_schedule_state_phase_matches_transport():
    from repro.runtime.failover import schedule_state_phase
    bw, nbytes = 1e6, 4 << 20
    topo = LinkTopology(8, bw, quantum=1 << 12)
    t_bi = schedule_state_phase(nbytes, bw, quantum=1 << 12, topology=topo,
                                paths=topo.disjoint_paths(0, 1))
    assert t_bi == pytest.approx(nbytes / bw / 2, rel=0.05)


def test_split_falls_back_to_single_path_when_one_direction_dark():
    topo = LinkTopology(6, 1e6, quantum=1 << 12)
    topo.fail_edge(1, 2)               # cw direction severed
    tp = TopologyTransport(topo)
    arr = np.arange(1024, dtype=np.float32)
    cs = ChunkedStream.from_array("s", arr, quantum=1 << 12)
    asm = StreamAssembler.for_stream(cs)
    tp.send(cs, 0.0, assembler=asm, src=0, dst=2)
    tp.drain()
    assert asm.complete
    np.testing.assert_array_equal(asm.to_array(), arr)


# --------------------------------------------------------------------------- #
# failure storms
# --------------------------------------------------------------------------- #
def test_storm_darkens_whole_pod_and_recovery_routes_over_dcn():
    fab = PodFabric(4, 4, ici_bw=50e9, dcn_bw=5e9, dcn_latency=1e-3)
    rep = inject_storm(fab, seed=123, pods=1)
    assert len(rep.pods) == 1
    dark = rep.pods[0]
    assert fab.dark_pods() == [dark]
    assert set(rep.nodes) == set(fab.pod_nodes(dark))
    # a fetch between the two pods flanking the dark one must race the
    # other way around the gateway ring, over DCN
    src = fab.gateway((dark + 1) % 4)
    dst = fab.gateway((dark - 1) % 4)
    path = fab.path(src, dst)
    dark_nodes = set(fab.pod_nodes(dark))
    assert all(u not in dark_nodes and v not in dark_nodes
               for u, v in path)
    assert sum(1 for e in path if fab.tier(*e) == TIER_DCN) >= 2
    # and the transfer is bounded by DCN bandwidth + per-hop latency
    pts = submit_chunked_path(fab, "STATE", 50e6, 0.0, path)
    fab.drain()
    n_dcn = sum(1 for e in path if fab.tier(*e) == TIER_DCN)
    bound = 50e6 / 5e9 + n_dcn * 1e-3 + len(path) * (1 << 20) / 5e9
    assert max(pt.t_finish for pt in pts) <= bound * 1.01


def test_storm_is_reproducible_and_correlated():
    a = inject_storm(PodFabric(4, 4, 1e9, 1e8), seed=7, pods=1,
                     edge_failures=2)
    b = inject_storm(PodFabric(4, 4, 1e9, 1e8), seed=7, pods=1,
                     edge_failures=2)
    assert a == b                      # same seed, same blast
    c = inject_storm(PodFabric(4, 4, 1e9, 1e8), seed=8, pods=1,
                     edge_failures=2)
    assert (a.pods, a.edges) != (c.pods, c.edges) or a != c
    assert len(a.edges) == 2


def test_storm_on_flat_ring_fails_clustered_edges():
    topo = LinkTopology(8, 1e9)
    rep = inject_storm(topo, seed=3, pods=1, edge_failures=2)
    assert rep.pods == ()              # no pods on a flat ring
    assert len(rep.edges) == 2
    assert all(e in topo.dark_edges for e in rep.edges)


# --------------------------------------------------------------------------- #
# per-tier FCR
# --------------------------------------------------------------------------- #
def test_fcr_per_tier_matches_closed_form_on_idle_fabric():
    rng = np.random.default_rng(11)
    for _ in range(8):
        s = float(rng.integers(128, 1 << 14))
        b = float(rng.integers(1, 64))
        c = float(rng.uniform(1e12, 1e16))
        v_ici = float(rng.uniform(1e9, 1e12))
        v_dcn = float(rng.uniform(1e8, 1e10))
        if abs(fcr(s, b, v_ici, c) - 1.0) < 1e-3 or \
                abs(fcr(s, b, v_dcn, c) - 1.0) < 1e-3:
            continue                   # numerical knife-edge
        fab = PodFabric(3, 3, v_ici, v_dcn)
        closed = fcr_per_tier(fab, s, b, c)
        assert closed[TIER_ICI] == pytest.approx(fcr(s, b, v_ici, c))
        assert closed[TIER_DCN] == pytest.approx(fcr(s, b, v_dcn, c))
        hidden = fcr_hidden_per_tier(fab, s, b, c, phi=1e8)
        assert hidden[TIER_ICI] == is_free(s, b, v_ici, c)
        assert hidden[TIER_DCN] == is_free(s, b, v_dcn, c)


# --------------------------------------------------------------------------- #
# hierarchical train traffic
# --------------------------------------------------------------------------- #
def test_hierarchical_step_traffic_shapes():
    g = 1e9
    p = hierarchical_step_traffic(g, n_pods=4, pod_size=8)
    assert p.train_bytes == pytest.approx(2 * 7 / 8 * g)
    assert p.dcn_bytes == pytest.approx(2 * 3 / 4 * g / 8)
    # degenerate: one pod -> flat intra-pod ring, no DCN leg
    flat = hierarchical_step_traffic(g, n_pods=1, pod_size=8)
    assert flat.dcn_bytes == 0.0
    # degenerate: singleton pods -> pure gateway ring
    gw = hierarchical_step_traffic(g, n_pods=8, pod_size=1)
    assert gw.train_bytes == 0.0
    assert gw.dcn_bytes == pytest.approx(2 * 7 / 8 * g)


# --------------------------------------------------------------------------- #
# cluster-level: pod fabric training + storm recovery
# --------------------------------------------------------------------------- #
def _mk_pod_cluster(tmp_path, recovery=None, **fabric_kw):
    import dataclasses

    import jax  # noqa: F401  (ensures cpu backend initialized)
    from repro.configs import get_arch, reduce_for_smoke
    from repro.optim import AdamWConfig
    from repro.runtime.cluster import (ClusterConfig, FabricConfig,
                                       SimCluster)
    cfg = dataclasses.replace(reduce_for_smoke(get_arch("qwen3-0.6b")),
                              dtype="float32")
    fabric_kw.setdefault("quantum", 2048)
    fabric_kw.setdefault("pods", 2)
    fabric_kw.setdefault("dcn_bw", 5e9)
    fabric_kw.setdefault("dcn_latency", 1e-4)
    return SimCluster(
        cfg,
        cluster=ClusterConfig(
            dp=4, global_batch=8, seq_len=16, ckpt_dir=tmp_path / "ck",
            full_every=50,
            hp=AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=50), seed=0),
        fabric=FabricConfig(**fabric_kw), recovery=recovery)


def test_cluster_builds_pod_fabric_and_trains(tmp_path):
    import jax
    clu = _mk_pod_cluster(tmp_path)
    assert isinstance(clu.topology, PodFabric)
    assert clu.topology.n_pods == 2 and clu.topology.pod_size == 2
    losses = clu.run(3)
    assert all(np.isfinite(l) for l in losses)
    # the two-level allreduce loaded BOTH tiers with TRAIN traffic
    prof = clu.step_traffic_profile()
    assert prof.dcn_bytes > 0
    moved = sum(clu.topology.edge(*e).n_finished
                for e in clu.topology.tier_edges(TIER_DCN))
    assert moved > 0
    # state still bitwise-identical to a flat-ring run is not required —
    # but recovery must be: exercised in the storm test below
    del jax


def test_cluster_storm_recovery_bitwise_over_dcn(tmp_path):
    import jax
    clu = _mk_pod_cluster(tmp_path)
    clu.run(2)
    at_failure = [np.asarray(x).copy() for x in jax.tree.leaves(clu.state)]
    rep_storm = clu.inject_storm(7, pods=1)
    assert len(rep_storm.pods) == 1
    assert len(rep_storm.nodes) == 2   # the whole 2-worker pod died
    dead = set(rep_storm.nodes)
    assert all(not clu.workers[w].alive for w in dead)
    # one dead worker's backup holder is in the OTHER pod (ring successor),
    # so its recovery stream must cross the DCN gateway edge
    report = clu.recover()
    assert report.kind == "software"
    assert report.rolled_back_iterations == 0
    for x, y in zip(at_failure, jax.tree.leaves(clu.state)):
        np.testing.assert_array_equal(x, np.asarray(y))
    losses = clu.run(2)
    assert all(np.isfinite(l) for l in losses)


def test_cluster_storm_edge_damage_persists_then_heals(tmp_path):
    import jax  # noqa: F401
    clu = _mk_pod_cluster(tmp_path)
    clu.run(2)
    rep_storm = clu.inject_storm(5, pods=1, edge_failures=1)
    assert len(rep_storm.edges) == 1
    assert rep_storm.edges[0] in clu.topology.dark_edges
    report = clu.recover()             # streams routed around the dark edge
    assert report.recovered_from == "neighbor"
    # a completed recovery repairs the storm's fabric damage with the pods
    assert rep_storm.edges[0] not in clu.topology.dark_edges
    assert clu.last_storm is None
    losses = clu.run(2)
    assert all(np.isfinite(l) for l in losses)


def test_submit_step_traffic_loads_each_tier():
    fab = PodFabric(2, 4, 1e9, 1e8)
    tp = TopologyTransport(fab)
    prof = hierarchical_step_traffic(8e6, 2, 4)
    trs = submit_step_traffic(tp, prof, 0.0)
    assert len(trs) == len(fab.live_edges())
    for e in fab.tier_edges(TIER_ICI):
        assert fab.edge(*e).pending_bytes("TRAIN") == \
            pytest.approx(prof.train_bytes)
    for e in fab.tier_edges(TIER_DCN):
        assert fab.edge(*e).pending_bytes("TRAIN") == \
            pytest.approx(prof.dcn_bytes)
