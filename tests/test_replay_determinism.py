"""Dynamic backstop for simlint SIM006: scenario replays must be
bit-identical across different `PYTHONHASHSEED` values.

SIM006 statically bans unordered set/dict iteration feeding event
submission; this test catches whatever slips past it (or past a wrong
suppression justification) by actually running scenarios in two fresh
interpreters whose str/bytes hash randomization differs and comparing
the full pinned verdicts byte for byte. Any hash-order-dependent event
tie-break, storm ordering, or verdict booking shows up as a diff here.
"""
import json
import os
import subprocess
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]

# fast corpus subset covering the nastiest ordering surfaces: concurrent
# recovery races, gray-link scans over per-edge dicts, and straggler
# observation maps
SCENARIOS = ("clean_software_failure", "recovery_race_concurrent",
             "gray_link_degradation", "persistent_straggler")

DRIVER = """
import dataclasses, json, sys
from repro.runtime.scenarios import corpus, run_scenario

names = set(sys.argv[1].split(","))
out = {}
for sc in corpus():
    if sc.name in names:
        out[sc.name] = run_scenario(sc).pinned()
print(json.dumps(out, sort_keys=True))
"""


def _replay(hashseed: str) -> str:
    env = dict(os.environ)
    env["PYTHONHASHSEED"] = hashseed
    env["PYTHONPATH"] = str(ROOT / "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    env.setdefault("JAX_PLATFORMS", "cpu")
    proc = subprocess.run(
        [sys.executable, "-c", DRIVER, ",".join(SCENARIOS)],
        cwd=ROOT, env=env, capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stderr
    return proc.stdout.strip()


def test_replays_bit_identical_across_hash_seeds():
    a = _replay("0")
    b = _replay("1")
    assert json.loads(a), "driver produced no verdicts"
    assert a == b, (
        "verdicts diverged between PYTHONHASHSEED=0 and =1 — some event "
        "submission or booking iterates an unordered container "
        f"(simlint SIM006 backstop)\n0: {a}\n1: {b}")
