"""Docs stay true: README/docs code snippets' repro imports resolve, CLI
`python -m` references exist, and every src/repro package is in the README
module map (tools/check_docs.py, also the CI docs job)."""
import subprocess
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent


def test_docs_snippets_and_module_map():
    proc = subprocess.run(
        [sys.executable, str(ROOT / "tools" / "check_docs.py")],
        capture_output=True, text=True)
    assert proc.returncode == 0, f"\n{proc.stdout}\n{proc.stderr}"
