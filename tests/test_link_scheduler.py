"""LinkScheduler edge cases (paper §5.3): TRAIN preemption mid-quantum,
zero-byte transfers, and residual STATE surviving across run() calls."""
import pytest

from repro.core.lccl import LinkScheduler


def test_train_arriving_mid_state_quantum_yields():
    """A STATE quantum that would cross a TRAIN arrival is aborted: TRAIN
    starts exactly at its submit time, never queued behind STATE."""
    sch = LinkScheduler(bandwidth=1e9, quantum=1e8)    # 100 ms quanta
    st = sch.submit("STATE", 3e8, t=0.0)
    tr = sch.submit("TRAIN", 2e8, t=0.05)              # mid-first-quantum
    sch.drain()
    assert tr.t_start == pytest.approx(0.05, abs=1e-9)   # TRAIN never waits
    assert tr.t_finish == pytest.approx(0.25, abs=1e-9)
    # STATE restarts after TRAIN; the aborted quantum is retransmitted, so
    # it finishes 3 quanta AFTER the TRAIN completes
    assert st.t_finish == pytest.approx(0.25 + 0.3, abs=1e-9)
    assert st.t_finish > tr.t_finish


def test_zero_byte_transfers_complete_instantly():
    sch = LinkScheduler(bandwidth=1e9, quantum=1e6)
    z_state = sch.submit("STATE", 0.0, t=1.0)
    z_train = sch.submit("TRAIN", 0.0, t=2.0)
    sch.drain()
    assert z_state.t_finish == pytest.approx(1.0)
    assert z_train.t_finish == pytest.approx(2.0)
    assert sch.idle


def test_run_until_leaves_residual_state_resumable():
    """run(until=...) mid-transfer keeps the partial STATE item; a later
    run() resumes it from where it stopped instead of restarting."""
    sch = LinkScheduler(bandwidth=1e9, quantum=1e6)    # 1 ms quanta
    st = sch.submit("STATE", 5e8, t=0.0)               # 500 ms total
    sch.run(until=0.2)
    assert not sch.idle
    assert sch.pending_bytes("STATE") == pytest.approx(3e8, rel=1e-3)
    assert st.t_finish == 0.0                          # still in flight
    sch.run(until=1.0)
    assert sch.idle
    assert st.t_finish == pytest.approx(0.5, rel=1e-6)  # resumed, not reset
    assert sch.now == pytest.approx(1.0)


def test_clock_persists_across_runs():
    sch = LinkScheduler(bandwidth=1e9, quantum=1e6)
    a = sch.submit("TRAIN", 1e8, t=0.0)
    sch.run(until=0.5)
    b = sch.submit("TRAIN", 1e8, t=0.6)
    sch.run(until=2.0)
    assert a.t_finish == pytest.approx(0.1)
    assert b.t_start == pytest.approx(0.6)


def test_state_only_uses_full_bandwidth():
    sch = LinkScheduler(bandwidth=2e9, quantum=1e6)
    st = sch.submit("STATE", 1e9, t=0.0)
    busy = sch.run(until=10.0)
    assert st.t_finish == pytest.approx(0.5, rel=1e-6)
    assert busy == pytest.approx(0.5, rel=1e-6)


def test_drain_converges_when_train_denser_than_quantum():
    """Regression (ISSUE 4): TRAIN arrivals spaced tighter than one STATE
    quantum starved the old growing-horizon retry loop toward its
    non-convergence RuntimeError; the single-pass event-ordered drain just
    processes the arrivals in order and never raises."""
    sch = LinkScheduler(bandwidth=1e9, quantum=1e9)    # 1 s quanta
    st = sch.submit("STATE", 2e9, t=0.0)
    trains = [sch.submit("TRAIN", 1e5, t=0.5 * i) for i in range(1000)]
    sch.drain()
    assert sch.idle
    assert st.finished and all(tr.finished for tr in trains)
    # STATE only completes after the last dense TRAIN arrival frees a full
    # quantum: 999 * 0.5 s of arrivals, then 2 quanta of 1 s each
    assert st.t_finish == pytest.approx(0.5 * 999 + 1e5 / 1e9 + 2.0,
                                        rel=1e-6)


def test_drain_clock_carries_no_slack():
    """The old drain ran growing horizons and then clamped the clock back;
    the event-ordered drain lands exactly on the last transmission end, so
    a transfer submitted right after drain() starts at its own submit time
    instead of being delayed by leftover horizon slack."""
    sch = LinkScheduler(bandwidth=1e9, quantum=1e6)
    sch.submit("STATE", 3e8, t=0.0)                    # finishes at 0.3 s
    t_done = sch.drain()
    assert t_done == pytest.approx(0.3, rel=1e-9)
    assert sch.now == pytest.approx(0.3, rel=1e-9)
    late = sch.submit("STATE", 1e8, t=0.4)
    sch.drain()
    assert late.t_start == pytest.approx(0.4, rel=1e-9)
    assert late.t_finish == pytest.approx(0.5, rel=1e-9)
