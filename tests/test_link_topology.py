"""Per-link topology (ISSUE 2 tentpole): ring construction, routing around
dark nodes/edges, multi-hop store-and-forward timing, per-edge TRAIN/STATE
contention, per-edge FCR matching the closed form, hotspot bottlenecks, and
the NACK retransmission path through both transports."""
import numpy as np
import pytest

from repro.ckpt.stream import (ChunkedStream, StreamAssembler, StreamTransport,
                               TopologyTransport)
from repro.core.fcr import fcr, fcr_hidden_per_edge, is_free
from repro.core.lccl import (LinkScheduler, LinkTopology, edge_key,
                             submit_chunked_path)


# --------------------------------------------------------------------------- #
# graph shape + routing
# --------------------------------------------------------------------------- #
def test_ring_edges_and_neighbors():
    topo = LinkTopology(4, 1e9)
    assert sorted(topo.edges()) == [(0, 1), (0, 3), (1, 2), (2, 3)]
    assert topo.neighbors(0) == [1, 3]
    full = LinkTopology(4, 1e9, kind="full")
    assert len(full.edges()) == 6


def test_ring_path_shortest_and_multihop():
    topo = LinkTopology(6, 1e9)
    assert topo.path(0, 1) == [(0, 1)]
    assert topo.path(1, 0) == [(0, 1)]
    assert topo.path(0, 2) == [(0, 1), (1, 2)]
    assert topo.path(0, 5) == [(0, 5)]         # the short way around
    assert topo.path(0, 0) == []


def test_path_routes_around_dark_node_and_edge():
    topo = LinkTopology(4, 1e9)
    topo.fail_node(1)
    # 0 -> 2 must detour the long way: 0-3, 3-2
    assert topo.path(0, 2) == [(0, 3), (2, 3)]
    topo.restore_node(1)
    topo.fail_edge(0, 1)
    assert topo.path(0, 1) == [(0, 3), (2, 3), (1, 2)]
    topo.restore_edge(0, 1)
    assert topo.path(0, 1) == [(0, 1)]


def test_no_live_path_raises():
    topo = LinkTopology(4, 1e9)
    topo.fail_node(1)
    topo.fail_node(3)
    with pytest.raises(RuntimeError, match="no live path"):
        topo.path(0, 2)


def test_least_loaded_edge_prefers_idle():
    topo = LinkTopology(4, 1e9)
    topo.edge(0, 1).submit("TRAIN", 5e8, 0.0)
    topo.edge(1, 2).submit("STATE", 5e8, 0.0)
    assert topo.least_loaded_edge() in ((0, 3), (2, 3))
    topo.fail_node(3)                  # both idle edges go dark
    assert topo.least_loaded_edge() == (1, 2) or \
        topo.least_loaded_edge() == (0, 1)


# --------------------------------------------------------------------------- #
# multi-hop store-and-forward timing
# --------------------------------------------------------------------------- #
def test_multihop_pipeline_timing():
    """Chunked store-and-forward over k equal hops finishes in
    ~ total/bw + (k-1) * quantum/bw (pipelined), not k * total/bw."""
    topo = LinkTopology(6, 1e6, quantum=1e4)
    path = topo.path(0, 3)             # 3 hops
    pts = submit_chunked_path(topo, "STATE", 1e5, 0.0, path, quantum=1e4)
    topo.drain()
    finish = max(pt.t_finish for pt in pts)
    assert finish == pytest.approx(0.1 + 2 * 0.01, rel=1e-6)


def test_hotspot_edge_bottlenecks_exactly():
    """Acceptance criterion: with a single saturated hotspot edge on the
    path, recovery is bottlenecked by exactly that edge's residual
    bandwidth."""
    bw, hot_bw = 1e9, 1e8
    topo = LinkTopology(8, bw, quantum=1 << 20)
    topo.set_bandwidth(1, 2, hot_bw)   # the hotspot
    path = topo.path(0, 3)             # 0-1, 1-2(hot), 2-3
    nbytes = 64 << 20
    pts = submit_chunked_path(topo, "STATE", nbytes, 0.0, path)
    topo.drain()
    finish = max(pt.t_finish for pt in pts)
    # dominated by the hotspot: total/hot_bw, plus one pipelined quantum on
    # the (fast) edge before and after
    expect = nbytes / hot_bw + 2 * (1 << 20) / bw
    assert finish == pytest.approx(expect, rel=1e-3)
    # and WITHOUT the hotspot the same path is ~10x faster
    topo2 = LinkTopology(8, bw, quantum=1 << 20)
    pts2 = submit_chunked_path(topo2, "STATE", nbytes, 0.0, topo2.path(0, 3))
    topo2.drain()
    assert finish > 8 * max(pt.t_finish for pt in pts2)


def test_train_preempts_only_its_edge():
    """TRAIN on one edge delays only streams crossing that edge."""
    def finish(load_edge):
        topo = LinkTopology(4, 1e6, quantum=1e3)
        if load_edge is not None:
            topo.submit_train_edge(*load_edge, 2e6, 0.0)   # 2 s of TRAIN
        pts = submit_chunked_path(topo, "STATE", 1e5, 0.0,
                                  [(0, 1)], quantum=1e3)
        topo.drain()
        return max(pt.t_finish for pt in pts)
    assert finish(None) == pytest.approx(0.1, rel=1e-6)
    assert finish((1, 2)) == pytest.approx(0.1, rel=1e-6)   # other edge: free
    assert finish((0, 1)) > 2.0                             # same edge: waits


def test_submit_train_ring_loads_every_live_edge():
    topo = LinkTopology(4, 1e9)
    topo.fail_node(2)
    trs = topo.submit_train_ring(1e6, 0.0)
    assert len(trs) == 2               # edges (1,2) and (2,3) are dark
    assert all(tr.kind == "TRAIN" for tr in trs)


# --------------------------------------------------------------------------- #
# per-edge FCR (acceptance criterion: matches the closed form on a
# dedicated ring)
# --------------------------------------------------------------------------- #
def test_per_edge_fcr_matches_closed_form_on_dedicated_ring():
    rng = np.random.default_rng(7)
    for _ in range(10):
        s = float(rng.integers(128, 1 << 16))
        b = float(rng.integers(1, 64))
        c = float(rng.uniform(1e12, 1e16))
        bws = {e: float(rng.uniform(1e9, 1e12)) for e in
               [(0, 1), (1, 2), (2, 3), (0, 3)]}
        if any(abs(fcr(s, b, v, c) - 1.0) < 1e-3 for v in bws.values()):
            continue                   # numerical knife-edge
        topo = LinkTopology(4, 1e9, edge_bw=bws)
        hidden = fcr_hidden_per_edge(topo, s, b, c, phi=1e8)
        for e, v in bws.items():
            assert hidden[e] == is_free(s, b, v, c), (e, v)


def test_per_edge_fcr_hotspot_breaks_only_that_edge():
    s, b, c, phi = 4096, 8, 1e15, 1e8
    v = 2.0 * c / (s * b) * 4.0        # comfortably free default links
    topo = LinkTopology(4, v)
    topo.set_bandwidth(1, 2, v / 16.0)  # asymmetric hotspot: FCR < 1 there
    hidden = fcr_hidden_per_edge(topo, s, b, c, phi=phi)
    assert hidden[(1, 2)] is False
    assert all(hidden[e] for e in hidden if e != (1, 2))


# --------------------------------------------------------------------------- #
# TopologyTransport: routed streams + NACK healing
# --------------------------------------------------------------------------- #
def _stream_and_asm(n=400, quantum=512, sid="s"):
    arr = np.arange(n, dtype=np.float32)
    cs = ChunkedStream.from_array(sid, arr, quantum=quantum)
    return arr, cs, StreamAssembler.for_stream(cs)


def test_topology_transport_multihop_bitwise():
    topo = LinkTopology(6, 1e6, quantum=256)
    tp = TopologyTransport(topo)
    arr, cs, asm = _stream_and_asm()
    ticket = tp.send(cs, 0.0, assembler=asm, src=0, dst=3)
    tp.drain()
    assert ticket.complete and asm.complete
    np.testing.assert_array_equal(asm.to_array(), arr)


def test_topology_transport_least_loaded_for_unrouted():
    topo = LinkTopology(4, 1e6, quantum=256)
    topo.edge(0, 1).submit("TRAIN", 1e6, 0.0)
    tp = TopologyTransport(topo)
    arr, cs, asm = _stream_and_asm()
    tp.send(cs, 0.0, assembler=asm)    # no src/dst: least-loaded edge
    assert topo.edge(0, 1).pending_bytes("STATE") == 0.0
    tp.drain()
    assert asm.complete


def test_nack_retransmit_heals_corrupt_chunk_topology():
    topo = LinkTopology(4, 1e6, quantum=256)
    tp = TopologyTransport(topo)
    arr, cs, asm = _stream_and_asm()
    tp.corrupt_once("s", 1)
    tp.corrupt_once("s", 2)
    tp.send(cs, 0.0, assembler=asm, src=2, dst=0)
    tp.drain()
    assert asm.complete                # healed without a missing() pass
    assert asm.rejected == 2
    assert tp.nacks_sent == 2
    np.testing.assert_array_equal(asm.to_array(), arr)


def test_nack_retransmit_heals_on_single_link_too():
    tp = StreamTransport(LinkScheduler(1e6, quantum=256))
    arr, cs, asm = _stream_and_asm()
    tp.corrupt_once("s", 0)
    ticket = tp.send(cs, 0.0, assembler=asm)
    tp.drain()
    assert asm.complete and ticket.complete
    assert tp.nacks_sent == 1
    # the resend costs link time: finish strictly after the clean case
    tp2 = StreamTransport(LinkScheduler(1e6, quantum=256))
    _, cs2, asm2 = _stream_and_asm()
    t2 = tp2.send(cs2, 0.0, assembler=asm2)
    tp2.drain()
    assert ticket.finish_time > t2.finish_time


def test_nack_gives_up_after_retransmit_budget():
    """Persistent corruption exhausts the per-chunk NACK budget; the chunk
    stays in missing() (a later full resend pass can still heal it)."""
    topo = LinkTopology(4, 1e6, quantum=256)
    tp = TopologyTransport(topo)
    tp.max_retransmits = 2
    arr, cs, asm = _stream_and_asm()
    # corrupted on the initial send AND both retransmits: budget exhausted
    tp.corrupt_once("s", 0, times=3)
    tp.send(cs, 0.0, assembler=asm, src=0, dst=1)
    tp.drain()
    assert asm.missing() == [0]
    assert tp.nacks_sent == 2          # original + 2 retransmits, then stop
    assert asm.rejected == 3
    # the classic missing() resend pass (clean wire now) heals it
    tp.send(cs, 10.0, assembler=asm, src=0, dst=1)
    tp.drain()
    assert asm.complete
    np.testing.assert_array_equal(asm.to_array(), arr)
