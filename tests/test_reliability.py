"""Control-plane surface tests: the straggler-detector fixes, the
InterruptibleBarrier rendezvous, StateController exact-cover/consistency,
and the ReliabilityController's gray-link + cadence loops on a fake
cluster (no jax model — these are fast units; the end-to-end loop runs in
test_scenario_fleet.py)."""
import threading
import time

import numpy as np
import pytest

from repro.core.controller import StateController
from repro.core.detection import (DetectionTimeline, InterruptibleBarrier,
                                  WorkerInterrupted)
from repro.core.lccl import LinkTopology, edge_key
from repro.runtime.reliability import (ReliabilityConfig,
                                       ReliabilityController,
                                       adapted_full_interval, observed_mtbf)
from repro.runtime.straggler import (StragglerDetector, StragglerPolicy,
                                     mitigation_speedup)


# --------------------------------------------------------------------------- #
# straggler.py fixes (pinned)
# --------------------------------------------------------------------------- #
def test_straggler_policy_not_shared_across_detectors():
    """The old `policy: StragglerPolicy = StragglerPolicy()` default was
    evaluated ONCE at def time — tuning one detector retuned every default-
    constructed detector in the process."""
    a = StragglerDetector(4)
    b = StragglerDetector(4)
    assert a.policy is not b.policy
    a.policy.threshold = 99.0
    assert b.policy.threshold == StragglerPolicy().threshold


def test_straggler_explicit_policy_is_used():
    pol = StragglerPolicy(threshold=2.5, min_observations=1)
    det = StragglerDetector(3, policy=pol)
    assert det.policy is pol


def test_mitigation_speedup_excludes_straggler_from_denominator():
    """Post-migration the cluster paces at the max over the REMAINING
    workers. The old code divided by the straggler's own baseline
    (sort[-1]), reporting `straggler_factor` regardless of the fleet."""
    times = np.array([1.0, 1.0, 1.0, 2.0])
    # straggler runs at 2.0 * 1.5 = 3.0; without it the pace is 1.0
    assert mitigation_speedup(times, 1.5) == pytest.approx(3.0)
    # the buggy version returned 1.5 here — pin that it does not
    assert mitigation_speedup(times, 1.5) != pytest.approx(1.5)


def test_mitigation_speedup_uniform_fleet():
    times = np.ones(4)
    assert mitigation_speedup(times, 2.0) == pytest.approx(2.0)


def test_mitigation_speedup_single_worker_is_identity():
    """Nobody to migrate to: no speedup."""
    assert mitigation_speedup(np.array([1.0]), 3.0) == pytest.approx(1.0)


def test_straggler_detector_flags_persistent_outlier():
    det = StragglerDetector(4, policy=StragglerPolicy(min_observations=3))
    for _ in range(5):
        for w in range(4):
            det.observe(w, 2.0 if w == 2 else 1.0)
    assert det.stragglers() == [2]
    assert det.cluster_step_time() == pytest.approx(2.0)


# --------------------------------------------------------------------------- #
# InterruptibleBarrier (§6.1): breakdown interrupt beats timeout
# --------------------------------------------------------------------------- #
def test_barrier_interrupt_beats_timeout():
    """A blocked collective wakes on the controller's breakdown
    notification LONG before the (NCCL-style) timeout would fire."""
    bar = InterruptibleBarrier(2)
    caught = {}

    def blocked():
        t0 = time.monotonic()
        try:
            bar.wait(0, timeout=30.0)
        except WorkerInterrupted as e:
            caught["failed"] = e.failed_workers
            caught["waited"] = time.monotonic() - t0

    th = threading.Thread(target=blocked)
    th.start()
    time.sleep(0.05)
    bar.interrupt([1])
    th.join(timeout=5.0)
    assert not th.is_alive()
    assert caught["failed"] == [1]
    assert caught["waited"] < 5.0          # nowhere near the 30 s timeout


def test_barrier_broken_state_rejects_new_waiters_until_reset():
    bar = InterruptibleBarrier(2)
    bar.interrupt([0])
    with pytest.raises(WorkerInterrupted):
        bar.wait(1, timeout=0.1)
    bar.reset()
    # full rendezvous works again after reset
    done = []

    def waiter(w):
        done.append(bar.wait(w, timeout=5.0))

    th = threading.Thread(target=waiter, args=(0,))
    th.start()
    gen_last = bar.wait(1, timeout=5.0)
    th.join(timeout=5.0)
    assert not th.is_alive()
    assert done[0] == gen_last             # same generation rendezvoused


def test_barrier_generation_advances_per_rendezvous_and_reset():
    bar = InterruptibleBarrier(1)
    g0 = bar.wait(0)
    g1 = bar.wait(0)
    assert g1 == g0 + 1
    bar.reset(n_workers=2)
    assert bar.n == 2
    g2_holder = []
    th = threading.Thread(target=lambda: g2_holder.append(bar.wait(0, 5.0)))
    th.start()
    g2 = bar.wait(1, timeout=5.0)
    th.join(timeout=5.0)
    assert g2_holder[0] == g2
    assert g2 > g1                          # reset bumped the generation


def test_barrier_timeout_is_the_slow_path():
    bar = InterruptibleBarrier(2)
    with pytest.raises(TimeoutError):
        bar.wait(0, timeout=0.05)


# --------------------------------------------------------------------------- #
# StateController: exact cover + consistency
# --------------------------------------------------------------------------- #
def _cover(ctl: StateController, iteration: int, dataset: int) -> None:
    """The active ranks' ranges exactly tile the iteration's global batch."""
    a = ctl.assignment(iteration, dataset)
    spans = sorted(a.ranges.values())
    assert len(spans) == ctl.active_dp
    start = (iteration * ctl.global_batch) % dataset
    assert spans[0][0] == start
    for (lo, hi), (lo2, _) in zip(spans, spans[1:]):
        assert hi == lo2                   # contiguous, no overlap, no gap
    assert spans[-1][1] - spans[0][0] == ctl.global_batch


def test_shrink_restore_exact_cover():
    ctl = StateController(dp=4, pp=1, tp=1, global_batch=8)
    _cover(ctl, 3, 64)
    ctl.shrink_dp([2])
    ctl.global_batch = 6                   # what SimCluster.shrink recomputes
    _cover(ctl, 4, 64)
    assert ctl.active_dp == 3
    ctl.shrink_dp([0])
    ctl.global_batch = 4
    _cover(ctl, 5, 64)
    ctl.restore_dp()
    ctl.global_batch = 8
    assert ctl.active_dp == 4
    _cover(ctl, 6, 64)


def test_shrink_dp_dedupes_lost_groups_and_floors_at_one():
    ctl = StateController(dp=3, pp=1, tp=1, global_batch=6)
    assert ctl.shrink_dp([1, 1, 2]) == 1   # two distinct losses
    assert ctl.shrink_dp([0]) == 1         # never below one
    assert ctl.restore_dp(2) == 2


def test_resolve_recovery_iteration_is_global_min():
    ctl = StateController(dp=4, pp=1, tp=1, global_batch=8)
    for d, it in enumerate([7, 5, 9, 6]):
        ctl.report_ckpt(d, it)
    assert ctl.resolve_recovery_iteration() == 5
    # a shrink drops the trailing groups from the consistency vote
    ctl.report_ckpt(3, 1)
    ctl.shrink_dp([3])
    assert ctl.resolve_recovery_iteration() == 5


def test_detect_failures_on_supplied_clock():
    """Liveness runs on whatever clock the caller supplies (SimCluster
    passes sim time) — no wall-clock reads in the detection path."""
    ctl = StateController(dp=3, pp=1, tp=1, global_batch=6,
                          heartbeat_timeout=1.0)
    for w in range(3):
        ctl.beat(w, now=0.0)
    ctl.beat(0, now=5.0)
    ctl.beat(2, now=5.0)
    assert ctl.detect_failures(now=5.0) == [1]
    assert ctl.detect_failures(now=0.9) == []


# --------------------------------------------------------------------------- #
# ReliabilityController units on a fake cluster (no jax, no model)
# --------------------------------------------------------------------------- #
class _FakeWorker:
    def __init__(self, wid):
        self.wid = wid
        self.alive = True

        class _Cfg:
            full_every = 50
        self.engine = type("E", (), {"cfg": _Cfg()})()


class _FakeCluster:
    """The duck-typed surface ReliabilityController drives."""

    def __init__(self, dp=4, bw=1e9):
        self.dp = dp
        self.t_iter_model = 0.05
        self.topology = LinkTopology(dp, bw, quantum=1 << 16)
        self.controller = StateController(dp=dp, pp=1, tp=1,
                                          global_batch=2 * dp,
                                          heartbeat_timeout=0.2)
        self.workers = [_FakeWorker(w) for w in range(dp)]
        self.last_step_times = None
        self._measured_detection = None
        self._detection_elapsed = False
        for w in range(dp):
            self.controller.beat(w, now=0.0)

    def shard_nbytes(self):
        return 4096.0

    def clear_straggler(self, wid):
        pass


def _mk_loop(**over):
    cfg = ReliabilityConfig(heartbeat_period=0.2, scan_period=0.2,
                            notify_latency=0.01, **over)
    clu = _FakeCluster()
    return clu, ReliabilityController(clu, cfg)


def test_loop_detects_silent_worker_within_one_heartbeat_of_analytic():
    clu, loop = _mk_loop()
    t = 0.0
    # healthy cadence, then worker 2 goes silent at t=0.25
    while t < 1.2:
        t = round(t + 0.05, 10)
        for w in range(clu.dp):
            if w == 2 and t > 0.25:
                continue
            clu.controller.beat(w, now=t)
        if t > 0.25 and 2 in [x.wid for x in clu.workers]:
            loop.note_failure([2], 0.25) if 2 not in loop.failed_at else None
        loop.tick(t)
    assert 2 in loop.detected
    lat = loop.last_detection_latency
    analytic = DetectionTimeline(0.2, 0.2, 0.01).detection_time()
    # measured within one heartbeat period of the closed-form worst case
    assert abs(lat - analytic) <= 0.2 + 1e-9
    assert clu._detection_elapsed and clu._measured_detection == lat


def test_loop_gray_edge_quarantined_from_observed_throughput():
    clu, loop = _mk_loop(min_gray_observations=1)
    e = edge_key(1, 2)
    sch = clu.topology.links[e]
    # healthy traffic, then the link silently degrades to 20% of spec
    for t in (0.05, 0.10, 0.15):
        sch.submit("TRAIN", 1e7, t)
    clu.topology.run(until=0.2)
    loop.tick(0.2)
    assert e not in loop.quarantined
    clu.topology.set_bandwidth(1, 2, 0.2e9)
    for t in (0.25, 0.30, 0.35):
        sch.submit("TRAIN", 1e7, t)
    clu.topology.run(until=0.6)
    loop.tick(0.6)
    assert e in loop.quarantined
    assert not clu.topology.edge_up(1, 2)   # routing detours around it
    ev = [x for x in loop.events if x.kind == "gray_edge"]
    assert len(ev) == 1
    assert ev[0].detail["observed_bps"] == pytest.approx(0.2e9)
    # repair lifts the quarantine
    loop.release_edge(1, 2)
    assert clu.topology.edge_up(1, 2)


def test_loop_healthy_edges_never_quarantined():
    clu, loop = _mk_loop(min_gray_observations=1)
    for e, sch in clu.topology.links.items():
        sch.submit("TRAIN", 1e7, 0.01)
    clu.topology.run(until=0.5)
    loop.tick(0.5)
    assert loop.quarantined == {}


def test_adapted_cadence_closed_form_and_clamps():
    assert adapted_full_interval(200.0, 1.0) == pytest.approx(20.0)
    assert observed_mtbf([10.0, 30.0, 50.0]) == pytest.approx(20.0)
    assert observed_mtbf([10.0]) is None
    clu, loop = _mk_loop(ckpt_cost_s=0.1, min_full_every=5,
                         max_full_every=500)
    loop.detection_times = [1.0, 5.0]      # observed MTBF = 4 s
    loop._adapt_cadence(5.0)
    expect = int(round(adapted_full_interval(4.0, 0.1) / 0.05))
    assert loop.current_full_every == expect
    for w in clu.workers:
        assert w.engine.cfg.full_every == expect
    # degenerate trace clamps at the floor instead of thrashing
    loop.detection_times = [2.0, 2.0]
    loop._adapt_cadence(6.0)
    assert loop.current_full_every == 5


def test_straggler_migration_rebinds_role_to_spare():
    clu, loop = _mk_loop(straggler=StragglerPolicy(min_observations=3))
    for _ in range(5):
        clu.last_step_times = {w: (0.1 if w == 1 else 0.05)
                               for w in range(clu.dp)}
        loop.tick(0.0)
        if any(x.kind == "straggler_migrate" for x in loop.events):
            break                       # migrated: stop feeding slow steps
    ev = [x for x in loop.events if x.kind == "straggler_migrate"]
    assert len(ev) == 1 and ev[0].detail["worker"] == 1
    spare = ev[0].detail["spare_rank"]
    assert spare >= clu.dp
    roles = clu.controller.roles
    assert roles.rank_to_role[spare].dp == 1
    assert 1 not in roles.rank_to_role      # old rank released
    # detector state was reset in the migrating tick: the worker is not
    # immediately re-flagged off its pre-migration history
    assert loop.straggler.count[1] == 0


# --------------------------------------------------------------------------- #
# hypothesis property tests (skipped when hypothesis is absent)
# --------------------------------------------------------------------------- #
try:
    import hypothesis  # noqa: F401
    _HAVE_HYPOTHESIS = True
except ImportError:
    _HAVE_HYPOTHESIS = False

if _HAVE_HYPOTHESIS:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    @given(st.integers(1, 16), st.integers(1, 8), st.integers(0, 1000),
           st.data())
    @settings(max_examples=60, deadline=None)
    def test_exact_cover_under_random_shrinks(dp, per, iteration, data):
        """However the job shrinks, the active ranks' ranges always tile
        the (recomputed) global batch contiguously."""
        ctl = StateController(dp=dp, pp=1, tp=1, global_batch=dp * per)
        n_lost = data.draw(st.integers(0, dp - 1))
        lost = data.draw(st.lists(st.integers(0, dp - 1),
                                  min_size=n_lost, max_size=n_lost,
                                  unique=True))
        ctl.shrink_dp(lost)
        ctl.global_batch = ctl.active_dp * per
        _cover(ctl, iteration, 4096)

    @given(st.lists(st.floats(0.0, 1e5, allow_nan=False), min_size=2,
                    max_size=32))
    @settings(max_examples=60, deadline=None)
    def test_observed_mtbf_invariants(ts):
        m = observed_mtbf(ts)
        assert m is not None and m >= 0.0
        # shift invariance: MTBF depends on spacing, not the epoch
        m2 = observed_mtbf([t + 123.0 for t in ts])
        assert m2 == pytest.approx(m, abs=1e-6)

    @given(st.floats(1e-3, 1e6), st.floats(1e-3, 1e3))
    @settings(max_examples=60, deadline=None)
    def test_adapted_interval_monotone_in_mtbf(mtbf, cost):
        a = adapted_full_interval(mtbf, cost)
        b = adapted_full_interval(2 * mtbf, cost)
        assert b > a                        # rarer failures, rarer ckpts
        assert a == pytest.approx((2 * cost * mtbf) ** 0.5)

    @given(st.floats(0.1, 10.0), st.lists(
        st.floats(0.01, 5.0, allow_nan=False), min_size=2, max_size=16))
    @settings(max_examples=60, deadline=None)
    def test_mitigation_speedup_at_least_factor_over_rest(factor, times):
        """Speedup >= straggler_factor whenever the straggler was already
        the pacing worker (it is factor * max / second_max >= factor)."""
        sp = mitigation_speedup(np.array(times), max(factor, 1.0))
        assert sp >= max(factor, 1.0) - 1e-9
