"""Hypothesis property tests on FFTrainer's core invariants."""
import math

import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="hypothesis not installed — property tests skipped (declared in "
           "pyproject [dev]; tier-1 degrades gracefully without it)")
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.analytic import (cluster_failure_probability, k_failure_prob,
                                 mfu_loss, recovery_prob_given_k,
                                 recovery_probability)
from repro.core.consistency import ReconcileAction, reconcile
from repro.core.fcr import fcr, is_free
from repro.core.razor import razor_bytes_formula
from repro.data.indexer import TidIndexer


# --------------------------------------------------------------------------- #
# Eq. (3): non-adjacent failure probability
# --------------------------------------------------------------------------- #
@given(st.integers(4, 64), st.integers(0, 8))
def test_recovery_prob_given_k_in_unit_interval(n, k):
    p = recovery_prob_given_k(n, min(k, n))
    assert 0.0 <= p <= 1.0


@given(st.integers(6, 24), st.integers(2, 5))
@settings(max_examples=30, deadline=None)
def test_recovery_prob_matches_bruteforce(n, k):
    """Eq. (3) equals the exhaustive count of adjacent-pair-free subsets on a
    cycle of n (small n brute force)."""
    if k > n // 2:
        return
    import itertools
    total = ok = 0
    for comb in itertools.combinations(range(n), k):
        total += 1
        s = set(comb)
        if not any(((i + 1) % n) in s for i in s):
            ok += 1
    expected = ok / total
    assert math.isclose(recovery_prob_given_k(n, k), expected,
                        rel_tol=1e-9, abs_tol=1e-12)


@given(st.integers(8, 2000), st.floats(0.5, 24.0))
@settings(max_examples=30, deadline=None)
def test_recovery_probability_monotone_in_horizon(n, h):
    assert recovery_probability(n, h) >= recovery_probability(n, h * 2) - 1e-9


def test_paper_table2_values():
    """Table 2: P_16384 and P_65536 at cluster-MTBF horizons."""
    assert abs(cluster_failure_probability(16384, 3) - 0.46) < 0.01
    assert abs(cluster_failure_probability(65536, 3) - 0.91) < 0.01
    assert abs(cluster_failure_probability(16384, 12) - 0.91) < 0.01


def test_paper_table6_values():
    """P(N,H) > 99% for thousands of hosts over 12 h (paper Table 6)."""
    for hosts, h, lo in [(800, 3, 0.999), (2000, 12, 0.99),
                         (2000, 3, 0.999)]:
        assert recovery_probability(hosts, h) > lo


@given(st.integers(0, 65), st.integers(1, 200))
def test_k_failure_prob_is_distribution(k, n):
    if k > n:
        return
    total = sum(k_failure_prob(n, i, 3.0) for i in range(n + 1))
    assert abs(total - 1.0) < 1e-6


# --------------------------------------------------------------------------- #
# MFU loss / FCR
# --------------------------------------------------------------------------- #
@given(st.floats(0.0, 100.0), st.floats(1.0, 10_000.0),
       st.floats(1.0, 3600.0), st.floats(600.0, 1e6))
def test_mfu_loss_bounds(t_ckpt, t_i, mttr, mtbf):
    l = mfu_loss(t_ckpt, t_i, mttr, mtbf)
    assert 0 <= l.ckpt <= 1 and 0 <= l.recover <= 1 and 0 <= l.rollback <= 1


def test_mfu_loss_paper_magnitude():
    """3-hour MTBF, 30-min interval, zero CKPT overhead -> ~19% loss
    (paper §3.1 'a 3-hour breakdown results in a 19% MFU loss' includes
    recovery; with MTTR=1000 s)."""
    l = mfu_loss(0.0, 1800.0, 1000.0, 3 * 3600.0)
    assert 0.10 < l.total < 0.25


@given(st.integers(128, 1_000_000), st.integers(1, 512),
       st.floats(1e9, 1e12), st.floats(1e12, 1e16))
def test_fcr_threshold_consistency(s, b, v, c):
    assert is_free(s, b, v, c) == (fcr(s, b, v, c) >= 1.0)


def test_fcr_matches_overlap_condition():
    """FCR >= 1 iff T_c >= T'_ckpt for random phi (phi cancels)."""
    from repro.core.analytic import ckpt_time_razor, compute_time
    rng = np.random.default_rng(1)
    for _ in range(100):
        s = float(rng.integers(128, 1 << 20))
        b = float(rng.integers(1, 256))
        v = float(rng.uniform(1e9, 1e12))
        c = float(rng.uniform(1e12, 1e16))
        phi = float(rng.uniform(1e6, 1e11))
        lhs = compute_time(s, b, phi, c) >= ckpt_time_razor(phi, v)
        assert lhs == is_free(s, b, v, c)


# --------------------------------------------------------------------------- #
# Razor arithmetic
# --------------------------------------------------------------------------- #
@given(st.integers(1, 10**12), st.integers(1, 1024))
def test_razor_bytes_shrink_with_dp(phi, d):
    assert razor_bytes_formula(phi, d) <= 12 * phi
    assert razor_bytes_formula(phi, 1) == 12 * phi


# --------------------------------------------------------------------------- #
# Consistency reconciliation
# --------------------------------------------------------------------------- #
@given(st.lists(st.integers(100, 101), min_size=2, max_size=16))
def test_reconcile_one_iteration_skew(versions):
    acts = reconcile(dict(enumerate(versions)))
    target = min(versions)
    for a in acts:
        assert a.target_iteration == target
        assert a.action == ("keep" if versions[a.worker] == target
                            else "rollback")


def test_reconcile_rejects_wide_skew():
    with pytest.raises(AssertionError):
        reconcile({0: 100, 1: 103})


# --------------------------------------------------------------------------- #
# TID indexer: exact cover + determinism + elasticity
# --------------------------------------------------------------------------- #
@given(st.integers(1, 16), st.integers(0, 50), st.integers(1, 4))
@settings(max_examples=50, deadline=None)
def test_indexer_exact_cover(dp, iteration, batch_mult):
    gb = dp * batch_mult * 2
    idx = TidIndexer(dataset_size=4096, global_batch=gb, seed=3)
    parts = [idx.indices(iteration, r, dp) for r in range(dp)]
    allv = np.concatenate(parts)
    assert len(allv) == gb                      # exact cover
    g = idx.global_slice(iteration)
    np.testing.assert_array_equal(np.sort(allv), np.sort(g))
    # determinism
    idx2 = TidIndexer(dataset_size=4096, global_batch=gb, seed=3)
    np.testing.assert_array_equal(idx2.indices(iteration, 0, dp), parts[0])


def test_indexer_epoch_permutation_no_repeats():
    idx = TidIndexer(dataset_size=64, global_batch=16, seed=0)
    seen = np.concatenate([idx.global_slice(i) for i in range(4)])  # 1 epoch
    assert len(np.unique(seen)) == 64


def test_indexer_elastic_preserves_global_order():
    """Shrinking dp re-partitions the SAME global slice."""
    idx = TidIndexer(dataset_size=1024, global_batch=32, seed=1)
    g = idx.global_slice(7)
    for dp in (1, 2, 4, 8):
        parts = np.concatenate([idx.indices(7, r, dp) for r in range(dp)])
        np.testing.assert_array_equal(parts, g)
