"""StateStream tentpole coverage: chunk format + CRCs, resumable assembly,
CkptEngine paths through the shared transport, scheduler-derived failover
timelines (preemption delays recovery), multi-failure resume-from-partial-
chunks on the cluster, and the emergent FCR hiding condition."""
import dataclasses
from pathlib import Path

import numpy as np
import pytest

from repro.ckpt.engine import CkptEngine, CkptEngineConfig
from repro.ckpt.stream import (ChunkedStream, StreamAssembler, StreamChunk,
                               StreamTransport, stream_pytree)
from repro.core.lccl import LinkScheduler
from repro.runtime.recovery import FaultScript


def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {"a": rng.normal(size=1000).astype(np.float32),
            "b": {"c": rng.normal(size=(3, 7)),
                  "d": np.int32(5)}}


# --------------------------------------------------------------------------- #
# chunk format
# --------------------------------------------------------------------------- #
def test_pytree_chunk_roundtrip_bitwise():
    tree = _tree()
    cs = ChunkedStream.from_pytree("s", tree, quantum=512)
    assert cs.n_chunks > 3
    assert sum(c.nbytes for c in cs.chunks) == cs.total_bytes
    asm = StreamAssembler.for_stream(cs)
    for c in reversed(cs.chunks):          # out-of-order delivery
        assert asm.offer(c)
    out = asm.to_pytree(tree)
    for k in ("a",):
        np.testing.assert_array_equal(out[k], tree[k])
    np.testing.assert_array_equal(out["b"]["c"], tree["b"]["c"])
    assert out["b"]["d"] == tree["b"]["d"]


def test_corrupt_chunk_rejected_by_crc():
    cs = ChunkedStream.from_pytree("s", _tree(), quantum=512)
    good = cs.chunks[1]
    flipped = bytes([good.payload[0] ^ 0xFF]) + good.payload[1:]
    bad = StreamChunk(good.stream_id, good.seq, good.n_chunks, good.offset,
                      flipped, good.crc, good.total_bytes)
    asm = StreamAssembler.for_stream(cs)
    assert not asm.offer(bad)
    assert asm.rejected == 1
    assert good.seq in asm.missing()       # still owed after corruption
    assert asm.offer(good)                 # retransmit succeeds


def test_assembler_resumes_from_partial():
    cs = ChunkedStream.from_pytree("s", _tree(), quantum=256)
    asm = StreamAssembler.for_stream(cs)
    for c in cs.chunks[:3]:
        asm.offer(c)
    assert len(asm.missing()) == cs.n_chunks - 3
    # duplicate delivery is idempotent
    assert not asm.offer(cs.chunks[0])
    for seq in asm.missing():
        asm.offer(cs.chunks[seq])
    assert asm.complete


# --------------------------------------------------------------------------- #
# transport: STATE chunks + TRAIN preemption on one scheduler
# --------------------------------------------------------------------------- #
def test_transport_delivers_through_scheduler():
    tp = StreamTransport(LinkScheduler(1e6, quantum=256))
    tree = _tree()
    ticket, asm = stream_pytree(tp, "t", tree, t=0.0, quantum=512)
    tp.drain()
    assert ticket.complete and asm.complete
    np.testing.assert_array_equal(asm.to_pytree(tree)["a"], tree["a"])


def test_train_traffic_delays_stream_completion():
    def finish(with_train):
        tp = StreamTransport(LinkScheduler(1e6, quantum=256))
        ticket, _ = stream_pytree(tp, "t", _tree(), t=0.0, quantum=512)
        if with_train:
            tp.submit_train(2e6, 0.0005)   # 2 s of TRAIN early on
        tp.drain()
        return ticket.finish_time
    assert finish(True) > finish(False) + 1.5


# --------------------------------------------------------------------------- #
# CkptEngine: instant + full + lazy all ride the shared link
# --------------------------------------------------------------------------- #
def test_engine_paths_stream_chunks(tmp_path):
    tp = StreamTransport(LinkScheduler(1e9, quantum=1 << 20))
    eng = CkptEngine(CkptEngineConfig(out_dir=tmp_path, full_every=2,
                                      quantum=512), worker_id=0, transport=tp)
    shard = {"shard": np.arange(400, dtype=np.float32)}
    eng.on_step(1, shard, shard, t=0.0)
    assert eng.streamed_chunks > 0
    n_after_instant = eng.streamed_chunks
    eng.maybe_full_checkpoint(2, {"w": np.ones(300, np.float32)}, t=0.1)
    assert eng.streamed_chunks > n_after_instant
    n_after_full = eng.streamed_chunks
    eng.lazy_backup(2, {"params": np.ones(100, np.float32)},
                    is_dp_rank0=True, t=0.2)
    assert eng.streamed_chunks > n_after_full
    tp.drain()
    assert tp.chunks_delivered == eng.streamed_chunks
    # full ckpt wrote a per-chunk CRC manifest
    from repro.ckpt.storage import load_manifest
    man = load_manifest(eng._full_path(2))
    assert man is not None and man["n_chunks"] >= 1
    eng.writer.drain()
    eng.close()


def test_engine_export_import_stream(tmp_path):
    eng = CkptEngine(CkptEngineConfig(out_dir=tmp_path, quantum=128))
    shard = {"shard": np.arange(100, dtype=np.float32)}
    eng.on_step(7, shard, shard)
    stream = eng.export_stream(7, which="neighbor")
    asm = StreamAssembler.for_stream(stream)
    for c in stream.chunks:
        asm.offer(c)
    out = CkptEngine.import_stream(asm, shard)
    np.testing.assert_array_equal(out["shard"], shard["shard"])
    eng.close()


# --------------------------------------------------------------------------- #
# failover timelines are scheduler-derived
# --------------------------------------------------------------------------- #
def test_preempted_state_chunks_delay_recovery():
    """The acceptance-criteria property: TRAIN traffic on the shared link
    preempts recovery STATE chunks and the fftrainer timeline stretches by
    the schedule's answer."""
    from repro.runtime.failover import fftrainer_timeline
    quiet = fftrainer_timeline(16, 10e9)
    busy = fftrainer_timeline(16, 10e9,
                              train_traffic=[(0.0, 50e9), (1.0, 50e9)])
    assert busy["network_and_state"] > quiet["network_and_state"] + 0.5
    assert busy["total"] > quiet["total"] + 0.5
    # without competition the schedule reduces to bytes/bandwidth (+ramp)
    assert quiet["network_and_state"] == pytest.approx(
        max(0.5 + 0.001 * 16, 10e9 / 50e9 + 0.2), rel=1e-3)


def test_baseline_timeline_still_serial():
    from repro.runtime.failover import baseline_timeline
    tl = baseline_timeline(16, 13e9 / 4)
    assert tl["state_recovery"] == pytest.approx(13e9 / 4 / 1e9 + 2.0,
                                                 rel=1e-3)
    assert tl["total"] > 800.0


# --------------------------------------------------------------------------- #
# emergent FCR
# --------------------------------------------------------------------------- #
def test_fcr_emergent_matches_closed_form():
    from repro.core.fcr import fcr, fcr_hidden_emergent, is_free
    rng = np.random.default_rng(3)
    for _ in range(40):
        s = float(rng.integers(128, 1 << 18))
        b = float(rng.integers(1, 64))
        v = float(rng.uniform(1e9, 1e12))
        c = float(rng.uniform(1e12, 1e16))
        if abs(fcr(s, b, v, c) - 1.0) < 1e-3:
            continue                      # numerical knife-edge
        assert fcr_hidden_emergent(s, b, v, c, phi=1e8) == is_free(s, b, v, c)


def test_fcr_hiding_breaks_under_train_contention():
    from repro.core.fcr import fcr_hidden_emergent, is_free
    s, b, c, phi = 4096, 8, 1e15, 1e8
    v = 2.0 * c / (s * b) * 1.1           # marginally free link
    assert is_free(s, b, v, c)
    t_c = 6 * s * b * phi / c
    busy = [(i * t_c, 0.5 * v * t_c) for i in range(3)]
    assert fcr_hidden_emergent(s, b, v, c, phi=phi)
    assert not fcr_hidden_emergent(s, b, v, c, phi=phi, train_traffic=busy)


# --------------------------------------------------------------------------- #
# cluster: multi-failure, resume from partial chunks (real state movement)
# --------------------------------------------------------------------------- #
def _mk_cluster(tmp_path, **fabric_kw):
    import jax  # noqa: F401  (ensures cpu backend initialized)
    from repro.configs import get_arch, reduce_for_smoke
    from repro.optim import AdamWConfig
    from repro.runtime.cluster import (ClusterConfig, FabricConfig,
                                       SimCluster)
    cfg = dataclasses.replace(reduce_for_smoke(get_arch("qwen3-0.6b")),
                              dtype="float32")
    fabric_kw.setdefault("quantum", 2048)
    return SimCluster(
        cfg,
        cluster=ClusterConfig(
            dp=4, global_batch=8, seq_len=16, ckpt_dir=tmp_path / "ck",
            full_every=50,
            hp=AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=50), seed=0),
        fabric=FabricConfig(**fabric_kw))


def test_multi_failure_resumes_from_partial_chunks(tmp_path):
    import jax
    ref = _mk_cluster(tmp_path / "a")
    ref.run(10)

    clu = _mk_cluster(tmp_path / "b")
    clu.run(5)
    clu.inject_failure([0], hardware=True)
    r1 = clu.recover(FaultScript(hardware=True, interrupt_after_chunks=3))
    assert r1.kind == "interrupted"
    assert r1.chunks_sent == 3 and r1.chunks_total > 3
    assert not clu.workers[0].alive        # still down mid-transfer

    # second concurrent failure (non-adjacent: its backup holder is alive)
    clu.inject_failure([2], hardware=True)
    r2 = clu.recover(FaultScript(hardware=True))
    assert r2.kind == "hardware"
    assert r2.chunks_reused == 3           # partial chunks NOT re-sent
    assert r2.chunks_sent == r2.chunks_total - 3
    assert r2.rolled_back_iterations == 0  # instant ckpt: zero rollback

    clu.run(10 - clu.iteration)
    for x, y in zip(jax.tree.leaves(ref.state), jax.tree.leaves(clu.state)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_corruption_mid_recovery_heals_via_nack(tmp_path):
    """Bytes flipped on the wire mid-recovery are CRC-rejected and healed by
    per-chunk NACK retransmits — recovery completes with NO rollback and the
    recovered state is bitwise identical to an uninterrupted run."""
    import jax
    ref = _mk_cluster(tmp_path / "a")
    ref.run(8)

    clu = _mk_cluster(tmp_path / "b")
    clu.run(5)
    clu.inject_failure([1], hardware=True)
    rep = clu.recover(FaultScript(hardware=True, corrupt_chunks=3))
    assert rep.kind == "hardware"
    assert rep.rolled_back_iterations == 0     # healed in-stream: no rollback
    assert clu.transport.nacks_sent == 3       # one immediate resend each
    clu.run(8 - clu.iteration)
    for x, y in zip(jax.tree.leaves(ref.state), jax.tree.leaves(clu.state)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_shrink_mid_transfer_keeps_partial_streams(tmp_path):
    """Elastic shrink striking mid-recovery: the removed worker's stream dies
    with it, but the surviving failed worker's partial stream (and its
    received chunks) persists across the rescale and the next recover()
    RESUMES it — no restart, no rollback."""
    import jax
    clu = _mk_cluster(tmp_path)
    clu.run(5)
    at_failure = [np.asarray(x).copy() for x in jax.tree.leaves(clu.state)]

    clu.inject_failure([0, 2], hardware=True)  # non-adjacent: backups survive
    r1 = clu.recover(FaultScript(hardware=True, interrupt_after_chunks=3))
    assert r1.kind == "interrupted" and r1.chunks_sent == 3

    # no spare capacity for worker 2: shrink it away mid-transfer; worker 0
    # keeps its partial recovery stream across the rescale
    assert clu.shrink([2]) == 3
    r2 = clu.recover(FaultScript(hardware=True))
    assert r2.kind == "hardware"
    assert r2.chunks_reused == 3               # partial chunks NOT re-sent
    assert r2.rolled_back_iterations == 0
    # the rebuilt state is bitwise the state at the failure iteration
    for x, y in zip(at_failure, jax.tree.leaves(clu.state)):
        np.testing.assert_array_equal(x, np.asarray(y))
    # training continues at dp=3
    losses = clu.run(3)
    assert all(np.isfinite(l) for l in losses)


def test_instant_ckpt_hidden_on_fast_link(tmp_path):
    """On the ICI-class default link the per-iteration shard drains inside
    the modeled iteration — the FCR condition, emergent from the transport."""
    clu = _mk_cluster(tmp_path)
    clu.run(4)
    assert clu.instant_hidden == 4
    assert clu.instant_exposed == 0
    assert clu.transport.chunks_delivered > 0
