"""Hypothesis property tests for compiled traffic plans: randomized
steady-state workloads (ragged sizes, same-instant ties, offsets,
zero-byte transfers, TRAIN/STATE mixes) replay identically compiled and
interpreted, to the repo's rtol=1e-12 discipline."""
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="hypothesis not installed — property tests skipped (declared in "
           "pyproject [dev]; tier-1 degrades gracefully without it)")
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.lccl import LinkTopology
from repro.core.plan import compile_traffic_plan


@settings(deadline=None, max_examples=40)
@given(data=st.data())
def test_compiled_equals_interpreted_on_random_patterns(data):
    bw = data.draw(st.sampled_from([1e5, 1e6, 4e6]), label="bw")
    quantum = data.draw(st.sampled_from([1e3, 1e4, 3e4]), label="quantum")
    period = 1.0
    subs = []
    for i in range(data.draw(st.integers(0, 5), label="n_subs")):
        kind = data.draw(st.sampled_from(["TRAIN", "STATE"]),
                         label=f"kind{i}")
        size = data.draw(st.sampled_from(
            [0.0, quantum / 2, float(quantum), 2.7 * quantum,
             bw * period / 12]), label=f"size{i}")
        off = data.draw(st.sampled_from([0.0, 0.1, 0.25, 0.4]),
                        label=f"off{i}")
        subs.append((kind, size, off))
    # max drain: 5 * (bw*period/12)/bw busy after the last 0.4 offset stays
    # inside the period, so every drawn pattern compiles
    topo = LinkTopology(4, bw, quantum=quantum)
    pattern = {e: tuple(subs) for e in topo.edges()}
    plan = compile_traffic_plan(topo, pattern, period)
    n = data.draw(st.integers(1, 5), label="n_steps")
    ref = LinkTopology(4, bw, quantum=quantum)
    for s in range(n):
        for e, es in pattern.items():
            for kind, size, off in es:
                ref.links[e].submit(kind, size, s * period + off)
        ref.run(until=(s + 1) * period)
    ref.drain()
    for e in pattern:
        got = np.sort(plan.finish_times(*e, n))
        want = np.sort([tr.t_finish for tr in ref.links[e].done])
        assert len(got) == len(want)
        np.testing.assert_allclose(got, want, rtol=1e-12)
