"""GPipe pipeline parallelism: correctness vs unpipelined forward
(subprocess, 4 virtual devices on the pipe axis)."""
import subprocess
import sys
import textwrap

import pytest

# a single ~4 s subprocess run since shard_map_compat fixed it on the 0.4.37
# floor — cheap enough for the fast CI job (no blanket `slow` skip)


def test_pipeline_matches_sequential():
    env_script = """
    import os
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.parallel.pipeline import bubble_fraction, pipeline_forward

    from repro.launch.mesh import make_mesh_compat
    mesh = make_mesh_compat((4,), ("pipe",))
    L, D, M, MB, S = 8, 16, 6, 2, 4
    rng = np.random.default_rng(0)
    params = {"w": jnp.asarray(rng.normal(size=(L, D, D)) * 0.3, jnp.float32)}
    x = jnp.asarray(rng.normal(size=(M, MB, S, D)), jnp.float32)

    def layer(p, h):
        return jnp.tanh(h @ p["w"])

    with mesh:
        out = jax.jit(lambda p, x: pipeline_forward(layer, p, x, mesh))(
            params, x)

    # sequential reference
    ref = x
    for i in range(L):
        ref = jnp.tanh(ref @ params["w"][i])
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)
    assert abs(bubble_fraction(4, 6) - 3 / 9) < 1e-9
    print("pipeline ok")
    """
    import os
    env = dict(os.environ)
    env.update({"XLA_FLAGS": "--xla_force_host_platform_device_count=4",
                "PYTHONPATH": "src", "JAX_PLATFORMS": "cpu"})
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(env_script)],
                       capture_output=True, text=True, timeout=560, env=env,
                       cwd=".")
    assert r.returncode == 0, f"{r.stdout}\n{r.stderr}"
    assert "pipeline ok" in r.stdout
