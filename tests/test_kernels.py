"""Pallas kernel validation: shape/dtype sweeps vs. pure-jnp oracles
(interpret mode on CPU). Deliverable (c)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops
from repro.kernels.ref import (decode_attention_ref, flash_attention_ref,
                               ssd_recurrent_ref, ssd_ref)


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("b,s,h,hd", [(1, 128, 2, 64), (2, 256, 4, 64),
                                      (2, 128, 4, 128), (1, 512, 8, 32)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_sweep(b, s, h, hd, dtype, causal, rng):
    q = jnp.asarray(rng.normal(size=(b, s, h, hd)), dtype)
    k = jnp.asarray(rng.normal(size=(b, s, h, hd)), dtype)
    v = jnp.asarray(rng.normal(size=(b, s, h, hd)), dtype)
    out = ops.flash_attention(q, k, v, causal=causal, bq=64, bk=64)
    ref = flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))


def test_flash_attention_grad(rng):
    b, s, h, hd = 1, 128, 2, 64
    q = jnp.asarray(rng.normal(size=(b, s, h, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, s, h, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, s, h, hd)), jnp.float32)
    g1 = jax.grad(lambda q: ops.flash_attention(
        q, k, v, causal=True, bq=64, bk=64).sum())(q)
    g2 = jax.grad(lambda q: flash_attention_ref(q, k, v, causal=True).sum())(q)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("b,t,h,kh,hd", [(2, 128, 4, 2, 64), (1, 256, 8, 1, 64),
                                         (2, 64, 4, 4, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("cur_len", [1, 63, 128])
def test_decode_attention_sweep(b, t, h, kh, hd, dtype, cur_len, rng):
    cur_len = min(cur_len, t)
    q = jnp.asarray(rng.normal(size=(b, 1, h, hd)), dtype)
    kc = jnp.asarray(rng.normal(size=(b, t, kh, hd)), dtype)
    vc = jnp.asarray(rng.normal(size=(b, t, kh, hd)), dtype)
    out = ops.decode_attention(q, kc, vc, jnp.asarray(cur_len), bt=32)
    ref = decode_attention_ref(q, kc, vc, jnp.asarray(cur_len), h)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))


@pytest.mark.parametrize("b,s,h,p,n,chunk", [
    (2, 64, 8, 16, 32, 16), (1, 128, 8, 32, 64, 32), (2, 48, 16, 16, 16, 16),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd_kernel_sweep(b, s, h, p, n, chunk, dtype, rng):
    x = jnp.asarray(rng.normal(size=(b, s, h, p)), dtype)
    dt = jnp.asarray(rng.uniform(0.001, 0.1, size=(b, s, h)), jnp.float32)
    a = -jnp.asarray(rng.uniform(0.5, 2.0, size=(h,)), jnp.float32)
    bm = jnp.asarray(rng.normal(size=(b, s, n)), dtype)
    cm = jnp.asarray(rng.normal(size=(b, s, n)), dtype)
    yk, sk = ops.ssd(x, dt, a, bm, cm, chunk=chunk, head_tile=4)
    yo, so = ssd_ref(x, dt, a, bm, cm, chunk=chunk)
    np.testing.assert_allclose(np.asarray(yk, np.float32),
                               np.asarray(yo, np.float32), **_tol(dtype))
    np.testing.assert_allclose(np.asarray(sk), np.asarray(so),
                               rtol=1e-3, atol=1e-3)


def test_ssd_chunked_matches_recurrence(rng):
    """The chunked algorithm (and hence the kernel) must match the O(S)
    token-by-token recurrence — the ground-truth SSM semantics."""
    b, s, h, p, n = 2, 96, 4, 16, 32
    x = jnp.asarray(rng.normal(size=(b, s, h, p)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.001, 0.1, size=(b, s, h)), jnp.float32)
    a = -jnp.asarray(rng.uniform(0.5, 2.0, size=(h,)), jnp.float32)
    bm = jnp.asarray(rng.normal(size=(b, s, n)), jnp.float32)
    cm = jnp.asarray(rng.normal(size=(b, s, n)), jnp.float32)
    yo, so = ssd_ref(x, dt, a, bm, cm, chunk=32)
    yr, sr = ssd_recurrent_ref(x, dt, a, bm, cm)
    np.testing.assert_allclose(np.asarray(yo), np.asarray(yr),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(so), np.asarray(sr),
                               rtol=1e-4, atol=1e-4)


def test_ssd_initial_state_threading(rng):
    """Splitting a sequence in two with state carry == one full pass."""
    b, s, h, p, n = 1, 64, 4, 16, 16
    x = jnp.asarray(rng.normal(size=(b, s, h, p)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.001, 0.1, size=(b, s, h)), jnp.float32)
    a = -jnp.asarray(rng.uniform(0.5, 2.0, size=(h,)), jnp.float32)
    bm = jnp.asarray(rng.normal(size=(b, s, n)), jnp.float32)
    cm = jnp.asarray(rng.normal(size=(b, s, n)), jnp.float32)
    y_full, s_full = ssd_ref(x, dt, a, bm, cm, chunk=16)
    half = s // 2
    y1, s1 = ssd_ref(x[:, :half], dt[:, :half], a, bm[:, :half],
                     cm[:, :half], chunk=16)
    y2, s2 = ssd_ref(x[:, half:], dt[:, half:], a, bm[:, half:],
                     cm[:, half:], chunk=16, initial_state=s1)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                               np.asarray(y_full), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(s2), np.asarray(s_full),
                               rtol=1e-4, atol=1e-4)
