"""Per-arch smoke tests: reduced config, one forward/train step on CPU,
output shapes + no NaNs (deliverable f)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytestmark = pytest.mark.slow    # one jit compile per arch, ~2 min total

from repro.configs import ASSIGNED, PAPER_WORKLOADS, get_arch, reduce_for_smoke
from repro.models import build_model

B, S = 2, 16


def _batch(cfg, rng, seq=S):
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, (B, seq + 1)).astype(np.int32))}
    if cfg.num_patch_tokens:
        batch["patch_embeds"] = jnp.asarray(
            rng.normal(size=(B, cfg.num_patch_tokens, cfg.d_model)),
            jnp.bfloat16)
    if cfg.encoder_layers:
        batch["frames"] = jnp.asarray(
            rng.normal(size=(B, cfg.encoder_seq, cfg.d_model)), jnp.bfloat16)
    return batch


@pytest.mark.parametrize("arch", ASSIGNED + PAPER_WORKLOADS)
def test_smoke_loss_and_grad(arch, rng):
    cfg = reduce_for_smoke(get_arch(arch))
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    batch = _batch(cfg, rng)
    (loss, metrics), grads = jax.value_and_grad(
        lambda p: model.loss(p, batch), has_aux=True)(params)
    assert np.isfinite(float(loss)), f"{arch}: loss not finite"
    gnorm = sum(float(jnp.sum(jnp.abs(g.astype(jnp.float32))))
                for g in jax.tree.leaves(grads))
    assert np.isfinite(gnorm) and gnorm > 0, f"{arch}: bad grads"


@pytest.mark.parametrize("arch", ASSIGNED)
def test_smoke_forward_shapes(arch, rng):
    cfg = reduce_for_smoke(get_arch(arch))
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    batch = _batch(cfg, rng)
    logits = model.forward(params, {**batch,
                                    "tokens": batch["tokens"][:, :-1]})
    s_total = S + (cfg.num_patch_tokens or 0)
    assert logits.shape == (B, s_total, cfg.padded_vocab)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))


@pytest.mark.parametrize("arch", ASSIGNED)
def test_prefill_decode_matches_forward(arch, rng):
    """Decode continuing a prefill must reproduce the full-forward logits
    (fp32, dropless MoE so capacity effects can't differ across contexts)."""
    cfg = dataclasses.replace(reduce_for_smoke(get_arch(arch)),
                              capacity_factor=8.0, dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    toks = rng.integers(0, cfg.vocab_size, (B, 12)).astype(np.int32)
    npatch = cfg.num_patch_tokens
    batch = {"tokens": jnp.asarray(toks)}
    if npatch:
        batch["patch_embeds"] = jax.random.normal(
            jax.random.key(1), (B, npatch, cfg.d_model), jnp.float32)
    if cfg.encoder_layers:
        batch["frames"] = jax.random.normal(
            jax.random.key(2), (B, cfg.encoder_seq, cfg.d_model), jnp.float32)
    full = model.forward(params, batch)
    pre = dict(batch)
    pre["tokens"] = jnp.asarray(toks[:, :-1])
    pre["max_len"] = 12 + npatch + 4
    _, cache = model.prefill(params, pre)
    logits, cache = model.decode_step(params, cache, jnp.asarray(toks[:, -1]))
    ref = np.asarray(full[:, -1], np.float32)
    np.testing.assert_allclose(np.asarray(logits, np.float32), ref,
                               rtol=2e-4, atol=2e-4)
    assert int(cache["index"]) == 12 + npatch


def test_param_counts_match_analytic():
    """Exact param accounting for a dense arch (validates eval_shape path)."""
    from repro.models import param_count
    cfg = get_arch("llama3-8b")
    n = param_count(cfg)
    d, f, l, v = cfg.d_model, cfg.d_ff, cfg.num_layers, cfg.padded_vocab
    hd, h, kh = cfg.resolved_head_dim, cfg.num_heads, cfg.num_kv_heads
    per_layer = (d * h * hd + 2 * d * kh * hd + h * hd * d  # attn
                 + 3 * d * f                                 # swiglu
                 + 2 * d)                                    # norms
    expected = 2 * v * d + l * per_layer + d
    assert n == expected
