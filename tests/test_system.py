"""End-to-end system tests (deliverable c): the full stack through the public
API — examples must run, the CLI must train, benchmarks must emit CSV."""
import subprocess
import sys

import pytest

pytestmark = pytest.mark.slow    # subprocess end-to-end runs, minutes each


def _run(cmd, timeout=560):
    import os
    env = dict(os.environ)
    env["PYTHONPATH"] = "src:."
    env.setdefault("JAX_PLATFORMS", "cpu")
    r = subprocess.run(cmd, capture_output=True, text=True, timeout=timeout,
                       env=env, cwd=".")
    assert r.returncode == 0, f"cmd={cmd}\nstdout:\n{r.stdout[-3000:]}\nstderr:\n{r.stderr[-3000:]}"
    return r.stdout


def test_quickstart_example():
    out = _run([sys.executable, "examples/quickstart.py"])
    assert "recovered from neighbor" in out
    assert "rollback = 0 iterations" in out


def test_train_cli_with_failover():
    out = _run([sys.executable, "-m", "repro.launch.train",
                "--arch", "gemma-2b", "--steps", "8",
                "--inject-failure", "4"])
    assert "recovered from neighbor" in out
    assert "done:" in out


def test_serve_cli():
    out = _run([sys.executable, "-m", "repro.launch.serve",
                "--arch", "mamba2-2.7b", "--batch", "2",
                "--prompt-len", "8", "--gen", "6"])
    assert "decoded" in out


def test_elastic_example():
    out = _run([sys.executable, "examples/elastic_rescale.py"])
    assert "exact-cover data partition preserved" in out
