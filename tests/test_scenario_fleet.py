"""The adversarial scenario fleet, replayed with pinned verdicts.

Every scenario in `repro.runtime.scenarios.corpus()` runs end to end on the
sim clock and its `Verdict` must equal the pinned dict below FIELD FOR
FIELD — rollback count, measured detection latency, exposed seconds,
straggler migrations, gray-link quarantines, adapted cadence, bytes
streamed. The fleet is the regression surface for the self-driving
reliability loop: any change to detection cadence, routing, stream
chunking, or recovery policy semantics shows up as a verdict diff here.

Structural guarantees asserted across the whole corpus:
  * zero rollbacks wherever FCR predicts checkpoint-free recovery
    (software failures and non-adjacent/storm losses with surviving
    backups; adjacent double HARDWARE failure under ComputeRecovery);
  * measured detection latency within one heartbeat period of the
    analytic `DetectionTimeline.detection_time()` worst case;
  * bit-identical verdicts across replays (the S1 wall-clock-heartbeat
    regression: nothing in the loop reads `time.monotonic()`).

The hypothesis sweep generates random software-failure/straggler/gray-link
scenarios (`random_scenario`) and checks the invariants on each; set
``SCENARIO_FLEET_FULL=1`` (the main-branch CI lane) for a deeper sweep.
"""
import os

import pytest

from repro.runtime.scenarios import corpus, random_scenario, run_scenario

# dp=8 scenarios build twice the workers; keep the every-PR subset snappy
_SLOW = {"multi_wave_storm", "gateway_oversubscription",
         "gateway_oversubscription_no_detour",
         "cross_pod_k3_stripe", "cross_pod_k3_rebalance"}

# ---- the pinned fleet verdicts (regenerate by running the scenario and
# reading Verdict.pinned(); every field is deterministic in sim time) ----
VERDICTS = {
    "clean_software_failure": {
        "steps_completed": 10,
        "final_iteration": 10,
        "recoveries": 1,
        "rollbacks": 0,
        "rolled_back_iterations": 0,
        "interrupted": 0,
        "detection_latency_s": 0.36,
        "detections": 1,
        "exposed_seconds": 0.0,
        "mitigations": 0,
        "gray_quarantined": 0,
        "gray_tolerated": 0,
        "final_full_every": None,
        "state_bytes_streamed": 271488.0,
        "chunks_reused": 0,
        "recovery_total_s": 1.364,
        "stream_seconds": 5.43e-06,
        "rebalances": 0,
        "chunks_rebalanced": 0,
    },
    "recovery_race_concurrent": {
        "steps_completed": 10,
        "final_iteration": 10,
        "recoveries": 1,
        "rollbacks": 0,
        "rolled_back_iterations": 0,
        "interrupted": 0,
        "detection_latency_s": 0.36,
        "detections": 1,
        "exposed_seconds": 0.0,
        "mitigations": 0,
        "gray_quarantined": 0,
        "gray_tolerated": 0,
        "final_full_every": None,
        "state_bytes_streamed": 542976.0,
        "chunks_reused": 0,
        "recovery_total_s": 1.364,
        "stream_seconds": 5.43e-06,
        "rebalances": 0,
        "chunks_rebalanced": 0,
    },
    "multi_wave_storm": {
        "steps_completed": 12,
        "final_iteration": 12,
        "recoveries": 2,
        "rollbacks": 0,
        "rolled_back_iterations": 0,
        "interrupted": 0,
        "detection_latency_s": 0.259970136,
        "detections": 2,
        "exposed_seconds": 0.0,
        "mitigations": 0,
        "gray_quarantined": 0,
        "gray_tolerated": 0,
        "final_full_every": 6,
        "state_bytes_streamed": 1085952.0,
        "chunks_reused": 0,
        "recovery_total_s": 2.685970136,
        "stream_seconds": 5.9727e-05,
        "rebalances": 0,
        "chunks_rebalanced": 0,
    },
    "lazy_backup_pressure": {
        "steps_completed": 10,
        "final_iteration": 10,
        "recoveries": 1,
        "rollbacks": 0,
        "rolled_back_iterations": 0,
        "interrupted": 0,
        "detection_latency_s": 0.31,
        "detections": 1,
        "exposed_seconds": 0.0,
        "mitigations": 0,
        "gray_quarantined": 0,
        "gray_tolerated": 0,
        "final_full_every": None,
        "state_bytes_streamed": 271488.0,
        "chunks_reused": 0,
        "recovery_total_s": 1.314,
        "stream_seconds": 0.00135744,
        "rebalances": 0,
        "chunks_rebalanced": 0,
    },
    "gateway_oversubscription": {
        "steps_completed": 12,
        "final_iteration": 12,
        "recoveries": 0,
        "rollbacks": 0,
        "rolled_back_iterations": 0,
        "interrupted": 0,
        "detection_latency_s": None,
        "detections": 0,
        "exposed_seconds": 0.0,
        "mitigations": 0,
        "gray_quarantined": 1,
        "gray_tolerated": 0,
        "final_full_every": None,
        "state_bytes_streamed": 0.0,
        "chunks_reused": 0,
        "recovery_total_s": 0.0,
        "stream_seconds": 0.0,
        "rebalances": 0,
        "chunks_rebalanced": 0,
    },
    "gateway_oversubscription_no_detour": {
        "steps_completed": 10,
        "final_iteration": 10,
        "recoveries": 0,
        "rollbacks": 0,
        "rolled_back_iterations": 0,
        "interrupted": 0,
        "detection_latency_s": None,
        "detections": 0,
        "exposed_seconds": 0.0,
        "mitigations": 0,
        "gray_quarantined": 0,
        "gray_tolerated": 1,
        "final_full_every": None,
        "state_bytes_streamed": 0.0,
        "chunks_reused": 0,
        "recovery_total_s": 0.0,
        "stream_seconds": 0.0,
        "rebalances": 0,
        "chunks_rebalanced": 0,
    },
    "mid_transfer_degradation": {
        "steps_completed": 10,
        "final_iteration": 10,
        "recoveries": 1,
        "rollbacks": 0,
        "rolled_back_iterations": 0,
        "interrupted": 1,
        "detection_latency_s": 0.36,
        "detections": 1,
        "exposed_seconds": 0.05,
        "mitigations": 0,
        "gray_quarantined": 1,
        "gray_tolerated": 0,
        "final_full_every": None,
        "state_bytes_streamed": 238720.0,
        "chunks_reused": 2,
        "recovery_total_s": 1.364,
        "stream_seconds": 0.0011936,
        "rebalances": 1,
        "chunks_rebalanced": 7,
    },
    "mid_transfer_degradation_static": {
        "steps_completed": 10,
        "final_iteration": 10,
        "recoveries": 1,
        "rollbacks": 0,
        "rolled_back_iterations": 0,
        "interrupted": 1,
        "detection_latency_s": 0.36,
        "detections": 1,
        "exposed_seconds": 0.05,
        "mitigations": 0,
        "gray_quarantined": 1,
        "gray_tolerated": 0,
        "final_full_every": None,
        "state_bytes_streamed": 238720.0,
        "chunks_reused": 2,
        "recovery_total_s": 1.364,
        "stream_seconds": 0.00540672,
        "rebalances": 0,
        "chunks_rebalanced": 0,
    },
    "cross_pod_k3_stripe": {
        "steps_completed": 10,
        "final_iteration": 10,
        "recoveries": 1,
        "rollbacks": 0,
        "rolled_back_iterations": 0,
        "interrupted": 0,
        "detection_latency_s": 0.36,
        "detections": 1,
        "exposed_seconds": 0.0,
        "mitigations": 0,
        "gray_quarantined": 0,
        "gray_tolerated": 0,
        "final_full_every": None,
        "state_bytes_streamed": 135744.0,
        "chunks_reused": 0,
        "recovery_total_s": 1.368,
        "stream_seconds": 0.000491848,
        "rebalances": 0,
        "chunks_rebalanced": 0,
    },
    "cross_pod_k3_rebalance": {
        "steps_completed": 10,
        "final_iteration": 10,
        "recoveries": 1,
        "rollbacks": 0,
        "rolled_back_iterations": 0,
        "interrupted": 0,
        "detection_latency_s": 0.36,
        "detections": 1,
        "exposed_seconds": 0.0,
        "mitigations": 0,
        "gray_quarantined": 1,
        "gray_tolerated": 0,
        "final_full_every": None,
        "state_bytes_streamed": 135744.0,
        "chunks_reused": 0,
        "recovery_total_s": 1.368,
        "stream_seconds": 0.000655688,
        "rebalances": 1,
        "chunks_rebalanced": 2,
    },
    "persistent_straggler": {
        "steps_completed": 12,
        "final_iteration": 12,
        "recoveries": 0,
        "rollbacks": 0,
        "rolled_back_iterations": 0,
        "interrupted": 0,
        "detection_latency_s": None,
        "detections": 0,
        "exposed_seconds": 0.0,
        "mitigations": 1,
        "gray_quarantined": 0,
        "gray_tolerated": 0,
        "final_full_every": None,
        "state_bytes_streamed": 0.0,
        "chunks_reused": 0,
        "recovery_total_s": 0.0,
        "stream_seconds": 0.0,
        "rebalances": 0,
        "chunks_rebalanced": 0,
    },
    "gray_link_degradation": {
        "steps_completed": 10,
        "final_iteration": 10,
        "recoveries": 0,
        "rollbacks": 0,
        "rolled_back_iterations": 0,
        "interrupted": 0,
        "detection_latency_s": None,
        "detections": 0,
        "exposed_seconds": 0.0,
        "mitigations": 0,
        "gray_quarantined": 1,
        "gray_tolerated": 0,
        "final_full_every": None,
        "state_bytes_streamed": 0.0,
        "chunks_reused": 0,
        "recovery_total_s": 0.0,
        "stream_seconds": 0.0,
        "rebalances": 0,
        "chunks_rebalanced": 0,
    },
    "adaptive_cadence": {
        "steps_completed": 14,
        "final_iteration": 14,
        "recoveries": 2,
        "rollbacks": 0,
        "rolled_back_iterations": 0,
        "interrupted": 0,
        "detection_latency_s": 0.35999457,
        "detections": 2,
        "exposed_seconds": 0.0,
        "mitigations": 0,
        "gray_quarantined": 0,
        "gray_tolerated": 0,
        "final_full_every": 7,
        "state_bytes_streamed": 542976.0,
        "chunks_reused": 0,
        "recovery_total_s": 2.77799457,
        "stream_seconds": 1.086e-05,
        "rebalances": 0,
        "chunks_rebalanced": 0,
    },
    "hardware_double_stream_rollback": {
        "steps_completed": 10,
        "final_iteration": 7,
        "recoveries": 1,
        "rollbacks": 1,
        "rolled_back_iterations": 3,
        "interrupted": 0,
        "detection_latency_s": 0.26,
        "detections": 1,
        "exposed_seconds": 0.0,
        "mitigations": 0,
        "gray_quarantined": 0,
        "gray_tolerated": 0,
        "final_full_every": None,
        "state_bytes_streamed": 0.0,
        "chunks_reused": 0,
        "recovery_total_s": 8.26144794,
        "stream_seconds": 0.0,
        "rebalances": 0,
        "chunks_rebalanced": 0,
    },
    "hardware_double_compute_free": {
        "steps_completed": 10,
        "final_iteration": 10,
        "recoveries": 1,
        "rollbacks": 0,
        "rolled_back_iterations": 0,
        "interrupted": 0,
        "detection_latency_s": 0.26,
        "detections": 1,
        "exposed_seconds": 0.0,
        "mitigations": 0,
        "gray_quarantined": 0,
        "gray_tolerated": 0,
        "final_full_every": None,
        "state_bytes_streamed": 0.0,
        "chunks_reused": 0,
        "recovery_total_s": 7.76016968,
        "stream_seconds": 0.0,
        "rebalances": 0,
        "chunks_rebalanced": 0,
    },
}

_CORPUS = {sc.name: sc for sc in corpus()}


def _assert_verdict(got: dict, want: dict, name: str) -> None:
    got = {k: v for k, v in got.items() if k != "name"}
    assert set(got) == set(want), f"{name}: verdict fields drifted"
    for k, w in want.items():
        g = got[k]
        if isinstance(w, float):
            assert g == pytest.approx(w, abs=1e-6), f"{name}.{k}: {g} != {w}"
        else:
            assert g == w, f"{name}.{k}: {g} != {w}"


def test_corpus_and_pins_cover_each_other():
    assert set(_CORPUS) == set(VERDICTS)


def test_rebalanced_stream_beats_static_baseline():
    """The k-path acceptance pin, read across two pinned verdicts: the
    re-balanced mid-transfer-degradation stream finishes strictly faster
    than its static-2-path twin, moves actual chunks between paths, and
    delivers exactly the same bytes (zero duplicate sends). The pins
    themselves are enforced against live runs in
    test_scenario_verdict_pinned, so these are assertions about measured
    behavior, not about constants."""
    reb = VERDICTS["mid_transfer_degradation"]
    sta = VERDICTS["mid_transfer_degradation_static"]
    assert reb["stream_seconds"] < sta["stream_seconds"]
    assert reb["rebalances"] >= 1 and reb["chunks_rebalanced"] >= 1
    assert sta["rebalances"] == 0 and sta["chunks_rebalanced"] == 0
    assert reb["state_bytes_streamed"] == sta["state_bytes_streamed"]
    # the k=3 cross-pod stripe re-balances too, without duplicate bytes
    k3r, k3s = VERDICTS["cross_pod_k3_rebalance"], \
        VERDICTS["cross_pod_k3_stripe"]
    assert k3r["rebalances"] >= 1
    assert k3r["state_bytes_streamed"] == k3s["state_bytes_streamed"]


def _params():
    return [pytest.param(n, marks=pytest.mark.slow) if n in _SLOW
            else pytest.param(n) for n in VERDICTS]


@pytest.mark.parametrize("name", _params())
def test_scenario_verdict_pinned(name, tmp_path):
    sc = _CORPUS[name]
    v = run_scenario(sc, ckpt_dir=tmp_path)
    _assert_verdict(v.pinned(), VERDICTS[name], name)

    # FCR's promise, asserted structurally (not just via the pin): any
    # scenario without a hardware double-failure under the stream policy
    # must recover with ZERO rollback
    if name != "hardware_double_stream_rollback":
        assert v.rollbacks == 0 and v.rolled_back_iterations == 0

    # measured detection latency validates against the closed form within
    # one heartbeat period (the acceptance bound): the loop detects in
    # (timeout + notify, timeout + scan + notify], the analytic constant
    # is the worst case
    if v.detection_latency_s is not None:
        analytic = sc.reliability.heartbeat_period + \
            sc.reliability.scan_period + sc.reliability.notify_latency
        assert abs(v.detection_latency_s - analytic) <= \
            sc.reliability.heartbeat_period + 1e-9
        assert v.detection_latency_s > 0


def test_detection_latency_deterministic_across_replays(tmp_path):
    """The S1 regression: heartbeats used to mix `time.monotonic()` into
    the sim clock, so detection latency varied run to run. Two replays of
    the same scenario must now agree bit for bit."""
    sc = _CORPUS["clean_software_failure"]
    a = run_scenario(sc, ckpt_dir=tmp_path / "a").pinned()
    b = run_scenario(sc, ckpt_dir=tmp_path / "b").pinned()
    assert a == b
    assert a["detection_latency_s"] == b["detection_latency_s"]


# --------------------------------------------------------------------------- #
# hypothesis-randomized scenario generation
# --------------------------------------------------------------------------- #
try:
    import hypothesis  # noqa: F401
    _HAVE_HYPOTHESIS = True
except ImportError:
    _HAVE_HYPOTHESIS = False

_FULL = os.environ.get("SCENARIO_FLEET_FULL", "") not in ("", "0")

if _HAVE_HYPOTHESIS:
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st

    @pytest.mark.slow
    @given(st.integers(0, 10_000))
    @settings(max_examples=8 if _FULL else 2, deadline=None,
              suppress_health_check=[HealthCheck.too_slow],
              derandomize=not _FULL)
    def test_random_scenarios_hold_fleet_invariants(seed):
        """Seeded random gray-failure scenarios (software failures,
        stragglers, degraded links only): every recovery must be
        rollback-free, detection on-bound, and the run must complete."""
        sc = random_scenario(seed)
        v = run_scenario(sc, ckpt_dir=f"/tmp/repro_scen_rand/{seed}")
        assert v.steps_completed == sc.steps
        assert v.rollbacks == 0 and v.rolled_back_iterations == 0
        n_fails = sum(1 for e in sc.events if e.action == "fail")
        assert v.recoveries == n_fails
        if v.detection_latency_s is not None:
            analytic = (sc.reliability.heartbeat_period
                        + sc.reliability.scan_period
                        + sc.reliability.notify_latency)
            assert 0 < v.detection_latency_s <= analytic + \
                sc.reliability.heartbeat_period + 1e-9
