"""K-path striped recovery streams with mid-transfer re-balancing
(ISSUE 10).

Pins, in order: the `dcn_uplinks` fabric surface (default bit-identical
to the legacy single-gateway fabric), k edge-disjoint path discovery,
k=4 beating k=2 on an idle cross-pod leg and matching the
`estimate_stream_seconds` closed form, the typed `RoutingError` context,
mid-transfer re-balancing beating the static stripe with zero duplicate
delivered bytes (and without bumping the topology epoch), and the NACK
retransmit riding the current least-loaded live path of its route set.
"""
import numpy as np
import pytest

from repro.ckpt.stream import (ChunkedStream, StreamAssembler,
                               TopologyTransport)
from repro.core.lccl import LinkTopology, PodFabric, RoutingError
from repro.runtime.failover import schedule_state_phase
from repro.runtime.recovery import StreamRecovery, estimate_stream_seconds


def _stream(nbytes, quantum=1 << 16, sid="t/kpath"):
    arr = np.zeros(int(nbytes) // 4, np.float32)
    return ChunkedStream.from_pytree(sid, {"shard": arr}, quantum=quantum)


def _send(tp, nbytes, src, dst, t=0.0, quantum=1 << 16, k=None,
          sid="t/kpath"):
    s = _stream(nbytes, quantum, sid)
    asm = StreamAssembler.for_stream(s)
    tk = tp.send(s, t, assembler=asm, src=src, dst=dst, policy="split", k=k)
    return tk, asm


# --------------------------------------------------------------------------- #
# fabric surface
# --------------------------------------------------------------------------- #
def test_dcn_uplinks_default_is_bit_identical_to_legacy_fabric():
    a = PodFabric(3, 4, 50e9, 5e9)
    b = PodFabric(3, 4, 50e9, 5e9, dcn_uplinks=1)
    assert set(a.links) == set(b.links)
    dcn = sorted(e for e in a.links if a.tier(*e) == "dcn")
    assert dcn == [(0, 4), (0, 8), (4, 8)]


def test_uplink_positions_and_per_uplink_rings():
    fab = PodFabric(4, 4, 50e9, 5e9, dcn_uplinks=2)
    assert [fab.uplink(p, 0) for p in range(4)] == [0, 4, 8, 12]
    assert [fab.uplink(p, 1) for p in range(4)] == [2, 6, 10, 14]
    assert fab.uplink(1) == fab.gateway(1) == 4   # uplink 0 is the gateway
    dcn = {e for e in fab.links if fab.tier(*e) == "dcn"}
    assert dcn == {(0, 4), (4, 8), (8, 12), (0, 12),
                   (2, 6), (6, 10), (10, 14), (2, 14)}


def test_four_edge_disjoint_cross_pod_paths():
    fab = PodFabric(4, 4, 50e9, 5e9, dcn_uplinks=2)
    paths = fab.disjoint_paths(fab.gateway(0), fab.gateway(2), k=4)
    assert len(paths) == 4
    used = [e for p in paths for e in p]
    assert len(used) == len(set(used)), "paths share an edge"


# --------------------------------------------------------------------------- #
# k=4 vs k=2 on an idle 4-pod fabric, validated against the closed form
# --------------------------------------------------------------------------- #
def test_k4_beats_k2_and_matches_closed_form():
    nbytes = 64 << 20            # large enough to amortize pipeline fill
    finishes = {}
    for k in (2, 4):
        fab = PodFabric(4, 4, 50e9, 5e9, quantum=1 << 16, dcn_uplinks=2)
        tp = TopologyTransport(fab, route_k=k)
        tk, asm = _send(tp, nbytes, 0, 8, quantum=1 << 16)
        tp.drain()
        assert asm.complete
        finishes[k] = tk.finish_time
        est = estimate_stream_seconds(fab, 0, 8, nbytes, k=k)
        assert finishes[k] == pytest.approx(est, rel=0.05)
    assert finishes[4] < finishes[2]
    # the DCN bottleneck doubles: 4 disjoint 5 GB/s routes vs 2
    assert finishes[2] / finishes[4] == pytest.approx(2.0, rel=0.05)


def test_ring_k2_default_matches_explicit_bidirectional_split():
    """route_k=2 on a plain ring reproduces the historical bidirectional
    split: the transport's default routing lands at the same instant as
    an explicit 2-path `schedule_state_phase` over `disjoint_paths`."""
    nbytes = 4 << 20
    topo = LinkTopology(4, 50e9, quantum=1 << 16)
    tp = TopologyTransport(topo)          # default route_k=2
    tk, asm = _send(tp, nbytes, 0, 1, quantum=1 << 16)
    tp.drain()
    assert asm.complete
    ref = LinkTopology(4, 50e9, quantum=1 << 16)
    t_ref = schedule_state_phase(nbytes, 50e9, quantum=1 << 16,
                                 topology=ref,
                                 paths=ref.disjoint_paths(0, 1))
    assert tk.finish_time == pytest.approx(t_ref, rel=1e-9)


# --------------------------------------------------------------------------- #
# typed RoutingError (satellite 1)
# --------------------------------------------------------------------------- #
def test_routing_error_carries_src_dst_and_dark_sets():
    topo = LinkTopology(4, 50e9)
    topo.fail_node(0)
    topo.fail_node(2)                     # 1 and 3 are now disconnected
    with pytest.raises(RoutingError) as ei:
        topo.path(1, 3)
    err = ei.value
    assert isinstance(err, RuntimeError)  # back-compat for bare excepts
    assert err.src == 1 and err.dst == 3
    assert set(err.dark_nodes) == {0, 2}


def test_split_bytes_empty_paths_raises_routing_error():
    topo = LinkTopology(4, 50e9)
    with pytest.raises(RoutingError):
        topo.split_bytes([], 1e6)


def test_transport_routes_raises_routing_error_with_context():
    topo = LinkTopology(4, 50e9)
    topo.fail_node(0)
    topo.fail_node(2)
    tp = TopologyTransport(topo)
    with pytest.raises(RoutingError) as ei:
        tp.routes(1, 3, 1e6)
    assert ei.value.src == 1 and ei.value.dst == 3


def test_routing_error_is_public_api():
    import repro
    assert repro.RoutingError is RoutingError


# --------------------------------------------------------------------------- #
# mid-transfer re-balancing
# --------------------------------------------------------------------------- #
def _degraded_run(auto_rebalance):
    fab = PodFabric(4, 4, 50e9, 5e9, quantum=1 << 16, dcn_uplinks=2)
    tp = TopologyTransport(fab, route_k=4, auto_rebalance=auto_rebalance)
    tk, asm = _send(tp, 4 << 20, 0, 8, quantum=1 << 16)
    tp.run(until=0.0001)                 # mid-flight: ~half the bytes moved
    fab.set_bandwidth(0, 4, 1e7)         # one DCN route browns out to 0.2%
    epoch_after_degrade = fab.epoch
    tp.drain()
    assert asm.complete
    return tk, tp, fab, epoch_after_degrade


def test_rebalance_beats_static_with_zero_duplicate_bytes():
    tk_reb, tp_reb, _, _ = _degraded_run(auto_rebalance=True)
    tk_sta, tp_sta, _, _ = _degraded_run(auto_rebalance=False)
    assert tk_reb.finish_time < tk_sta.finish_time
    assert tp_reb.rebalances >= 1 and tp_reb.chunks_rebalanced >= 1
    assert tp_sta.rebalances == 0 and tp_sta.chunks_rebalanced == 0
    # byte conservation: both deliver exactly the stream, nothing twice
    assert tp_reb.accounting()["state_bytes"] == \
        tp_sta.accounting()["state_bytes"] == float(4 << 20)


def test_rebalance_does_not_bump_topology_epoch():
    """Compiled `TrafficPlan`s are invalidated by the topology epoch; a
    re-balance re-routes only its own pending chunks, so it must NOT look
    like a topology mutation."""
    _, tp, fab, epoch_after_degrade = _degraded_run(auto_rebalance=True)
    assert tp.rebalances >= 1
    assert fab.epoch == epoch_after_degrade


def test_auto_rebalance_idle_fabric_is_a_noop():
    """`drain()` checks the topology epoch before pumping; with no fabric
    mutation the stripes are left exactly as first laid out."""
    fab = PodFabric(4, 4, 50e9, 5e9, quantum=1 << 16, dcn_uplinks=2)
    tp = TopologyTransport(fab, route_k=4)
    _, asm = _send(tp, 4 << 20, 0, 8, quantum=1 << 16)
    tp.drain()
    assert asm.complete
    assert tp.rebalances == 0 and tp.chunks_rebalanced == 0


def test_forced_rebalance_on_healthy_fabric_conserves_bytes():
    """An explicit `rebalance()` is a forced re-stripe — even with nothing
    degraded it re-runs the split, and the stream still lands exactly."""
    fab = PodFabric(4, 4, 50e9, 5e9, quantum=1 << 16, dcn_uplinks=2)
    tp = TopologyTransport(fab, route_k=4)
    _, asm = _send(tp, 4 << 20, 0, 8, quantum=1 << 16)
    assert tp.rebalance() > 0
    tp.drain()
    assert asm.complete
    assert tp.accounting()["state_bytes"] == float(4 << 20)


# --------------------------------------------------------------------------- #
# NACK retransmits re-route (satellite 6)
# --------------------------------------------------------------------------- #
def test_nack_resend_rides_current_least_loaded_live_path():
    from repro.core.lccl import submit_chunked_path
    topo = LinkTopology(4, 50e9, quantum=1 << 14)
    tp = TopologyTransport(topo, route_k=2, auto_rebalance=False)
    _send(tp, 1 << 18, 0, 1, quantum=1 << 14)
    st = tp._stripes[0]
    direct, detour = sorted(st.paths, key=len)
    assert direct == [(0, 1)] and len(detour) == 3
    # bury the direct edge under a fresh STATE backlog: a retransmit
    # issued NOW must pick the 3-hop detour, not the original short path
    submit_chunked_path(topo, "STATE", 1e9, 0.0, direct, 1 << 20)
    assert tp._retransmit_path(st, tuple(direct)) == detour


def test_nack_resend_falls_back_to_fresh_disjoint_query():
    """When every striped path has a dead edge but the destination is
    still reachable, the resend re-routes via a fresh disjoint-paths
    query instead of pinning to the original (now dark) path."""
    fab = PodFabric(4, 4, 50e9, 5e9, quantum=1 << 16, dcn_uplinks=2)
    tp = TopologyTransport(fab, route_k=2, auto_rebalance=False)
    _send(tp, 1 << 20, 0, 8, quantum=1 << 16)
    st = tp._stripes[0]
    dead = set()
    for p in st.paths:                   # kill one DCN hop on each stripe
        u, v = next(e for e in p if fab.tier(*e) == "dcn")
        fab.fail_edge(u, v)
        dead.add((u, v))
    original = tuple(st.paths[0])
    rerouted = tp._retransmit_path(st, original)
    assert rerouted and not (set(rerouted) & dead)
    assert all(fab.edge_up(*e) for e in rerouted)


def test_corrupted_striped_stream_heals_end_to_end():
    fab = PodFabric(4, 4, 50e9, 5e9, quantum=1 << 16, dcn_uplinks=2)
    tp = TopologyTransport(fab, route_k=4)
    s = _stream(4 << 20, 1 << 16, "t/kpath_nack")
    asm = StreamAssembler.for_stream(s)
    tp.corrupt_once(s.stream_id, 0)
    tp.corrupt_once(s.stream_id, 7)
    tp.send(s, 0.0, assembler=asm, src=0, dst=8, policy="split")
    tp.drain()
    assert asm.complete and tp.nacks_sent == 2


# --------------------------------------------------------------------------- #
# policy threading
# --------------------------------------------------------------------------- #
def test_stream_recovery_route_k_overrides_transport_default():
    class _T:                 # minimal cluster stand-in
        route_k = 2
    class _C:
        transport = _T()
    assert StreamRecovery()._effective_k(_C()) == 2
    assert StreamRecovery(route_k=4)._effective_k(_C()) == 4


def test_estimate_stream_seconds_scales_with_k():
    fab = PodFabric(4, 4, 50e9, 5e9, dcn_uplinks=2)
    e2 = estimate_stream_seconds(fab, 0, 8, 64 << 20, k=2)
    e4 = estimate_stream_seconds(fab, 0, 8, 64 << 20, k=4)
    assert e4 == pytest.approx(e2 / 2, rel=1e-6)
