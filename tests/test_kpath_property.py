"""Property tests for the k-path split and mid-transfer re-balancing
(ISSUE 10, satellite).

Two layers share the same invariant checkers:

* `hypothesis` variants explore randomized fabrics when the library is
  installed (``pytest.importorskip`` keeps checkouts without it green);
* seeded `numpy` sweeps run the identical checks everywhere, so the
  invariants are exercised even where hypothesis is absent.

Invariants: `split_bytes` shares are non-negative and sum exactly to the
request; water-filling makes every active path land at the same finish
instant; a mid-transfer re-balance conserves bytes — delivered chunks are
never re-sent and the assembler sees each byte exactly once.
"""
import numpy as np
import pytest

from repro.ckpt.stream import (ChunkedStream, StreamAssembler,
                               TopologyTransport)
from repro.core.lccl import LinkTopology, PodFabric

try:                                    # container may not ship hypothesis
    from hypothesis import given, settings
    from hypothesis import strategies as st_
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


# --------------------------------------------------------------------------- #
# invariant checkers (shared by both layers)
# --------------------------------------------------------------------------- #
def _random_fabric(rng):
    n_pods = int(rng.integers(2, 5))
    pod_size = int(rng.integers(2, 5))
    uplinks = int(rng.integers(1, pod_size + 1))
    ici_bw = float(rng.uniform(1e9, 80e9))
    dcn_bw = float(rng.uniform(1e8, 10e9))
    return PodFabric(n_pods, pod_size, ici_bw, dcn_bw,
                     quantum=1 << 16, dcn_uplinks=uplinks)


def check_shares_sum_exactly(fab, src, dst, nbytes, k):
    paths = fab.disjoint_paths(src, dst, k=k)
    if not paths:
        return
    shares = fab.split_bytes(paths, nbytes)
    assert len(shares) == len(paths)
    assert all(s >= 0.0 for s in shares)
    assert sum(shares) == pytest.approx(nbytes, abs=1e-6)


def check_active_paths_finish_together(fab, src, dst, nbytes, k):
    """Water-filling invariant on an IDLE fabric: every path given a
    non-zero share lands at the same instant (share/rate + latency)."""
    paths = [p for p in fab.disjoint_paths(src, dst, k=k) if p]
    if len(paths) < 2:
        return
    shares = fab.split_bytes(paths, nbytes)
    finishes = []
    for p, s in zip(paths, shares):
        if s <= 0.0:
            continue
        rate = min(fab.edge(*e).bw for e in p)
        lat = sum(fab.edge(*e).latency for e in p)
        finishes.append(s / rate + lat)
    if len(finishes) >= 2:
        assert max(finishes) == pytest.approx(min(finishes), rel=1e-6,
                                              abs=1e-12)


def check_rebalance_conserves_bytes(fab, src, dst, nbytes, k, cut_frac):
    """Degrade one striped path mid-flight; the re-balance must deliver
    every byte exactly once (accounting == nbytes, assembly complete)."""
    tp = TopologyTransport(fab, route_k=k, auto_rebalance=True)
    arr = np.zeros(max(int(nbytes) // 4, 1), np.float32)
    stream = ChunkedStream.from_pytree("prop/rebalance", {"shard": arr},
                                       quantum=1 << 16)
    asm = StreamAssembler.for_stream(stream)
    tp.send(stream, 0.0, assembler=asm, src=src, dst=dst, policy="split")
    if not tp._stripes:                 # degenerate (src==dst etc.)
        tp.drain()
        return
    st = tp._stripes[0]
    # run to a fraction of the nominal duration, then brown out the first
    # edge of the first striped path
    total = float(stream.total_bytes)
    rate = sum(min(fab.edge(*e).bw for e in p) for p in st.paths if p)
    tp.run(until=cut_frac * total / max(rate, 1.0))
    u, v = st.paths[0][0]
    fab.set_bandwidth(u, v, fab.edge(u, v).bw * 0.05)
    tp.drain()
    assert asm.complete
    assert tp.accounting()["state_bytes"] == pytest.approx(total)


# --------------------------------------------------------------------------- #
# seeded sweeps — run everywhere, deterministic under PYTHONHASHSEED
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("seed", range(12))
def test_split_shares_sum_exactly_seeded(seed):
    rng = np.random.default_rng(1000 + seed)
    fab = _random_fabric(rng)
    src, dst = rng.choice(fab.n, size=2, replace=False)
    nbytes = float(rng.integers(1 << 12, 1 << 26))
    check_shares_sum_exactly(fab, int(src), int(dst), nbytes,
                             k=int(rng.integers(1, 7)))


@pytest.mark.parametrize("seed", range(12))
def test_active_paths_finish_together_seeded(seed):
    rng = np.random.default_rng(2000 + seed)
    fab = _random_fabric(rng)
    src, dst = rng.choice(fab.n, size=2, replace=False)
    nbytes = float(rng.integers(1 << 16, 1 << 26))
    check_active_paths_finish_together(fab, int(src), int(dst), nbytes,
                                       k=int(rng.integers(2, 7)))


@pytest.mark.parametrize("seed", range(8))
def test_rebalance_conserves_bytes_seeded(seed):
    rng = np.random.default_rng(3000 + seed)
    fab = _random_fabric(rng)
    gw_src = fab.gateway(0)
    gw_dst = fab.gateway(fab.n_pods - 1)
    nbytes = float(rng.integers(1 << 18, 1 << 22))
    check_rebalance_conserves_bytes(fab, gw_src, gw_dst, nbytes,
                                    k=int(rng.integers(2, 5)),
                                    cut_frac=float(rng.uniform(0.1, 0.7)))


# --------------------------------------------------------------------------- #
# hypothesis variants — richer search when the library is available
# --------------------------------------------------------------------------- #
if HAVE_HYPOTHESIS:

    @settings(max_examples=50, deadline=None)
    @given(seed=st_.integers(0, 2**32 - 1),
           nbytes=st_.integers(1 << 12, 1 << 26),
           k=st_.integers(1, 7))
    def test_split_shares_sum_exactly_hypothesis(seed, nbytes, k):
        rng = np.random.default_rng(seed)
        fab = _random_fabric(rng)
        src, dst = rng.choice(fab.n, size=2, replace=False)
        check_shares_sum_exactly(fab, int(src), int(dst), float(nbytes), k)

    @settings(max_examples=50, deadline=None)
    @given(seed=st_.integers(0, 2**32 - 1),
           nbytes=st_.integers(1 << 16, 1 << 26),
           k=st_.integers(2, 7))
    def test_active_paths_finish_together_hypothesis(seed, nbytes, k):
        rng = np.random.default_rng(seed)
        fab = _random_fabric(rng)
        src, dst = rng.choice(fab.n, size=2, replace=False)
        check_active_paths_finish_together(fab, int(src), int(dst),
                                           float(nbytes), k)

    @settings(max_examples=20, deadline=None)
    @given(seed=st_.integers(0, 2**32 - 1),
           nbytes=st_.integers(1 << 18, 1 << 22),
           k=st_.integers(2, 5),
           cut_frac=st_.floats(0.1, 0.7))
    def test_rebalance_conserves_bytes_hypothesis(seed, nbytes, k, cut_frac):
        rng = np.random.default_rng(seed)
        fab = _random_fabric(rng)
        check_rebalance_conserves_bytes(fab, fab.gateway(0),
                                        fab.gateway(fab.n_pods - 1),
                                        float(nbytes), k, cut_frac)

else:

    @pytest.mark.skip(reason="hypothesis not installed; seeded sweeps "
                      "above cover the same invariants")
    def test_hypothesis_variants_present():
        pass
