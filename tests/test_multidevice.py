"""Multi-device SPMD tests (subprocess with 8 virtual host devices): the
instant-checkpoint ppermute semantics, razor classification, ZeRO sharding,
cross-pod gradient compression, and a small-mesh dry-run."""
import subprocess
import sys
import textwrap

import pytest

# The subprocess SPMD tests are seconds each on the 0.4.37 floor thanks to
# repro/compat.py:shard_map_compat; only the all-families dry-run (minutes of
# jit compiles) keeps the `slow` marker. Partial-manual shard_map still
# CHECK-fails inside old XLA, so that one test needs AxisType-era jax. The
# gate is a precise version bound (not a blanket feature-detect skip):
# jax >= 0.6 is the AxisType-era line the latest-jax CI leg runs green
# (ROADMAP), and the one whose bundled XLA carries the IsManualSubgroup
# hlo_sharding_util fix. Dev/rc suffixes are ignored by the digit parse.
_JAX_FLOOR_FOR_PARTIAL_MANUAL = (0, 6, 0)


def _jax_version_tuple():
    import re
    jax = pytest.importorskip("jax")
    return tuple(int(x) for x in re.findall(r"\d+", jax.__version__)[:3])


requires_axis_type = pytest.mark.skipif(
    _jax_version_tuple() < _JAX_FLOOR_FOR_PARTIAL_MANUAL,
    reason="partial-manual shard_map CHECK-fails in pre-AxisType XLA "
           "(hlo_sharding_util IsManualSubgroup); needs jax >= "
           + ".".join(map(str, _JAX_FLOOR_FOR_PARTIAL_MANUAL)))


def _run(script: str, timeout: int = 560) -> str:
    env = {"XLA_FLAGS": "--xla_force_host_platform_device_count=8",
           "PYTHONPATH": "src", "JAX_PLATFORMS": "cpu", "PATH": "/usr/bin:/bin"}
    import os
    env["PATH"] = os.environ.get("PATH", env["PATH"])
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(script)],
                       capture_output=True, text=True, timeout=timeout,
                       env=env, cwd=".")
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    return r.stdout


def test_neighbor_backup_is_ring_permute():
    """After the in-step ppermute, device d holds device (d-1)'s shard."""
    _run("""
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P, NamedSharding
    from repro.core.instant import neighbor_backup

    from repro.launch.mesh import make_mesh_compat
    mesh = make_mesh_compat((4, 2), ("data", "model"))
    x = jnp.arange(8, dtype=jnp.float32).reshape(4, 2)  # row r on data-rank r
    xs = jax.device_put(x, NamedSharding(mesh, P("data", "model")))

    with mesh:
        out = jax.jit(lambda t: neighbor_backup(
            {"a": t}, {"a": P("data", "model")}, mesh))(xs)
    got = np.asarray(out["a"])
    expect = np.roll(np.asarray(x), 1, axis=0)  # shard i -> rank i+1
    np.testing.assert_array_equal(got, expect)
    print("ring ok")
    """)


def test_razor_plan_on_mesh():
    """Unique = ZeRO('data')-sharded opt leaves; bytes = 12 phi/d."""
    _run("""
    import jax, numpy as np
    from repro.configs import get_arch, reduce_for_smoke
    from repro.models import build_model, param_count
    from repro.core.razor import razor_plan
    from repro.train.state import make_state_plan

    from repro.launch.mesh import make_mesh_compat
    mesh = make_mesh_compat((4, 2), ("data", "model"))
    cfg = reduce_for_smoke(get_arch("llama3-8b"))
    model = build_model(cfg)
    plan = make_state_plan(model, mesh)
    razor = razor_plan(plan.state_specs["opt"], plan.opt_pspecs,
                       plan.state_specs["params"], mesh)
    phi = param_count(cfg)
    assert razor.dp == 4
    # master+m+v fp32 = 12 bytes per param; a few tiny non-divisible leaves
    # may stay replicated (razor counts them redundant)
    assert 0.9 * 12 * phi <= razor.unique_bytes <= 12 * phi
    assert razor.reduction > 0.5
    print("razor ok", razor.unique_bytes, 12 * phi)
    """)


def test_train_step_backup_roundtrip():
    """Run a REAL sharded train step on an 8-device mesh; verify the backup
    output equals the new opt state permuted by one DP rank."""
    _run("""
    import dataclasses, jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P, NamedSharding
    from repro.configs import get_arch, reduce_for_smoke, ShapeConfig
    from repro.models import build_model
    from repro.train.state import init_state
    from repro.train.step import build_train_step

    from repro.launch.mesh import make_mesh_compat
    mesh = make_mesh_compat((4, 2), ("data", "model"))
    cfg = dataclasses.replace(reduce_for_smoke(get_arch("qwen3-0.6b")),
                              dtype="float32")
    model = build_model(cfg)
    shape = ShapeConfig("t", 16, 8, "train")
    art = build_train_step(model, mesh, shape=shape, donate=False)
    state = init_state(model, jax.random.key(0))
    batch = {"tokens": jnp.asarray(
        np.random.default_rng(0).integers(0, cfg.vocab_size, (8, 17)),
        jnp.int32)}
    with mesh:
        new_state, metrics, backup = art.step_fn(state, batch)
    assert np.isfinite(float(metrics["loss"]))

    # pick a unique leaf and check ppermute semantics on the data axis
    flat_b = jax.tree_util.tree_leaves_with_path(backup)
    flat_o = dict(jax.tree_util.tree_leaves_with_path(new_state["opt"]))
    checked = 0
    for path, bleaf in flat_b:
        if bleaf is None:
            continue
        oleaf = flat_o[tuple(path)]
        spec = None
        # find this leaf's zero axis by matching pspec from the plan
        ps = art.plan.opt_pspecs
        node = ps
        for k in path:
            node = node[k.key] if hasattr(k, "key") else node[k.idx]
        axis_pos = [i for i, part in enumerate(node)
                    if part == "data" or (isinstance(part, tuple)
                                          and "data" in part)]
        if not axis_pos:
            continue
        ax = axis_pos[0]
        o = np.asarray(oleaf, np.float32)
        b = np.asarray(bleaf, np.float32)
        shards = np.split(o, 4, axis=ax)
        rolled = np.concatenate([shards[-1]] + shards[:-1], axis=ax)
        np.testing.assert_allclose(b, rolled, rtol=1e-6, atol=1e-6)
        checked += 1
        if checked >= 5:
            break
    assert checked >= 3
    print("backup semantics ok, leaves checked:", checked)
    """)


@requires_axis_type
def test_cross_pod_compression_close_to_exact():
    """int8 cross-pod gradient mean with error feedback ~= exact mean.

    tp=1 submesh: XLA's SPMD partitioner CHECK-fails on vocab-sharded gathers
    under a partial-manual shard_map (spmd_partitioner_util.cc:504) — the
    compression feature is supported for FSDP-style layouts until Shardy
    lands (documented in DESIGN.md §6)."""
    _run("""
    import dataclasses, jax, jax.numpy as jnp, numpy as np
    from repro.configs import get_arch, reduce_for_smoke, ShapeConfig
    from repro.models import build_model
    from repro.train.state import init_state
    from repro.train.step import build_train_step

    from repro.launch.mesh import make_mesh_compat
    mesh = make_mesh_compat((2, 4, 1), ("pod", "data", "model"))
    cfg = dataclasses.replace(reduce_for_smoke(get_arch("gemma-2b")),
                              dtype="float32")
    model = build_model(cfg)
    shape = ShapeConfig("t", 16, 8, "train")
    state = init_state(model, jax.random.key(0))
    batch = {"tokens": jnp.asarray(
        np.random.default_rng(0).integers(0, cfg.vocab_size, (8, 17)),
        jnp.int32)}

    outs = {}
    for compress in (False, True):
        art = build_train_step(model, mesh, shape=shape, donate=False,
                               compress_pod_grads=compress)
        with mesh:
            new_state, metrics, _ = art.step_fn(state, batch)
        outs[compress] = (jax.tree.map(np.asarray, new_state["params"]),
                          float(metrics["loss"]))
    assert abs(outs[True][1] - outs[False][1]) < 1e-4
    for a, b in zip(jax.tree.leaves(outs[True][0]),
                    jax.tree.leaves(outs[False][0])):
        np.testing.assert_allclose(a, b, rtol=5e-3, atol=5e-4)
    print("compression ok")
    """)


@pytest.mark.slow
def test_small_mesh_dryrun_all_families():
    """Lower+compile one representative per family on a 2x2x2 mesh."""
    _run("""
    import dataclasses, jax
    from repro.configs import get_arch, reduce_for_smoke, ShapeConfig
    from repro.models import build_model
    from repro.train.step import build_train_step
    from repro.train.state import make_state_specs
    from repro.train.serve import build_decode_step

    from repro.launch.mesh import make_mesh_compat
    mesh = make_mesh_compat((2, 2, 2), ("pod", "data", "model"))
    for arch in ("deepseek-67b", "qwen3-moe-30b-a3b", "mamba2-2.7b",
                 "zamba2-7b", "whisper-small", "internvl2-26b"):
        cfg = reduce_for_smoke(get_arch(arch))
        model = build_model(cfg)
        npatch = cfg.num_patch_tokens or 0
        shape = ShapeConfig("t", 32 + npatch, 8, "train")
        art = build_train_step(model, mesh, shape=shape)
        lowered = art.step_fn.lower(make_state_specs(model),
                                    model.input_specs(shape))
        lowered.compile()
        # decode too
        dshape = ShapeConfig("d", 64, 8, "decode")
        fn, plan, _ = build_decode_step(model, mesh, dshape)
        specs = model.input_specs(dshape)
        fn.lower(plan.state_specs["params"], specs["cache"],
                 specs["token"]).compile()
        print(arch, "ok")
    """)
