"""Unit tests: ckpt engine/storage, data loader, LCCL link scheduler,
detection barrier, failover timelines, memory model."""
import threading
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt.engine import CkptEngine, CkptEngineConfig
from repro.ckpt.storage import AsyncWriter, load_pytree, save_pytree
from repro.core.detection import InterruptibleBarrier, WorkerInterrupted
from repro.core.lccl import LinkScheduler, ring_allreduce_time
from repro.data.indexer import TidIndexer
from repro.data.loader import PrefetchingLoader, SyntheticTokens, buffer_bytes


# ---------------- storage ---------------- #
def test_pytree_roundtrip(tmp_path):
    tree = {"a": np.arange(6, dtype=np.float32).reshape(2, 3),
            "b": {"c": np.ones((4,), np.int32)}}
    save_pytree(tmp_path / "x.npz", tree, {"iteration": 7})
    like = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)
    back = load_pytree(tmp_path / "x.npz", like)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        np.testing.assert_array_equal(a, b)


def test_async_writer(tmp_path):
    w = AsyncWriter()
    for i in range(3):
        w.submit(tmp_path / f"s{i}.npz", {"x": np.full((2,), i)}, block=True)
    w.close()
    assert w.saved == 3 and not w.errors
    assert sorted(p.name for p in tmp_path.glob("*.npz")) == \
        ["s0.npz", "s1.npz", "s2.npz"]


def test_ckpt_engine_full_and_restore(tmp_path):
    eng = CkptEngine(CkptEngineConfig(out_dir=tmp_path, full_every=5),
                     worker_id=0)
    state = {"w": np.arange(8, dtype=np.float32)}
    assert not eng.maybe_full_checkpoint(3, state)
    assert eng.maybe_full_checkpoint(5, state)
    eng.writer.drain()
    assert eng.latest_full() == 5
    got = eng.restore_full(5, jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state))
    np.testing.assert_array_equal(got["w"], state["w"])
    eng.close()


def test_lazy_backup_rank0_only(tmp_path):
    eng = CkptEngine(CkptEngineConfig(out_dir=tmp_path), worker_id=3)
    assert eng.lazy_backup(9, {"p": np.ones(2)}, is_dp_rank0=False) is None
    path = eng.lazy_backup(9, {"p": np.ones(2)}, is_dp_rank0=True)
    assert path is not None and path.exists()


# ---------------- data loader ---------------- #
def test_loader_fifo_and_eviction():
    idx = TidIndexer(256, 8, seed=0)
    src = SyntheticTokens(256, 16, 100, seed=0)
    ld = PrefetchingLoader(src, idx, dp_rank=0, active_dp=2, k=3)
    for it in range(3):
        assert ld.preload_next(it) is not None
    assert ld.preload_next(0) is None  # buffer full (k=3)
    b0 = ld.get(0)
    assert b0.shape == (4, 17)
    # deterministic across recoveries
    ld2 = PrefetchingLoader(src, idx, dp_rank=0, active_dp=2)
    np.testing.assert_array_equal(ld2.get(0), b0)


def test_buffer_bound_formula():
    # paper: ~40 MB for LLaMA3-70B-scale (s=8192, b=1, k=10)
    b = buffer_bytes(8192, 1, 10, phi=1e9, bandwidth=25e9, flops=989e12)
    assert b == pytest.approx(4 * 8192 * 1 * 10)
    # compute-bound regime: second term binds
    b2 = buffer_bytes(128, 1, 1000, phi=1e6, bandwidth=1e9, flops=1e15)
    assert b2 == pytest.approx(6 * 128 * 1 * 1e6 * 1e9 / 1e15)


# ---------------- LCCL link scheduler ---------------- #
def test_train_monopolizes_link():
    """STATE only moves when the link is idle; TRAIN never waits."""
    sch = LinkScheduler(bandwidth=1e9, quantum=1e6)
    tr1 = sch.submit("TRAIN", 1e9, t=0.0)     # 1s of TRAIN at t=0
    st = sch.submit("STATE", 0.5e9, t=0.0)    # STATE waits
    tr2 = sch.submit("TRAIN", 1e9, t=1.2)     # more TRAIN at 1.2s
    sch.run(until=10.0)
    assert tr1.t_finish == pytest.approx(1.0, rel=1e-6)
    assert tr2.t_start == pytest.approx(1.2, rel=1e-6)   # TRAIN never queued
    # STATE squeezed into [1.0, 1.2] then resumed after tr2
    assert st.t_finish > tr2.t_finish
    assert st.t_start >= tr1.t_finish


def test_ring_allreduce_model_monotone():
    t1 = ring_allreduce_time(1e9, 8, 25e9)
    t2 = ring_allreduce_time(2e9, 8, 25e9)
    assert t2 > t1
    assert ring_allreduce_time(1e9, 1, 25e9) == 0.0


# ---------------- cross-layer detection ---------------- #
def test_interruptible_barrier_wakes_on_breakdown():
    bar = InterruptibleBarrier(3)
    results = {}

    def worker(i):
        try:
            bar.wait(i, timeout=5.0)
            results[i] = "completed"
        except WorkerInterrupted as e:
            results[i] = ("interrupted", tuple(e.failed_workers))

    threads = [threading.Thread(target=worker, args=(i,)) for i in range(2)]
    for t in threads:
        t.start()
    time.sleep(0.05)           # workers 0,1 blocked; worker 2 "failed"
    t0 = time.time()
    bar.interrupt([2])
    for t in threads:
        t.join(timeout=2)
    dt = time.time() - t0
    assert dt < 1.0            # woke fast, no 10-minute NCCL timeout
    assert results[0] == ("interrupted", (2,))
    assert results[1] == ("interrupted", (2,))


def test_barrier_completes_when_all_arrive():
    bar = InterruptibleBarrier(2)
    out = []
    t = threading.Thread(target=lambda: out.append(bar.wait(0, timeout=5)))
    t.start()
    time.sleep(0.02)
    bar.wait(1, timeout=5)
    t.join(timeout=2)
    assert out == [0]


# ---------------- failover timelines ---------------- #
def test_timeline_overlap_beats_serial():
    from repro.runtime.failover import baseline_timeline, fftrainer_timeline
    fft = fftrainer_timeline(128, 3e9)
    base = baseline_timeline(128, 3e9)
    assert fft["total"] < 40.0
    assert base["total"] > 800.0
    # the overlapped stage is max(), not sum()
    assert fft["network_and_state"] < 15.0


# ---------------- memory model sanity ---------------- #
def test_memory_model_param_accounting():
    import jax
    from repro.configs import get_arch
    from repro.launch.mesh import make_single_device_mesh
    from repro.models import build_model, param_count
    from repro.roofline.memory_model import sharded_bytes
    from repro.train.state import make_state_plan
    cfg = get_arch("qwen3-0.6b")
    mesh = make_single_device_mesh()
    model = build_model(cfg)
    plan = make_state_plan(model, mesh)
    p = sharded_bytes(plan.state_specs["params"], plan.param_pspecs, mesh)
    assert p == 2 * param_count(cfg)   # bf16, unsharded on 1x1 mesh
