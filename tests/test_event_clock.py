"""Exact event-driven fabric clock (ISSUE 4 tentpole): windowed
`LinkTopology.run(until=)` timings equal `drain()` timings to float
tolerance on ring, pod-fabric, and storm scenarios; multi-hop streams land
in the window they were submitted in; `peek_next_finish` mirrors `run`'s
scheduling decisions; and the cluster's hidden/exposed verdicts are booked
on real fabric edges without the old 4x sub-step loop."""
import numpy as np
import pytest

from repro.ckpt.stream import ChunkedStream, StreamAssembler, TopologyTransport
from repro.core.lccl import (LinkScheduler, LinkTopology, PodFabric,
                             inject_storm, submit_chunked_path)


# --------------------------------------------------------------------------- #
# windowed == drained (the acceptance criterion)
# --------------------------------------------------------------------------- #
def _ring(n=8, bw=1e6, q=1e4, **kw):
    return LinkTopology(n, bw, quantum=q, **kw)


def _pods(**kw):
    kw.setdefault("quantum", 1e4)
    return PodFabric(4, 4, ici_bw=1e6, dcn_bw=2e5, dcn_latency=1e-3, **kw)


def _storm_fabric():
    fab = _pods()
    inject_storm(fab, seed=123, pods=1, edge_failures=1)
    return fab


_SCENARIOS = {
    # (fabric factory, (src, dst), bytes)
    "ring_multihop": (_ring, (0, 3), 1e5),
    "ring_hotspot": (lambda: _ring(edge_bw={(1, 2): 2e5}), (0, 3), 1e5),
    "pod_crosspod": (_pods, (5, 2), 1e5),
    "storm_darkened_detour": (_storm_fabric, None, 1e5),
}


def _storm_endpoints(fab):
    """Gateways of the pods flanking the darkened pod: the fetch must race
    the other way around the DCN gateway ring."""
    dark = fab.dark_pods()[0]
    return (fab.gateway((dark + 1) % fab.n_pods),
            fab.gateway((dark - 1) % fab.n_pods))


@pytest.mark.parametrize("scenario", sorted(_SCENARIOS))
def test_windowed_run_matches_drain(scenario):
    make, ends, nbytes = _SCENARIOS[scenario]

    def finishes(windowed):
        topo = make()
        src, dst = ends if ends is not None else _storm_endpoints(topo)
        pts = submit_chunked_path(topo, "STATE", nbytes, 0.0,
                                  topo.path(src, dst), quantum=1e4)
        if windowed:
            t, horizon = 0.0, 10.0
            while not all(pt.finished for pt in pts) and t < horizon:
                t += 0.05
                topo.run(until=t)
        else:
            topo.drain()
        assert all(pt.finished for pt in pts)
        return [pt.t_finish for pt in pts]

    np.testing.assert_allclose(finishes(True), finishes(False), rtol=1e-12)


def test_windowed_run_matches_drain_bidirectional_split():
    """The two ring directions of a split recovery pipeline independently;
    windowed advancement must reproduce the drained schedule of BOTH."""
    def finish(windowed):
        topo = _ring()
        tp = TopologyTransport(topo)
        arr = np.zeros((4 << 20) // 8, dtype=np.float64)
        cs = ChunkedStream.from_array("r", arr, quantum=1 << 12)
        asm = StreamAssembler.for_stream(cs)
        ticket = tp.send(cs, 0.0, assembler=asm, src=0, dst=1, policy="split")
        if windowed:
            t = 0.0
            while not ticket.complete and t < 60.0:
                t += 0.25
                tp.run(until=t)
        else:
            tp.drain()
        assert asm.complete
        return ticket.finish_time

    assert finish(True) == pytest.approx(finish(False), rel=1e-12)


def test_multihop_stream_lands_inside_one_window():
    """A 3-hop chunked stream submitted at the window start crosses ALL its
    hops within that single run(until=) window, finishing at the exact
    pipelined store-and-forward time — the artifact the 4x sub-step loop
    used to paper over."""
    topo = LinkTopology(6, 1e6, quantum=1e4)
    pts = submit_chunked_path(topo, "STATE", 1e5, 0.0, topo.path(0, 3),
                              quantum=1e4)
    topo.run(until=0.2)                # ONE window
    assert all(pt.finished for pt in pts)
    assert max(pt.t_finish for pt in pts) == pytest.approx(0.1 + 2 * 0.01,
                                                           rel=1e-6)


def test_window_boundary_respected_mid_pipeline():
    """A short window cuts the pipeline mid-flight at exactly the right
    chunks: deliveries whose last hop starts before `until` land (at their
    exact store-and-forward instants); the rest stay queued and complete in
    the next window on the same exact schedule."""
    topo = LinkTopology(6, 1e6, quantum=1e4)
    pts = submit_chunked_path(topo, "STATE", 1e5, 0.0, topo.path(0, 3),
                              quantum=1e4)
    topo.run(until=0.05)
    # chunk i leaves hop2 at 0.02 + 0.01*i; only i <= 2 starts its last hop
    # before the 0.05 horizon
    done = [pt for pt in pts if pt.finished]
    assert len(done) == 3
    np.testing.assert_allclose([pt.t_finish for pt in done],
                               [0.03, 0.04, 0.05], rtol=1e-9)
    topo.run(until=0.2)
    assert all(pt.finished for pt in pts)
    np.testing.assert_allclose([pt.t_finish for pt in pts],
                               [0.03 + 0.01 * i for i in range(10)],
                               rtol=1e-9)


def test_cross_pod_latency_exact_in_window():
    """Per-hop DCN delivery latency accrues identically whether the fabric
    is drained or advanced in one window."""
    fab = PodFabric(3, 2, 1e6, 1e6, dcn_latency=0.25, quantum=1e4)
    pts = submit_chunked_path(fab, "STATE", 1e4, 0.0, fab.path(1, 3),
                              quantum=1e4)
    fab.run(until=1.0)
    assert pts[0].finished
    assert pts[0].t_finish == pytest.approx(0.03 + 0.25, rel=1e-6)


# --------------------------------------------------------------------------- #
# peek_next_finish mirrors run()
# --------------------------------------------------------------------------- #
def test_peek_matches_event_stepping_on_random_workloads():
    """Drive identical schedulers through (a) one drain and (b) a
    peek-then-step event loop; every predicted completion must match the
    realized one, and final clocks/finish times must be identical."""
    rng = np.random.default_rng(42)
    for trial in range(20):
        subs = []
        for _ in range(rng.integers(3, 12)):
            kind = "TRAIN" if rng.random() < 0.4 else "STATE"
            size = float(rng.choice([0.0, 1e4, 5e4, 3e5]))
            # half the submit times come from a small discrete set so
            # same-instant submissions with DIFFERENT sizes (a chunked
            # stream's ragged tail) are exercised — peek must keep run()'s
            # stable submission-order tie-break
            t_sub = (float(rng.choice([0.0, 0.1, 0.25]))
                     if rng.random() < 0.5 else float(rng.uniform(0, 0.5)))
            subs.append((kind, size, t_sub))
        a = LinkScheduler(1e6, quantum=2e4, latency=0.01)
        b = LinkScheduler(1e6, quantum=2e4, latency=0.01)
        tra = [a.submit(*s) for s in subs]
        trb = [b.submit(*s) for s in subs]
        a.drain()
        while True:
            predicted = b.peek_next_finish()
            if predicted is None:
                break
            before = b.n_finished
            b.run(until=float("inf"), stop_after_finish=True)
            assert b.n_finished == before + 1
            assert b.now == pytest.approx(predicted, rel=1e-12), trial
        assert b.idle
        assert b.now == pytest.approx(a.now, rel=1e-12)
        for x, y in zip(tra, trb):
            assert x.t_finish == pytest.approx(y.t_finish, rel=1e-12)


def test_ragged_tail_chunk_does_not_stall_the_event_clock():
    """Regression: a stream whose tail chunk is smaller than its siblings
    (all submitted at the same instant, chunk size > link quantum) must not
    desync peek from run — peek used to tie-break by size, promising the
    tail's completion inside a window run() spends mid-first-chunk, and the
    'event clock stalled' guard fired."""
    topo = LinkTopology(4, 1e6, quantum=1e4)
    tp = TopologyTransport(topo)
    arr = np.zeros(45000 // 8 * 8 // 8, dtype=np.uint64)   # 45000 bytes
    cs = ChunkedStream.from_array("ragged", arr, quantum=20000)
    assert [c.nbytes for c in cs.chunks] == [20000, 20000, 5000]
    asm = StreamAssembler.for_stream(cs)
    ticket = tp.send(cs, 0.0, assembler=asm, src=0, dst=1, policy="shortest")
    t = 0.0
    while not ticket.complete and t < 1.0:
        t += 0.006                     # window boundary mid-first-chunk
        tp.run(until=t)
    assert asm.complete
    # FIFO at full bandwidth: 45000 bytes end-to-end
    assert ticket.finish_time == pytest.approx(0.045, rel=1e-9)


def test_clock_never_overshoots_window_to_future_submission():
    """Regression: run(until=) used to jump an idle link's clock to its
    NEXT queued submission even when that lay beyond the horizon, so a
    chunk forwarded onto the link in a later window (but before that
    submission) was delayed to the far-future instant — windowed and
    drained schedules disagreed."""
    sch = LinkScheduler(1e6, quantum=1e4)
    far = sch.submit("STATE", 1e4, 5.0)
    sch.run(until=1.0)
    assert sch.now == pytest.approx(1.0)   # horizon, not 5.0
    # windowed vs drained parity through the fabric
    def finish(windowed):
        topo = LinkTopology(4, 1e6, quantum=1e4)
        topo.edge(1, 2).submit("STATE", 1e4, 5.0)
        pt = topo.submit_path("STATE", 1e4, 1.5, [(0, 1), (1, 2)])
        if windowed:
            topo.run(until=1.0)
            topo.run(until=2.0)
            topo.drain()
        else:
            topo.drain()
        return pt.t_finish
    assert finish(True) == pytest.approx(1.52, rel=1e-9)
    assert finish(True) == finish(False)
    sch.drain()
    assert far.t_finish == pytest.approx(5.01, rel=1e-9)


def test_peek_is_pure():
    sch = LinkScheduler(1e6, quantum=1e4)
    st = sch.submit("STATE", 5e4, 0.0)
    t1 = sch.peek_next_finish()
    t2 = sch.peek_next_finish()
    assert t1 == t2 == pytest.approx(0.05)
    assert sch.now == 0.0 and not st.finished and not sch.idle


def test_peek_none_when_nothing_starts_before_horizon():
    sch = LinkScheduler(1e6, quantum=1e4)
    sch.submit("STATE", 1e4, t=5.0)
    assert sch.peek_next_finish(until=1.0) is None
    assert sch.peek_next_finish() == pytest.approx(5.01)


# --------------------------------------------------------------------------- #
# cluster: verdicts without the sub-step loop, booked on real edges
# --------------------------------------------------------------------------- #
def _mk_pod_cluster(tmp_path, **fabric_kw):
    import dataclasses

    from repro.configs import get_arch, reduce_for_smoke
    from repro.optim import AdamWConfig
    from repro.runtime.cluster import (ClusterConfig, FabricConfig,
                                       SimCluster)
    cfg = dataclasses.replace(reduce_for_smoke(get_arch("qwen3-0.6b")),
                              dtype="float32")
    fabric_kw.setdefault("quantum", 2048)
    fabric_kw.setdefault("pods", 2)
    fabric_kw.setdefault("dcn_latency", 1e-4)
    return SimCluster(
        cfg,
        cluster=ClusterConfig(
            dp=4, global_batch=8, seq_len=16, ckpt_dir=tmp_path / "ck",
            full_every=50,
            hp=AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=50), seed=0),
        fabric=FabricConfig(**fabric_kw))


def test_cluster_verdicts_booked_on_real_fabric_edges(tmp_path):
    """Every per-edge hidden/exposed key is an actual fabric edge — the
    phantom (src, dst) pair a cross-pod instant route used to book under is
    gone (satellite: delivery_edge from the event queue)."""
    clu = _mk_pod_cluster(tmp_path)
    clu.run(3)
    books = {**clu.edge_instant_hidden, **clu.edge_instant_exposed}
    assert books, "no verdicts booked"
    for e in books:
        assert e in clu.topology.links, f"phantom edge key {e}"
    # the cross-pod instant shard (wid 1 -> wid 2 crosses the pod boundary)
    # lands over the delivering DCN edge, and on the fast fabric it hides
    assert clu.instant_hidden == 3 and clu.instant_exposed == 0
    dcn_booked = [e for e in books if clu.topology.tier(*e) == "dcn"]
    assert dcn_booked, "cross-pod instant shard not booked on its DCN hop"


def test_cluster_verdicts_match_drained_reference(tmp_path):
    """The windowed per-step verdict equals what an offline drain of the
    same tickets would conclude: every ticket the step marked hidden is
    complete with t_finish inside its iteration window."""
    clu = _mk_pod_cluster(tmp_path)
    for step in range(3):
        t_boundary = clu.sim_time + clu.t_iter_model
        clu.step()
        for w in clu.workers:
            tk = w.engine.last_instant_ticket
            assert tk is not None and tk.complete
            assert tk.finish_time <= t_boundary + 1e-9
    assert clu.instant_hidden == 3
