import os

# Keep tests on the single real CPU device — the 512-device virtual mesh is
# set ONLY by launch/dryrun.py (and by subprocess tests that opt in).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
