"""tools/bench_trend.py: the bench-smoke trend gate fails on >20% state-leg
regressions, passes improvements/noise in ungated rows, and tolerates a
missing previous artifact."""
import json
import subprocess
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parents[1]


def _dump(path: Path, rows) -> Path:
    path.write_text(json.dumps(
        [{"name": n, "us_per_call": 0.0, "derived": d} for n, d in rows]))
    return path


def _run(cur: Path, prev: Path, *extra: str) -> subprocess.CompletedProcess:
    return subprocess.run(
        [sys.executable, str(REPO / "tools" / "bench_trend.py"),
         "--current", str(cur), "--previous", str(prev), *extra],
        capture_output=True, text=True)


def test_state_leg_regression_fails(tmp_path):
    prev = _dump(tmp_path / "p.json",
                 [("table5/16gpu/fftrainer/state_leg_bidirectional", "0.033"),
                  ("table5/16gpu/bidi_beats_uni", "True")])
    cur = _dump(tmp_path / "c.json",
                [("table5/16gpu/fftrainer/state_leg_bidirectional", "0.050"),
                 ("table5/16gpu/bidi_beats_uni", "True")])
    r = _run(cur, prev)
    assert r.returncode == 1, r.stdout + r.stderr
    assert "REGRESSION" in r.stdout


def test_within_threshold_and_improvements_pass(tmp_path):
    prev = _dump(tmp_path / "p.json",
                 [("table5/sim/recovery_total_s", "10.0"),
                  ("table5/16gpu/fftrainer/state_recovery", "0.85"),
                  ("fig4/measured/per_iter_no_ckpt_us", "100.0")])
    cur = _dump(tmp_path / "c.json",
                [("table5/sim/recovery_total_s", "11.0"),   # +10% < gate
                 ("table5/16gpu/fftrainer/state_recovery", "0.40"),  # better
                 ("fig4/measured/per_iter_no_ckpt_us", "900.0")])    # ungated
    r = _run(cur, prev)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "OK" in r.stdout


def test_missing_previous_artifact_passes(tmp_path):
    cur = _dump(tmp_path / "c.json", [("table5/sim/recovery_total_s", "10.0")])
    r = _run(cur, tmp_path / "absent.json")
    assert r.returncode == 0
    assert "nothing to gate" in r.stdout


def test_vanished_gated_row_warns(tmp_path):
    prev = _dump(tmp_path / "p.json",
                 [("table5/16gpu/fftrainer/state_leg_bidirectional", "0.033")])
    cur = _dump(tmp_path / "c.json",
                [("table5/16gpu/fftrainer/state_leg_bidi_RENAMED", "0.05")])
    r = _run(cur, prev)
    assert r.returncode == 0
    assert r.stdout.count("WARNING gated row missing") == 2  # both sides


def test_zero_baseline_growth_is_a_regression(tmp_path):
    prev = _dump(tmp_path / "p.json", [("table5/sim/recovery_total_s", "0.0")])
    cur = _dump(tmp_path / "c.json", [("table5/sim/recovery_total_s", "12.0")])
    r = _run(cur, prev)
    assert r.returncode == 1
    assert "REGRESSION" in r.stdout


def test_gated_boolean_row_is_not_gated_numerically(tmp_path):
    """bool is an int subclass: a gated row holding true/false must warn as
    non-numeric, not fail CI as a 0->1 'regression' (or pass a True->False
    breakage silently)."""
    prev = _dump(tmp_path / "p.json", [("x/state_leg_ok", False)])
    cur = _dump(tmp_path / "c.json", [("x/state_leg_ok", True)])
    r = _run(cur, prev)
    assert r.returncode == 0
    assert "WARNING gated row non-numeric" in r.stdout


def test_gated_row_turned_non_numeric_warns(tmp_path):
    prev = _dump(tmp_path / "p.json", [("table5/sim/recovery_total_s", "3.0")])
    cur = _dump(tmp_path / "c.json", [("table5/sim/recovery_total_s", "oops")])
    r = _run(cur, prev)
    assert r.returncode == 0
    assert "WARNING gated row non-numeric" in r.stdout


def test_fleet_wall_clock_regression_fails(tmp_path):
    """The fleet-bench job's wall-clock rows are gated: the compiled-plan
    fast path slowing down >20% on the same runner class must fail CI."""
    prev = _dump(tmp_path / "p.json",
                 [("fleet/tiny/wall_s", "1.0"),
                  ("fleet/tiny/events_per_wall_s", "3.2e8")])
    cur = _dump(tmp_path / "c.json",
                [("fleet/tiny/wall_s", "1.5"),
                 ("fleet/tiny/events_per_wall_s", "2.0e8")])
    r = _run(cur, prev)
    assert r.returncode == 1, r.stdout + r.stderr
    assert "fleet/tiny/wall_s" in r.stdout
    assert "REGRESSION" in r.stdout


def test_fleet_wall_clock_within_threshold_passes(tmp_path):
    """Throughput rows (events_per_wall_s) are informational — only the
    wall_s rows gate, and +15% wall is inside the 20% noise budget."""
    prev = _dump(tmp_path / "p.json",
                 [("fleet/4096/wall_s", "5.5"),
                  ("fleet/4096/events_per_wall_s", "3.2e8")])
    cur = _dump(tmp_path / "c.json",
                [("fleet/4096/wall_s", "6.3"),
                 ("fleet/4096/events_per_wall_s", "1.0e8")])
    r = _run(cur, prev)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "OK" in r.stdout


def test_min_gated_speedup_drop_fails(tmp_path):
    """The scenario-fleet lane's straggler speedup is min-gated: the loop
    no longer migrating (speedup collapsing to ~1.0) must fail CI."""
    prev = _dump(tmp_path / "p.json", [("fig10/straggler/speedup", "2.0")])
    cur = _dump(tmp_path / "c.json", [("fig10/straggler/speedup", "1.0")])
    r = _run(cur, prev)
    assert r.returncode == 1, r.stdout + r.stderr
    assert "dropped" in r.stdout


def test_min_gated_speedup_growth_and_noise_pass(tmp_path):
    prev = _dump(tmp_path / "p.json", [("fig10/straggler/speedup", "2.0")])
    cur = _dump(tmp_path / "c.json", [("fig10/straggler/speedup", "1.9")])
    assert _run(cur, prev).returncode == 0       # -5% is inside the budget
    cur = _dump(tmp_path / "c.json", [("fig10/straggler/speedup", "3.0")])
    assert _run(cur, prev).returncode == 0       # faster is never a fail


def test_detection_latency_growth_fails(tmp_path):
    prev = _dump(tmp_path / "p.json",
                 [("fig10/loop/detection_latency_s", "0.36")])
    cur = _dump(tmp_path / "c.json",
                [("fig10/loop/detection_latency_s", "0.80")])
    r = _run(cur, prev)
    assert r.returncode == 1
    assert "REGRESSION" in r.stdout


def test_min_gated_row_vanishing_warns(tmp_path):
    prev = _dump(tmp_path / "p.json", [("fig10/straggler/speedup", "2.0")])
    cur = _dump(tmp_path / "c.json", [("fig10/straggler/speedup_NEW", "2.0")])
    r = _run(cur, prev)
    assert r.returncode == 0
    assert "WARNING gated row missing" in r.stdout


def test_custom_match_min_flag(tmp_path):
    prev = _dump(tmp_path / "p.json", [("x/throughput_gbps", "10.0")])
    cur = _dump(tmp_path / "c.json", [("x/throughput_gbps", "5.0")])
    assert _run(cur, prev).returncode == 0        # not min-gated by default
    r = _run(cur, prev, "--match-min", "throughput")
    assert r.returncode == 1
    assert "dropped" in r.stdout


def test_custom_threshold_and_match(tmp_path):
    prev = _dump(tmp_path / "p.json", [("x/custom_row", "1.0")])
    cur = _dump(tmp_path / "c.json", [("x/custom_row", "1.4")])
    assert _run(cur, prev).returncode == 0            # not gated by default
    r = _run(cur, prev, "--match", "custom_row", "--threshold", "0.3")
    assert r.returncode == 1
