"""End-to-end failover: train a real (smoke) model in the cluster simulator,
kill workers, recover from neighbor backups, and require BITWISE equality
with an uninterrupted run — instant checkpointing means zero rollback."""
import dataclasses
from pathlib import Path

import jax
import numpy as np
import pytest

from repro.configs import get_arch, reduce_for_smoke
from repro.optim import AdamWConfig
from repro.runtime.cluster import ClusterConfig, FaultScript, SimCluster


def _mk(tmp_path, dp=4, full_every=50, arch="qwen3-0.6b", seed=0):
    cfg = reduce_for_smoke(get_arch(arch))
    cfg = dataclasses.replace(cfg, dtype="float32")  # bitwise-stable
    return SimCluster(cfg, cluster=ClusterConfig(
        dp=dp, global_batch=8, seq_len=16, ckpt_dir=tmp_path / "ck",
        full_every=full_every,
        hp=AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=50), seed=seed))


def _state_equal(a, b):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        if not np.array_equal(np.asarray(x), np.asarray(y)):
            return False
    return True


def test_software_failure_bitwise_recovery(tmp_path):
    ref = _mk(tmp_path / "a")
    ref.run(10)

    clu = _mk(tmp_path / "b")
    clu.run(5)
    clu.inject_failure([2])
    rep = clu.recover()
    assert rep.recovered_from == "neighbor"
    assert rep.rolled_back_iterations == 0      # instant ckpt: no rollback
    clu.run(10 - clu.iteration)
    assert clu.iteration == 10
    assert _state_equal(ref.state, clu.state)
    assert ref.loss_history[-1] == clu.loss_history[-1]


def test_hardware_failure_recovery(tmp_path):
    ref = _mk(tmp_path / "a")
    ref.run(8)

    clu = _mk(tmp_path / "b")
    clu.run(4)
    clu.inject_failure([1], hardware=True)      # host RAM lost too
    rep = clu.recover(FaultScript(hardware=True))
    assert rep.recovered_from == "neighbor"     # worker 2 held the backup
    clu.run(8 - clu.iteration)
    assert _state_equal(ref.state, clu.state)


def test_adjacent_failure_falls_back_to_full_ckpt(tmp_path):
    """Paper corner case: worker and its DP-ring successor both fail ->
    neighbor copy is gone -> multi-level insurance (full CKPT) + rollback."""
    clu = _mk(tmp_path / "c", full_every=3)
    clu.run(7)                                  # full ckpts at it 3 and 6
    clu.inject_failure([1, 2], hardware=True)   # 2 held 1's backup
    rep = clu.recover(FaultScript(hardware=True))
    assert rep.recovered_from == "full_ckpt"
    assert rep.resume_iteration == 6
    assert rep.rolled_back_iterations == 1      # 7 -> 6
    clu.run(3)
    assert clu.iteration == 9
    assert np.isfinite(clu.loss_history[-1])


def test_failover_timeline_much_faster_than_baseline(tmp_path):
    clu = _mk(tmp_path / "d")
    clu.run(3)
    clu.inject_failure([0])
    rep = clu.recover()
    from repro.runtime.failover import baseline_timeline
    base = baseline_timeline(clu.dp, 1e9)
    assert rep.total_time < 30.0                # paper: 26-29 s
    assert base["total"] > 800.0                # paper: 899-994 s
    assert rep.total_time < 0.05 * base["total"]


def test_elastic_shrink_continues_training(tmp_path):
    clu = _mk(tmp_path / "e", dp=4)
    clu.run(4)
    # lose worker 3 with no spare: shrink to dp=3, batch re-partitions
    clu.inject_failure([3], hardware=True)
    clu.workers[3].alive = True                 # recover() replaces in-place;
    clu.shrink([3])                             # here we rescale instead
    assert clu.dp == 3
    assert clu.global_batch % 3 == 0
    losses = clu.run(4)
    assert all(np.isfinite(l) for l in losses)
    # exact cover still holds after rescale
    parts = [w.loader.indexer.indices(clu.iteration, i, clu.dp)
             for i, w in enumerate(clu.workers)]
    assert len(np.concatenate(parts)) == clu.global_batch


def test_straggler_detection():
    from repro.runtime.straggler import StragglerDetector
    det = StragglerDetector(4)
    for _ in range(8):
        for w, t in enumerate([0.1, 0.1, 0.1, 0.4]):
            det.observe(w, t)
    assert det.stragglers() == [3]
    assert det.cluster_step_time() == pytest.approx(0.4, rel=0.2)
