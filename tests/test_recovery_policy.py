"""Pluggable RecoveryPolicy coverage (ISSUE 6): the refactored stream policy
is bit-identical to the pre-refactor recovery (pinned timelines on ring and
pod fabrics), the legacy kwarg surface still works (with DeprecationWarning),
checkpoint-free compute recovery rebuilds CURRENT state with ZERO state bytes
on the wire, hybrid mixes legs per worker, and the storm crossover where
compute beats stream shows up in measured end-to-end totals."""
import dataclasses
import subprocess
import sys
import warnings
from pathlib import Path

import jax
import numpy as np
import pytest

from repro.configs import get_arch, reduce_for_smoke
from repro.optim import AdamWConfig
from repro.runtime.cluster import (ClusterConfig, FabricConfig, SimCluster)
from repro.runtime.recovery import (ComputeRecovery, FaultScript,
                                    HybridRecovery, RecoveryError,
                                    RecoveryPlan, RecoveryPolicy,
                                    StreamRecovery, resolve_policy)

ROOT = Path(__file__).resolve().parent.parent


def _cfg():
    return dataclasses.replace(reduce_for_smoke(get_arch("qwen3-0.6b")),
                               dtype="float32")


def _mk(tmp_path, name, recovery=None, fabric=None, **ck):
    ck.setdefault("dp", 4)
    ck.setdefault("global_batch", 8)
    ck.setdefault("seq_len", 16)
    ck.setdefault("ckpt_dir", tmp_path / name)
    ck.setdefault("hp", AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=50))
    return SimCluster(_cfg(), cluster=ClusterConfig(**ck), fabric=fabric,
                      recovery=recovery)


def _leaves(clu):
    return [np.asarray(x) for x in jax.tree_util.tree_leaves(clu.state)]


def _assert_states_equal(a, b):
    la, lb = _leaves(a), _leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(x, y)


# --------------------------------------------------------------------------- #
# stream policy: bit-identical to the pre-refactor recovery (pinned numbers)
# --------------------------------------------------------------------------- #
def test_stream_ring_timeline_matches_pre_refactor(tmp_path):
    clu = _mk(tmp_path, "ring")
    clu.run(4)
    clu.inject_failure([1])
    rep = clu.recover()
    # pinned from the pre-refactor SimCluster._recover_from_neighbors
    assert rep.timeline["detection"] == pytest.approx(2.05)
    assert rep.timeline["pod_creation"] == pytest.approx(0.5)
    assert rep.timeline["dependency_install"] == pytest.approx(0.0)
    assert rep.timeline["network_and_state"] == pytest.approx(0.504)
    assert rep.total_time == pytest.approx(3.054)
    assert (rep.chunks_sent, rep.chunks_total) == (1, 1)
    assert rep.recovered_from == "neighbor"
    assert rep.rolled_back_iterations == 0
    assert rep.policy == "stream"
    assert rep.state_bytes_streamed == pytest.approx(271488.0)


def test_stream_pod_fabric_timeline_matches_pre_refactor(tmp_path):
    clu = _mk(tmp_path, "pod", fabric=FabricConfig(
        quantum=2048, pods=2, dcn_bw=5e9, dcn_latency=1e-4))
    clu.run(4)
    clu.inject_failure([1])
    rep = clu.recover()
    assert rep.timeline["network_and_state"] == pytest.approx(0.504)
    assert rep.total_time == pytest.approx(3.054)
    assert (rep.chunks_sent, rep.chunks_total) == (133, 133)
    assert rep.state_bytes_streamed == pytest.approx(271488.0)


def test_stream_hardware_timeline_matches_pre_refactor(tmp_path):
    clu = _mk(tmp_path, "hw")
    clu.run(4)
    clu.inject_failure([2], hardware=True)
    rep = clu.recover(FaultScript(hardware=True))
    assert rep.kind == "hardware"
    assert rep.timeline["pod_creation"] == pytest.approx(7.0)
    assert rep.total_time == pytest.approx(9.554)
    assert rep.rolled_back_iterations == 0


# --------------------------------------------------------------------------- #
# legacy kwarg surface: same bits, plus a DeprecationWarning
# --------------------------------------------------------------------------- #
def test_legacy_kwargs_bit_identical_to_config_api(tmp_path):
    new = _mk(tmp_path, "new")
    with pytest.warns(DeprecationWarning):
        old = SimCluster(  # deprecated-ok: the shim under test
            _cfg(), dp=4, global_batch=8, seq_len=16,
            ckpt_dir=tmp_path / "old",
            hp=AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=50))
    new.run(4)
    old.run(4)
    new.inject_failure([1])
    old.inject_failure([1])
    rep_new = new.recover(FaultScript())
    with pytest.warns(DeprecationWarning):
        rep_old = old.recover(hardware=False)  # deprecated-ok: shim test
    assert rep_old.timeline == rep_new.timeline
    assert rep_old.total_time == rep_new.total_time
    assert (rep_old.chunks_sent, rep_old.chunks_total) == \
        (rep_new.chunks_sent, rep_new.chunks_total)
    new.run(3)
    old.run(3)
    _assert_states_equal(new, old)


def test_from_kwargs_shim_warns_and_builds(tmp_path):
    with pytest.warns(DeprecationWarning):
        clu = SimCluster.from_kwargs(  # deprecated-ok: the shim under test
            _cfg(), dp=4, global_batch=8, seq_len=16,
            ckpt_dir=tmp_path / "fk", quantum=2048)
    assert clu.dp == 4
    assert clu.cluster_config.global_batch == 8
    assert clu.fabric_config.quantum == 2048


def test_unknown_kwargs_raise_typeerror(tmp_path):
    with pytest.raises(TypeError):
        SimCluster(_cfg(), bogus_knob=1)
    clu = _mk(tmp_path, "tk")
    clu.run(2)
    clu.inject_failure([1])
    with pytest.raises(TypeError):
        clu.recover(bogus_fault=True)
    clu.recover()                      # cluster still usable afterwards


# --------------------------------------------------------------------------- #
# compute policy: checkpoint-free, zero STATE traffic, zero rollback
# --------------------------------------------------------------------------- #
class _AcctSpy:
    """A custom policy object (plugs straight into `recovery=`) that wraps
    another policy and measures the STATE bytes its execute leg puts on the
    wire — isolating the policy from recover()'s lazy-backup traffic."""
    def __init__(self, inner):
        self.inner, self.name, self.delta = inner, inner.name, None

    def plan(self, cluster, failed, faults=FaultScript(), **kw):
        return self.inner.plan(cluster, failed, faults, **kw)

    def execute(self, plan):
        b0 = plan.cluster.transport.accounting()["state_bytes"]
        rep = self.inner.execute(plan)
        self.delta = plan.cluster.transport.accounting()["state_bytes"] - b0
        return rep


def test_compute_recovery_zero_state_traffic_bitwise(tmp_path):
    ref = _mk(tmp_path, "ref")
    ref.run(7)
    spy = _AcctSpy(ComputeRecovery())
    clu = _mk(tmp_path, "comp", recovery=spy)
    clu.run(4)
    clu.inject_failure([1])
    rep = clu.recover()
    assert spy.delta == 0.0            # the recovery itself streamed nothing
    assert rep.state_bytes_streamed == 0.0
    assert rep.policy == "compute"
    assert rep.recovered_from == "compute_replay"
    assert rep.rolled_back_iterations == 0
    assert rep.resume_iteration == 4
    assert rep.compute_seconds > 0.0
    # replay wall = setup + bytes * overhead / (rate * replayers)
    cost = ComputeRecovery().cost_model
    bytes_ = clu.shard_nbytes()
    wall = cost.setup_seconds + bytes_ * cost.replay_overhead / (
        cost.recompute_rate * 2)
    assert rep.timeline["replay_compute"] == pytest.approx(wall)
    clu.run(3)
    _assert_states_equal(clu, ref)     # rebuilt CURRENT state, not a rollback


def test_compute_survives_adjacent_double_hardware(tmp_path):
    # workers 1 and 2 both die hard: worker 1's backup (held by 2) is gone,
    # so the stream policy must fall back to the periodic full CKPT and roll
    # back — the compute policy replays instead and loses nothing
    stream = _mk(tmp_path, "dbl_s", full_every=3)
    stream.run(4)
    stream.inject_failure([1, 2], hardware=True)
    rep_s = stream.recover(FaultScript(hardware=True))
    assert rep_s.recovered_from == "full_ckpt"
    assert rep_s.rolled_back_iterations > 0

    ref = _mk(tmp_path, "dbl_ref", full_every=3)
    ref.run(7)
    comp = _mk(tmp_path, "dbl_c", full_every=3, recovery="compute")
    comp.run(4)
    comp.inject_failure([1, 2], hardware=True)
    rep_c = comp.recover(FaultScript(hardware=True))
    assert rep_c.recovered_from == "compute_replay"
    assert rep_c.rolled_back_iterations == 0
    assert rep_c.kind == "hardware"
    comp.run(3)
    _assert_states_equal(comp, ref)


def test_compute_rejects_chunk_faults(tmp_path):
    clu = _mk(tmp_path, "rej", recovery="compute")
    clu.run(2)
    clu.inject_failure([1])
    with pytest.raises(RecoveryError):
        clu.recover(FaultScript(interrupt_after_chunks=2))
    with pytest.raises(RecoveryError):
        clu.recover(FaultScript(corrupt_chunks=1))
    clu.recover()                      # plain compute recovery still works


# --------------------------------------------------------------------------- #
# storm crossover + hybrid
# --------------------------------------------------------------------------- #
STORM_FABRIC = dict(quantum=2048, pods=2, dcn_bw=2e5, dcn_latency=1e-4)


def _storm_cluster(tmp_path, name, recovery):
    clu = _mk(tmp_path, name, recovery=recovery,
              fabric=FabricConfig(**STORM_FABRIC))
    clu.run(2)
    clu.inject_storm(7, pods=1)        # seed 7 darkens pod 1 (workers 2, 3)
    return clu


def test_storm_crossover_compute_beats_stream(tmp_path):
    rep_s = _storm_cluster(tmp_path, "st_s", "stream").recover()
    rep_c = _storm_cluster(tmp_path, "st_c", "compute").recover()
    # the cross-pod stream is DCN-bound; the replay leg never touches the
    # fabric — the crossover the model-level table5 rows predict
    assert rep_s.state_bytes_streamed > 0
    assert rep_c.state_bytes_streamed == 0.0
    assert rep_c.total_time < rep_s.total_time


def test_hybrid_mixes_legs_per_worker(tmp_path):
    rep_s = _storm_cluster(tmp_path, "hy_s", "stream").recover()
    rep_h = _storm_cluster(tmp_path, "hy", "hybrid").recover()
    assert rep_h.policy == "hybrid"
    assert rep_h.recovered_from == "neighbor+compute"
    # streams only the worker whose backup is reachable in-pod; the
    # DCN-bound worker replays instead
    assert 0 < rep_h.state_bytes_streamed < rep_s.state_bytes_streamed
    assert rep_h.compute_seconds > 0.0
    assert rep_h.total_time < rep_s.total_time
    assert rep_h.rolled_back_iterations == 0


def test_hybrid_healthy_prefers_stream(tmp_path):
    clu = _mk(tmp_path, "hy_ok", recovery="hybrid")
    clu.run(4)
    clu.inject_failure([1])
    rep = clu.recover()
    assert rep.recovered_from == "neighbor"   # all legs streamed
    assert rep.compute_seconds == 0.0
    assert rep.total_time == pytest.approx(3.054)


# --------------------------------------------------------------------------- #
# policy plumbing
# --------------------------------------------------------------------------- #
def test_resolve_policy_specs():
    assert resolve_policy(None).name == "stream"
    assert resolve_policy("compute").name == "compute"
    custom = HybridRecovery()
    assert resolve_policy(custom) is custom
    assert isinstance(StreamRecovery(), RecoveryPolicy)
    with pytest.raises(ValueError):
        resolve_policy("teleport")


def test_plan_is_inspectable_before_execute(tmp_path):
    clu = _mk(tmp_path, "plan", recovery="compute")
    clu.run(2)
    clu.inject_failure([1])
    plan = clu.recovery_policy.plan(clu, [1])
    assert isinstance(plan, RecoveryPlan)
    assert plan.mode == "compute"
    assert plan.est_state_bytes == 0.0
    assert plan.est_compute_seconds > 0.0
    assert [l.wid for l in plan.compute_legs] == [1]
    clu.recover()                      # planning didn't disturb the cluster


def test_recovery_error_is_runtime_error():
    assert issubclass(RecoveryError, RuntimeError)


def test_deprecation_lint_passes():
    proc = subprocess.run(
        [sys.executable, str(ROOT / "tools" / "check_deprecations.py")],
        capture_output=True, text=True)
    assert proc.returncode == 0, f"\n{proc.stdout}\n{proc.stderr}"


def test_public_api_resolves():
    import repro
    for name in repro.__all__:
        assert getattr(repro, name) is not None
    assert "SimCluster" in dir(repro)
