"""Compiled traffic plans (ISSUE 7 tentpole): plan-compiled timings equal
the exact event-driven clock to float precision on ring / pod-fabric /
storm / bidirectional scenarios (same rtol=1e-12 discipline as
tests/test_event_clock.py — hypothesis-randomized workloads live in
test_traffic_plan_property.py); the `compile_plan` decoupled run path
matches the global event loop on multi-hop traffic; and plans + routing
caches invalidate on topology epochs (failures, storms, restores,
bandwidth edits)."""
from types import SimpleNamespace

import numpy as np
import pytest

from repro.core.lccl import (LinkTopology, PodFabric, inject_storm,
                             submit_chunked_path)
from repro.core.plan import (PlanUnsupported, compile_traffic_plan,
                             steady_state_pattern)

PERIOD = 0.25


def _profile(train=4e4, state=2.5e4, dcn=1e4):
    """Duck-typed TrafficProfile: drains well inside PERIOD on the 1e6 B/s
    test fabrics (0.065s ICI, 0.05s DCN)."""
    return SimpleNamespace(train_bytes=train, state_bytes=state,
                           dcn_bytes=dcn)


def _ring():
    return LinkTopology(8, 1e6, quantum=1e4)


def _pods():
    return PodFabric(4, 4, ici_bw=1e6, dcn_bw=2e5, dcn_latency=1e-3,
                     quantum=1e4)


def _storm_fabric():
    fab = _pods()
    inject_storm(fab, seed=123, pods=1, edge_failures=1)
    return fab


def _steady(fab):
    return steady_state_pattern(fab, _profile())


def _bidi(fab):
    """Bidirectional split: each ring edge carries the two half-shards a
    worker splits across both ring directions (same-instant ragged STATE
    plus a later offset batch) on top of TRAIN."""
    half = 1.25e4
    return {e: (("TRAIN", 4e4, 0.0), ("STATE", half, 0.0),
                ("STATE", half, 0.3 * PERIOD))
            for e in fab.live_edges()}


_SCENARIOS = {
    "ring": (_ring, _steady),
    "pod_fabric": (_pods, _steady),
    "storm": (_storm_fabric, _steady),
    "bidirectional": (_ring, _bidi),
}


def _interpret(factory, pattern, n):
    """Reference: drive a fresh identical fabric through `n` periods on the
    exact event-driven clock, one window per period."""
    fab = factory()
    for s in range(n):
        for e, subs in pattern.items():
            for kind, size, off in subs:
                fab.links[e].submit(kind, size, s * PERIOD + off)
        fab.run(until=(s + 1) * PERIOD)
    fab.drain()
    return fab


# --------------------------------------------------------------------------- #
# compiled == drained (the acceptance criterion)
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("scenario", sorted(_SCENARIOS))
def test_compiled_plan_matches_drain(scenario):
    factory, pat_fn = _SCENARIOS[scenario]
    fab = factory()
    pattern = pat_fn(fab)
    plan = compile_traffic_plan(fab, pattern, PERIOD)
    n = 6
    ref = _interpret(factory, pattern, n)
    for e in pattern:
        got = np.sort(plan.finish_times(*e, n))
        want = np.sort([tr.t_finish for tr in ref.links[e].done])
        assert len(got) == len(want), e
        np.testing.assert_allclose(got, want, rtol=1e-12)


@pytest.mark.parametrize("scenario", sorted(_SCENARIOS))
def test_apply_advances_schedulers_like_the_interpreter(scenario):
    """`apply` leaves every planned edge exactly where the per-event loop
    would: clock at the window horizon, completion counters advanced."""
    factory, pat_fn = _SCENARIOS[scenario]
    fab = factory()
    pattern = pat_fn(fab)
    plan = compile_traffic_plan(fab, pattern, PERIOD)
    n = 5
    rep = plan.apply(n)
    ref = _interpret(factory, pattern, n)
    assert rep.events == sum(len(ref.links[e].done) for e in pattern)
    for e in pattern:
        assert fab.links[e].now == pytest.approx(n * PERIOD, rel=1e-12)
        assert fab.links[e].n_finished == ref.links[e].n_finished
        assert fab.links[e].idle


# --------------------------------------------------------------------------- #
# the decoupled compile_plan run path == the global event loop
# --------------------------------------------------------------------------- #
def _multihop_finishes(make, src_dst, nbytes, compile_plan, windowed):
    topo = make()
    topo.compile_plan = compile_plan
    src, dst = src_dst if src_dst is not None else _storm_endpoints(topo)
    pts = submit_chunked_path(topo, "STATE", nbytes, 0.0,
                              topo.path(src, dst), quantum=1e4)
    if windowed:
        t = 0.0
        while not all(pt.finished for pt in pts) and t < 10.0:
            t += 0.05
            topo.run(until=t)
    else:
        topo.drain()
    assert all(pt.finished for pt in pts)
    return [pt.t_finish for pt in pts]


def _storm_endpoints(fab):
    dark = fab.dark_pods()[0]
    return (fab.gateway((dark + 1) % fab.n_pods),
            fab.gateway((dark - 1) % fab.n_pods))


_MULTIHOP = {
    "ring_multihop": (_ring, (0, 3), 1e5),
    "pod_crosspod": (_pods, (5, 2), 1e5),
    "storm_darkened_detour": (_storm_fabric, None, 1e5),
}


@pytest.mark.parametrize("windowed", [False, True])
@pytest.mark.parametrize("scenario", sorted(_MULTIHOP))
def test_decoupled_run_matches_event_loop_on_multihop(scenario, windowed):
    """With compile_plan set, `run` skips the global peek/min loop for
    uncoupled edges but must reproduce the exact event-ordered schedule of
    multi-hop items, windowed and drained alike."""
    make, ends, nbytes = _MULTIHOP[scenario]
    fast = _multihop_finishes(make, ends, nbytes, True, windowed)
    exact = _multihop_finishes(make, ends, nbytes, False, False)
    np.testing.assert_allclose(fast, exact, rtol=1e-12)


def test_decoupled_run_matches_bidirectional_transport_split():
    """The TopologyTransport bidirectional split (two ring directions
    pipelining independently) is identical under the decoupled path."""
    from repro.ckpt.stream import (ChunkedStream, StreamAssembler,
                                   TopologyTransport)

    def finish(compile_plan):
        topo = _ring()
        topo.compile_plan = compile_plan
        tp = TopologyTransport(topo)
        arr = np.zeros((1 << 20) // 8, dtype=np.float64)
        cs = ChunkedStream.from_array("r", arr, quantum=1 << 12)
        asm = StreamAssembler.for_stream(cs)
        ticket = tp.send(cs, 0.0, assembler=asm, src=0, dst=1,
                         policy="split")
        t = 0.0
        while not ticket.complete and t < 60.0:
            t += 0.25
            tp.run(until=t)
        assert asm.complete
        return ticket.finish_time

    assert finish(True) == pytest.approx(finish(False), rel=1e-12)


# --------------------------------------------------------------------------- #
# cache invalidation: epochs, routing tables, stale plans
# --------------------------------------------------------------------------- #
def test_path_cache_invalidates_on_topology_change():
    topo = LinkTopology(5, 1e6, quantum=1e4)
    e0 = topo.epoch
    direct = topo.path(0, 2)
    assert direct == [(0, 1), (1, 2)]
    assert topo.path(0, 2) == direct          # cache hit, same route
    topo.fail_node(1)
    assert topo.epoch > e0
    detour = topo.path(0, 2)
    assert detour == [(0, 4), (3, 4), (2, 3)]
    topo.restore_node(1)
    assert topo.path(0, 2) == direct          # re-cached after restore


def test_blocked_lookups_bypass_the_cache():
    topo = LinkTopology(5, 1e6, quantum=1e4)
    assert topo.path(0, 2) == [(0, 1), (1, 2)]
    alt = topo.path(0, 2, blocked={(0, 1)})
    assert alt == [(0, 4), (3, 4), (2, 3)]
    assert topo.path(0, 2) == [(0, 1), (1, 2)]


def test_stale_plan_refuses_to_replay():
    fab = _pods()
    plan = compile_traffic_plan(fab, _steady(fab), PERIOD)
    assert not plan.stale
    dark = next(iter(fab.live_edges()))
    fab.fail_edge(*dark)
    assert plan.stale
    with pytest.raises(PlanUnsupported, match="stale"):
        plan.apply(1)
    # restoring is ALSO a topology change: the epoch is monotone, so a plan
    # from before the failure stays stale and must be recompiled
    fab.restore_edge(*dark)
    assert plan.stale
    fresh = compile_traffic_plan(fab, _steady(fab), PERIOD)
    assert not fresh.stale
    fresh.apply(2)


def test_bandwidth_edit_invalidates_the_plan():
    fab = _ring()
    plan = compile_traffic_plan(fab, _steady(fab), PERIOD)
    fab.set_bandwidth(0, 1, 5e5)
    assert plan.stale


def test_overcommitted_period_is_unsupported():
    fab = _ring()
    pattern = {e: (("TRAIN", 2 * 1e6 * PERIOD, 0.0),)
               for e in fab.live_edges()}
    with pytest.raises(PlanUnsupported, match="overcommitted"):
        compile_traffic_plan(fab, pattern, PERIOD)


def test_dark_edge_in_pattern_is_unsupported():
    fab = _ring()
    pattern = _steady(fab)
    fab.fail_edge(0, 1)
    with pytest.raises(PlanUnsupported, match="dark"):
        compile_traffic_plan(fab, pattern, PERIOD)


def test_apply_requires_a_steady_state_boundary():
    fab = _ring()
    plan = compile_traffic_plan(fab, _steady(fab), PERIOD)
    fab.links[(0, 1)].submit("STATE", 5e4, 0.0)   # mid-flight leftover
    with pytest.raises(PlanUnsupported, match="boundary"):
        plan.apply(1)


# --------------------------------------------------------------------------- #
# cluster wiring: FabricConfig(compile_plan=True) changes nothing but speed
# --------------------------------------------------------------------------- #
def _mk_pod_cluster(tmp_path, **fabric_kw):
    import dataclasses

    from repro.configs import get_arch, reduce_for_smoke
    from repro.optim import AdamWConfig
    from repro.runtime.cluster import (ClusterConfig, FabricConfig,
                                       SimCluster)
    cfg = dataclasses.replace(reduce_for_smoke(get_arch("qwen3-0.6b")),
                              dtype="float32")
    fabric_kw.setdefault("quantum", 2048)
    fabric_kw.setdefault("pods", 2)
    fabric_kw.setdefault("dcn_latency", 1e-4)
    return SimCluster(
        cfg,
        cluster=ClusterConfig(
            dp=4, global_batch=8, seq_len=16, ckpt_dir=tmp_path / "ck",
            full_every=50,
            hp=AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=50), seed=0),
        fabric=FabricConfig(**fabric_kw))


def test_cluster_compile_plan_is_bit_identical(tmp_path):
    """A SimCluster on the compiled fast path trains, books hidden/exposed
    verdicts, and times its fabric identically to the exact path."""
    fast = _mk_pod_cluster(tmp_path / "fast", compile_plan=True)
    assert fast.topology.compile_plan
    exact = _mk_pod_cluster(tmp_path / "exact")
    assert not exact.topology.compile_plan
    lf = fast.run(3)
    le = exact.run(3)
    assert lf == le                               # bitwise-identical training
    assert fast.instant_hidden == exact.instant_hidden
    assert fast.instant_exposed == exact.instant_exposed
    assert fast.edge_instant_hidden == exact.edge_instant_hidden
    assert fast.edge_instant_exposed == exact.edge_instant_exposed
    for wf, we in zip(fast.workers, exact.workers):
        tf, te = wf.engine.last_instant_ticket, we.engine.last_instant_ticket
        assert tf.finish_time == pytest.approx(te.finish_time, rel=1e-12)
