"""simlint: per-rule positive/negative fixtures, pragma mechanics, the
SIM004 bump-deletion acceptance check, and the CLI contract.

Fixtures go through `tools.simlint.lint_text(source, rel)`, which runs
the default rule registry on a source string as if it lived at repo path
`rel` — the same engine path CI uses, minus the filesystem walk.
"""
import json
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

ROOT = Path(__file__).resolve().parents[1]
sys.path.insert(0, str(ROOT))

from tools.simlint import default_rules, lint_text  # noqa: E402
from tools.simlint.engine import run  # noqa: E402
from tools.simlint.rules.api_pin import PUBLIC_API  # noqa: E402
from tools.simlint.rules.deprecations import DeprecatedKwargsRule  # noqa: E402

SIM_REL = "src/repro/runtime/_fixture_.py"


def codes(source, rel=SIM_REL):
    return [f.code for f in lint_text(textwrap.dedent(source), rel)]


def findings(source, rel=SIM_REL):
    return lint_text(textwrap.dedent(source), rel)


def test_registry_has_all_eight_rules():
    assert [r.code for r in default_rules()] == [
        "SIM001", "SIM002", "SIM003", "SIM004",
        "SIM005", "SIM006", "SIM007", "SIM008"]


# --------------------------- SIM001 --------------------------- #
def test_sim001_flags_wall_clock_reads():
    src = """
    import time
    from time import perf_counter
    from datetime import datetime

    def beat(worker, now=None):
        now = time.monotonic() if now is None else now
        return now

    def stamp():
        return perf_counter(), datetime.now()
    """
    got = codes(src)
    assert got.count("SIM001") == 3


def test_sim001_negative_and_allowlist():
    clean = """
    def beat(worker, now):
        return now
    """
    assert codes(clean) == []
    walled = """
    import time

    def cli_timer():
        return time.monotonic()
    """
    # host-side launch code is allowlisted; test code is out of scope
    assert codes(walled, rel="src/repro/launch/_fixture_.py") == []
    assert codes(walled, rel="tests/_fixture_.py") == []
    assert "SIM001" in codes(walled)


# --------------------------- SIM002 --------------------------- #
def test_sim002_flags_global_rng_draws():
    src = """
    import random
    import numpy as np

    def storm():
        random.shuffle([1, 2])
        x = np.random.rand(3)
        rng = np.random.default_rng()
        return x, rng
    """
    assert codes(src).count("SIM002") == 3


def test_sim002_negative_seeded_generators():
    src = """
    import random
    import numpy as np

    def storm(seed):
        rng = np.random.default_rng(seed)
        r = random.Random(seed)
        return rng.random(), r.random()
    """
    assert codes(src) == []


# --------------------------- SIM003 --------------------------- #
def test_sim003_flags_mutable_defaults():
    src = """
    from dataclasses import dataclass

    @dataclass
    class StragglerPolicy:
        threshold: float = 1.5

    @dataclass
    class ReliabilityConfig:
        straggler: StragglerPolicy = StragglerPolicy()

    def observe(samples=[], policy=StragglerPolicy()):
        samples.append(policy)
    """
    # the shared dataclass field, the [] default, and the shared policy
    # default — the PR 7 bug shape twice over
    assert codes(src).count("SIM003") == 3


def test_sim003_negative_factories_and_frozen():
    src = """
    from dataclasses import dataclass, field

    @dataclass(frozen=True)
    class Frozen:
        x: int = 0

    @dataclass
    class Cfg:
        items: list = field(default_factory=list)
        frozen: Frozen = Frozen()

    def observe(samples=None, cfg=Frozen()):
        return samples, cfg
    """
    assert codes(src) == []


# --------------------------- SIM004 --------------------------- #
def test_sim004_flags_unbumped_mutation_and_missed_path():
    src = """
    class LinkTopology:
        def _bump_epoch(self):
            self._epoch += 1

        def fail_node(self, n):
            self.dark_nodes.add(n)

        def set_bw(self, u, v, bw, only_up=True):
            if only_up and (u, v) not in self.links:
                return
            self.links[(u, v)].bw = bw
            if bw > 0:
                self._bump_epoch()
    """
    got = codes(src)
    assert got.count("SIM004") == 2    # fail_node + the bw>0-only branch


def test_sim004_negative_every_path_bumps():
    src = """
    class LinkTopology:
        def __init__(self):
            self.dark_nodes = set()       # construction is exempt

        def _bump_epoch(self):
            self._epoch += 1

        def fail_node(self, n):
            self.dark_nodes.add(n)
            self._bump_epoch()

        def set_bw(self, u, v, bw):
            if (u, v) in self.links:
                self.links[(u, v)].bw = bw
            self._bump_epoch()

        def read_only(self):
            return sorted(self.dark_nodes)
    """
    assert codes(src) == []


def test_sim004_ignores_non_topology_classes():
    src = """
    class Ledger:
        def add(self, n):
            self.dark_nodes = n
    """
    assert codes(src) == []


MUTATING_METHODS = ("fail_node", "restore_node", "fail_edge",
                    "restore_edge", "set_bandwidth")


@pytest.mark.parametrize("method", MUTATING_METHODS)
def test_sim004_acceptance_deleting_real_bump_fails(method):
    """Acceptance: remove `self._bump_epoch()` from any topology-mutating
    method of the REAL src/repro/core/lccl.py and SIM004 must fire."""
    import ast

    source = (ROOT / "src" / "repro" / "core" / "lccl.py").read_text()
    tree = ast.parse(source)
    fn = next(n for cls in ast.walk(tree)
              if isinstance(cls, ast.ClassDef)
              and cls.name in ("LinkTopology", "PodFabric")
              for n in cls.body
              if isinstance(n, ast.FunctionDef) and n.name == method)
    lines = source.splitlines()
    bump_lines = [i for i in range(fn.lineno, fn.end_lineno + 1)
                  if "_bump_epoch()" in lines[i - 1]]
    assert bump_lines, f"{method} has no _bump_epoch call to delete?"
    for i in bump_lines:
        indent = len(lines[i - 1]) - len(lines[i - 1].lstrip())
        lines[i - 1] = " " * indent + "pass"
    mutant = "\n".join(lines)
    got = lint_text(mutant, rel="src/repro/core/_lccl_mutant_.py")
    assert any(f.code == "SIM004" and method in f.message for f in got), \
        f"SIM004 missed the deleted bump in {method}"


def test_sim004_real_lccl_is_clean():
    source = (ROOT / "src" / "repro" / "core" / "lccl.py").read_text()
    got = lint_text(source, rel="src/repro/core/lccl.py")
    assert [f for f in got if f.code == "SIM004"] == []


# --------------------------- SIM005 --------------------------- #
def test_sim005_flags_float_clock_equality():
    src = """
    def race(t_finish, t_start, dt):
        if t_finish == t_start:
            return True
        return dt != 0.5
    """
    assert codes(src).count("SIM005") == 2


def test_sim005_negative_sentinels_and_ordering():
    src = """
    def race(t, until, deadline, tier):
        if until == float("inf") or t == 0:
            return True
        if tier == "dcn" or t == tier:
            return False
        return t <= deadline
    """
    assert codes(src) == []


# --------------------------- SIM006 --------------------------- #
def test_sim006_flags_set_and_dict_iteration_into_sinks():
    src = """
    def storm(sched, failed: set, links: dict):
        for n in failed:
            sched.submit("FAIL", n)
        return [sched.submit("X", e) for e in links.items()]
    """
    assert codes(src).count("SIM006") == 2


def test_sim006_negative_sorted_or_no_sink():
    src = """
    def storm(sched, failed: set, log):
        for n in sorted(failed):
            sched.submit("FAIL", n)
        out = []
        for n in failed:
            out = out + [n]        # accumulation, not an event sink
        return out
    """
    assert codes(src) == []


# --------------------------- SIM007 --------------------------- #
def test_sim007_flags_legacy_kwargs_everywhere():
    src = """
    def build():
        clu = SimCluster(dp=4, link_bw=1e9)
        clu.recover(hardware=True)
        return SimCluster.from_kwargs(dp=2)
    """
    assert codes(src, rel="tests/_fixture_.py").count("SIM007") == 3
    assert codes(src).count("SIM007") == 3


def test_sim007_negative_new_api():
    src = """
    def build(cfg, fab):
        clu = SimCluster(cluster=cfg, fabric=fab)
        clu.recover(faults=None)
        return clu
    """
    assert codes(src, rel="tests/_fixture_.py") == []


# --------------------------- SIM008 --------------------------- #
def test_sim008_real_init_matches_pin():
    source = (ROOT / "src" / "repro" / "__init__.py").read_text()
    assert lint_text(source, rel="src/repro/__init__.py") == []


def test_sim008_flags_drift_and_missing_exports():
    names = [n for n in PUBLIC_API if n != "SimCluster"] + ["RogueExport"]
    source = "__all__ = %r\n_EXPORTS = %r\n" % (
        names, {n: "repro.x" for n in PUBLIC_API})
    got = lint_text(source, rel="src/repro/__init__.py")
    msgs = "\n".join(f.message for f in got)
    assert any(f.code == "SIM008" for f in got)
    assert "SimCluster" in msgs          # pinned but not declared
    assert "RogueExport" in msgs         # declared but not pinned


# ------------------------ pragma mechanics ------------------------ #
def test_pragma_with_justification_suppresses():
    src = """
    import time

    def f():
        return time.monotonic()  # simlint: disable=SIM001 -- fixture
    """
    assert codes(src) == []


def test_pragma_without_justification_is_sim000():
    src = """
    import time

    def f():
        return time.monotonic()  # simlint: disable=SIM001
    """
    assert codes(src) == ["SIM000"]


def test_pragma_in_comment_block_above_statement():
    src = """
    import time

    def f():
        # simlint: disable=SIM001 -- the justification may span a
        # multi-line comment block directly above the statement
        return time.monotonic()
    """
    assert codes(src) == []


def test_pragma_mentioned_in_docstring_is_not_a_suppression():
    src = '''
    import time

    def f():
        """Docs may discuss `# simlint: disable=SIM001 -- like so`."""
        return time.monotonic()
    '''
    assert codes(src) == ["SIM001"]


def test_legacy_deprecated_ok_pragma_suppresses_sim007():
    src = """
    def build():
        return SimCluster(dp=4)  # deprecated-ok: shim under test
    """
    assert codes(src, rel="tests/_fixture_.py") == []


def test_legacy_pragma_reported_once_per_file(tmp_path):
    mod = tmp_path / "src" / "mod.py"
    mod.parent.mkdir(parents=True)
    mod.write_text("a = SimCluster(dp=1)  # deprecated-ok: one\n"
                   "b = SimCluster(dp=2)  # deprecated-ok: two\n")
    report = run(["src"], [DeprecatedKwargsRule()], root=tmp_path)
    assert report.findings == []
    assert len(report.suppressed) == 2
    assert report.legacy_pragma_files == ["src/mod.py"]


# ----------------- PR 7 bug shapes stay machine-caught ----------------- #
def test_pr7_wall_clock_heartbeat_bug_is_flagged():
    src = """
    import time

    class StateController:
        def beat(self, worker, now=None):
            self.heartbeats.beat(
                worker, time.monotonic() if now is None else now)
    """
    assert "SIM001" in codes(src, rel="src/repro/core/_fixture_.py")


def test_pr7_shared_policy_default_bug_is_flagged():
    src = """
    from dataclasses import dataclass

    @dataclass
    class StragglerPolicy:
        relative_threshold: float = 1.45

    class ReliabilityController:
        def __init__(self, straggler=StragglerPolicy()):
            self.straggler = straggler
    """
    assert "SIM003" in codes(src)


# --------------------------- CLI contract --------------------------- #
def test_cli_src_repro_sweep_is_clean_and_writes_json(tmp_path):
    out = tmp_path / "simlint.json"
    proc = subprocess.run(
        [sys.executable, "-m", "tools.simlint", "src/repro",
         "--json", str(out)],
        cwd=ROOT, capture_output=True, text=True)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    data = json.loads(out.read_text())
    assert data["tool"] == "simlint"
    assert data["summary"]["findings"] == 0
    # every suppression that survives in-tree must say why
    assert all(s.get("justification") for s in data["suppressed"])


def test_cli_list_rules_names_all_codes():
    proc = subprocess.run(
        [sys.executable, "-m", "tools.simlint", "--list-rules"],
        cwd=ROOT, capture_output=True, text=True)
    assert proc.returncode == 0
    for code in ("SIM001", "SIM002", "SIM003", "SIM004",
                 "SIM005", "SIM006", "SIM007", "SIM008"):
        assert code in proc.stdout


def test_cli_select_unknown_code_is_usage_error():
    proc = subprocess.run(
        [sys.executable, "-m", "tools.simlint", "src/repro",
         "--select", "SIM999"],
        cwd=ROOT, capture_output=True, text=True)
    assert proc.returncode == 2
