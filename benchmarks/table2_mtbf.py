"""Paper Table 2: cluster failure probability P_x at a given MTBF horizon and
the relative MFU loss (per-30-min CKPT, MTTR 1000 s) — plus MEASURED rows:
a seeded exponential failure trace per horizon is fed through the
reliability loop's estimators (`observed_mtbf`, `adapted_full_interval`),
reporting the MTBF the loop would actually observe, the Young–Daly cadence
it adapts to, and the resulting MFU loss vs the fixed 30-min schedule."""
import numpy as np

from benchmarks.common import row
from repro.core.analytic import cluster_failure_probability, mfu_loss
from repro.runtime.reliability import adapted_full_interval, observed_mtbf

CKPT_COST_S = 30.0
MTTR_S = 1000.0


def run(tiny: bool = False) -> None:
    rng = np.random.default_rng(0)
    n_failures = 32 if tiny else 256
    for mtbf_h in (3, 6, 9, 12):
        mtbf_s = mtbf_h * 3600.0
        p16k = cluster_failure_probability(16384, mtbf_h)
        p65k = cluster_failure_probability(65536, mtbf_h)
        loss = mfu_loss(t_ckpt=0.0, t_interval=1800.0, mttr=MTTR_S,
                        mtbf=mtbf_s)
        row(f"table2/mtbf{mtbf_h}h/P_16384", 0.0, f"{p16k:.2f}")
        row(f"table2/mtbf{mtbf_h}h/P_65536", 0.0, f"{p65k:.2f}")
        row(f"table2/mtbf{mtbf_h}h/rel_mfu_loss", 0.0, f"{loss.total:.2f}")

        # measured: what the reliability loop observes from a seeded
        # exponential failure trace at this horizon, and the checkpoint
        # cadence it adapts to (Young-Daly on the OBSERVED mtbf)
        times = np.cumsum(rng.exponential(mtbf_s, size=n_failures))
        mtbf_obs = observed_mtbf(list(times))
        interval = adapted_full_interval(mtbf_obs, CKPT_COST_S)
        loss_adapted = mfu_loss(t_ckpt=CKPT_COST_S, t_interval=interval,
                                mttr=MTTR_S, mtbf=mtbf_s)
        row(f"table2/mtbf{mtbf_h}h/observed_mtbf_s", 0.0,
            round(mtbf_obs, 3))
        row(f"table2/mtbf{mtbf_h}h/adapted_interval_s", 0.0,
            round(interval, 3))
        row(f"table2/mtbf{mtbf_h}h/rel_mfu_loss_adapted", 0.0,
            f"{loss_adapted.total:.4f}")


if __name__ == "__main__":
    from benchmarks.common import bench_main
    bench_main(run)
