"""Paper Table 2: cluster failure probability P_x at a given MTBF horizon and
the relative MFU loss (per-30-min CKPT, MTTR 1000 s)."""
from benchmarks.common import row
from repro.core.analytic import cluster_failure_probability, mfu_loss


def run() -> None:
    for mtbf_h in (3, 6, 9, 12):
        p16k = cluster_failure_probability(16384, mtbf_h)
        p65k = cluster_failure_probability(65536, mtbf_h)
        loss = mfu_loss(t_ckpt=0.0, t_interval=1800.0, mttr=1000.0,
                        mtbf=mtbf_h * 3600.0)
        row(f"table2/mtbf{mtbf_h}h/P_16384", 0.0, f"{p16k:.2f}")
        row(f"table2/mtbf{mtbf_h}h/P_65536", 0.0, f"{p65k:.2f}")
        row(f"table2/mtbf{mtbf_h}h/rel_mfu_loss", 0.0, f"{loss.total:.2f}")


if __name__ == "__main__":
    run()
