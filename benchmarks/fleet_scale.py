"""Fleet-scale fabric benchmark: a 4096-node / 64-pod PodFabric over a
multi-day seeded failure trace, in single-digit wall-clock seconds.

The steady-state traffic (per-edge TRAIN allreduce + quantum-chunked STATE
instant shards, every `--period` seconds) is compiled once per topology
epoch into a `TrafficPlan` (`repro/core/plan.py`) and replayed as numpy
algebra; each seeded storm crosses on the exact per-event path (degraded
fabric, live edges only) for `--storm-steps` windows, then the storm is
repaired and the plan recompiled. Timings on both paths are the event
clock's own (tests/test_traffic_plan.py property-tests the equivalence).

Rows (`BENCH_fleet_scale.json`, uploaded by the CI `fleet-bench` job):
`wall_s` is wall-clock and **gated** by `tools/bench_trend.py` (>20%
slowdown fails); `events` counts the interpreter completions the compiled
plan batched away plus the exact-path completions actually processed;
`events_per_wall_s`, `sim_s_per_wall_s`, and `peak_rss_mb` are the
headline throughput/footprint numbers.

Usage:
    python -m benchmarks.fleet_scale [--tiny] [--json OUT] [--budget-s S]
        [--days D] [--seed N]

`--budget-s` makes the benchmark itself the hard wall-clock gate: exit 1
when the measured wall time exceeds the budget (the CI job's failure mode).
"""
from __future__ import annotations

import argparse
import resource
import sys
import time
from dataclasses import dataclass

import numpy as np

from benchmarks.common import dump_rows, row
from repro.core.lccl import PodFabric, inject_storm
from repro.core.plan import compile_traffic_plan, steady_state_pattern
from repro.train.step import hierarchical_step_traffic


@dataclass(frozen=True)
class FleetSpec:
    label: str
    n_pods: int
    pod_size: int
    days: float
    n_storms: int
    grad_bytes: float = 2e11           # ~50B-param float32 gradient
    state_bytes: float = float(1 << 30)  # 1 GiB instant shard per worker
    period: float = 10.0               # modeled seconds per training step
    storm_steps: int = 2               # exact-path windows per storm
    ici_bw: float = 50e9
    dcn_bw: float = 5e9
    dcn_latency: float = 1e-3
    quantum: float = float(64 << 20)   # STATE chunk grain on the fleet

    @property
    def nodes(self) -> int:
        return self.n_pods * self.pod_size

    @property
    def n_steps(self) -> int:
        return int(self.days * 86400 / self.period)


FULL = FleetSpec("4096", n_pods=64, pod_size=64, days=3.0, n_storms=10)
TINY = FleetSpec("tiny", n_pods=4, pod_size=8, days=0.5, n_storms=3)


def _submit_pattern(fab: PodFabric, pattern, t: float) -> None:
    for e, subs in pattern.items():
        sch = fab.links[e]
        for kind, size, off in subs:
            sch.submit(kind, size, t + off)


def run_fleet(spec: FleetSpec, seed: int = 0) -> dict:
    """Simulate `spec.days` of fleet traffic with `spec.n_storms` seeded
    storms; returns the aggregate stats the rows report."""
    t_wall0 = time.perf_counter()
    fab = PodFabric(spec.n_pods, spec.pod_size, ici_bw=spec.ici_bw,
                    dcn_bw=spec.dcn_bw, dcn_latency=spec.dcn_latency,
                    quantum=spec.quantum)
    fab.compile_plan = True            # exact windows skip the global loop
    profile = hierarchical_step_traffic(spec.grad_bytes, spec.n_pods,
                                        spec.pod_size,
                                        state_bytes=spec.state_bytes)
    rng = np.random.default_rng(seed)
    lo, hi = 1, max(spec.n_steps - spec.storm_steps - 1, 2)
    storm_at = sorted(set(int(s) for s in rng.integers(lo, hi,
                                                       spec.n_storms)))
    events = 0
    exact_events = 0
    recompiles = 0
    t_sim = 0.0
    step = 0
    pattern = steady_state_pattern(fab, profile)
    plan = compile_traffic_plan(fab, pattern, spec.period)

    def replay(n: int) -> None:
        nonlocal events, t_sim, step
        if n <= 0:
            return
        rep = plan.apply(n, t0=t_sim)
        events += rep.events
        t_sim = rep.t_end
        step += n

    for s in storm_at:
        replay(s - step)
        report = inject_storm(fab, seed=seed * 1009 + s, pods=1,
                              edge_failures=2)
        # degraded segment: live edges only, exact event-driven windows
        storm_pattern = steady_state_pattern(fab, profile)
        before = sum(sch.n_finished for sch in fab.links.values())
        for _ in range(spec.storm_steps):
            _submit_pattern(fab, storm_pattern, t_sim)
            fab.run(until=t_sim + spec.period)
            t_sim += spec.period
            step += 1
        exact_events += sum(sch.n_finished
                            for sch in fab.links.values()) - before
        # repair + recompile: the epoch moved, the old plan is stale
        for p in report.pods:
            fab.restore_pod(p)
        for e in report.edges:
            fab.restore_edge(*e)
        assert plan.stale
        pattern = steady_state_pattern(fab, profile)
        plan = compile_traffic_plan(fab, pattern, spec.period)
        recompiles += 1
    replay(spec.n_steps - step)

    wall = time.perf_counter() - t_wall0
    rss_kb = resource.getrusage(resource.RUSAGE_SELF).ru_maxrss
    return {
        "wall_s": wall,
        "sim_s": t_sim,
        "events": events + exact_events,
        "exact_events": exact_events,
        "storms": len(storm_at),
        "recompiles": recompiles,
        "steps": step,
        "peak_rss_mb": rss_kb / 1024.0,
    }


def emit_rows(spec: FleetSpec, stats: dict) -> None:
    pre = f"fleet/{spec.label}"
    wall = stats["wall_s"]
    row(f"{pre}/wall_s", wall * 1e6, round(wall, 3))
    row(f"{pre}/nodes", 0.0, spec.nodes)
    row(f"{pre}/sim_days", 0.0, round(stats["sim_s"] / 86400.0, 4))
    row(f"{pre}/steps", 0.0, stats["steps"])
    row(f"{pre}/storms", 0.0, stats["storms"])
    row(f"{pre}/events", 0.0, stats["events"])
    row(f"{pre}/exact_events", 0.0, stats["exact_events"])
    row(f"{pre}/events_per_wall_s", 0.0,
        round(stats["events"] / max(wall, 1e-9)))
    row(f"{pre}/sim_s_per_wall_s", 0.0,
        round(stats["sim_s"] / max(wall, 1e-9)))
    row(f"{pre}/peak_rss_mb", 0.0, round(stats["peak_rss_mb"], 1))


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--json", default=None, metavar="OUT",
                    help="also dump the rows as a JSON artifact")
    ap.add_argument("--tiny", action="store_true",
                    help="smoke-scale fleet (CI fleet-bench job)")
    ap.add_argument("--budget-s", type=float, default=None, metavar="S",
                    help="hard wall-clock budget: exit 1 when exceeded")
    ap.add_argument("--days", type=float, default=None,
                    help="override the simulated trace length")
    ap.add_argument("--seed", type=int, default=0,
                    help="failure-trace seed")
    args = ap.parse_args(argv)
    spec = TINY if args.tiny else FULL
    if args.days is not None:
        spec = FleetSpec(**{**spec.__dict__, "days": args.days})
    stats = run_fleet(spec, seed=args.seed)
    emit_rows(spec, stats)
    if args.json:
        print(f"wrote {dump_rows(args.json)}")
    if args.budget_s is not None and stats["wall_s"] > args.budget_s:
        print(f"fleet_scale: FAIL — wall {stats['wall_s']:.2f}s exceeds "
              f"the {args.budget_s:.0f}s budget", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
