"""Benchmark driver: one module per paper table/figure.
Prints ``name,us_per_call,derived`` CSV rows (see benchmarks/common.py)."""
import sys
import traceback


def main() -> None:
    from benchmarks import (fig4_ckpt_overhead, fig5_mfu, fig7_lccl_allreduce,
                            fig8_net_init, fig9_fcr, fig10_controller,
                            table1_data_io, table2_mtbf, table5_failover,
                            table6_recovery_prob, table7_dp_scaling)
    modules = [table1_data_io, table2_mtbf, fig4_ckpt_overhead,
               table5_failover, fig5_mfu, table6_recovery_prob,
               table7_dp_scaling, fig7_lccl_allreduce, fig8_net_init,
               fig9_fcr, fig10_controller]
    print("name,us_per_call,derived")
    failures = []
    for mod in modules:
        try:
            mod.run()
        except Exception:
            traceback.print_exc()
            failures.append(mod.__name__)
    if failures:
        print(f"FAILED: {failures}", file=sys.stderr)
        raise SystemExit(1)


if __name__ == "__main__":
    main()
