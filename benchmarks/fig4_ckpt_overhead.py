"""Paper Fig. 4: per-iteration checkpoint overhead by engine.

Two parts:
  (a) REAL measurement: per-step wall time of a smoke-scale training loop in
      the cluster simulator with instant checkpointing ON vs OFF (the razor +
      ring-copy overhead FFTrainer adds to each iteration).
  (b) Engine model at paper scale: overhead per iteration for vanilla
      Megatron/DeepSpeed (full CKPT over storage), Gemini (CPU-memory, every
      minute), FFTrainer (razor + idle links) using the paper's bandwidths.
"""
import dataclasses
from pathlib import Path

from benchmarks.common import row, timeit
from repro.configs import get_arch, reduce_for_smoke
from repro.core.analytic import ckpt_time_full
from repro.models import param_count


def _measured(tmp: Path, tiny: bool = False) -> None:
    # NOTE: the with-ckpt arm now includes the StateStream bookkeeping the
    # simulator does in-process (shard serialization + per-chunk CRC32), so
    # overhead_frac upper-bounds the paper's razor+ring-copy cost; on real
    # hardware the permute is an in-step collective the compiler overlaps.
    from repro.runtime.cluster import ClusterConfig, SimCluster
    cfg = dataclasses.replace(reduce_for_smoke(get_arch("qwen3-0.6b")),
                              dtype="float32")
    base, inst = [], []
    for with_ckpt in (False, True):
        clu = SimCluster(cfg, cluster=ClusterConfig(
            dp=4, global_batch=8, seq_len=16,
            ckpt_dir=tmp / f"c{with_ckpt}", full_every=10**9))
        if not with_ckpt:
            clu._shard_and_backup = lambda: None  # disable instant ckpt
        warm, meas = (1, 2) if tiny else (3, 5)
        clu.run(warm)  # warmup + compile
        import time
        t0 = time.perf_counter()
        clu.run(meas)
        dt = (time.perf_counter() - t0) / meas * 1e6
        (inst if with_ckpt else base).append(dt)
    row("fig4/measured/per_iter_no_ckpt_us", base[0], "")
    row("fig4/measured/per_iter_instant_ckpt_us", inst[0], "")
    row("fig4/measured/overhead_frac", 0.0,
        f"{(inst[0] - base[0]) / base[0]:.4f}")
    row("fig4/measured/instant_hidden_iters", 0.0, clu.instant_hidden)
    row("fig4/measured/instant_exposed_iters", 0.0, clu.instant_exposed)
    row("fig4/measured/state_chunks_streamed", 0.0,
        clu.transport.chunks_delivered)


def _modeled() -> None:
    # paper measurement: async CKPT in a background thread inflates the
    # iteration ~7x while I/O is active (GPU-host PCIe contention, (3.1)) —
    # the dominant term, calibrated as CONTENTION
    disk, nic, CONTENTION = 2e9, 25e9, 7.0
    per_iter = {"gpt2-2.7b": 21.0, "llama3-8b": 11.0,
                "llama2-13b": 36.0, "llama3-70b": 77.0}
    dps = {"gpt2-2.7b": 16, "llama3-8b": 4, "llama2-13b": 4, "llama3-70b": 2}
    pts = {"gpt2-2.7b": 8, "llama3-8b": 32, "llama2-13b": 32,
           "llama3-70b": 64}
    for arch, t_iter in per_iter.items():
        phi = param_count(get_arch(arch)) / pts[arch]  # params per GPU
        t_full = ckpt_time_full(phi, nic, disk)        # megatron-style
        # contention-inflated overhead amortized over the 5-iteration period
        over = (t_full * (CONTENTION - 1)) / (5 * t_iter)
        row(f"fig4/model/{arch}/megatron_overhead", 0.0, f"{over:.3f}")
        # gemini: CPU-memory ckpt each minute, mild contention
        t_gem = 2 * 16 * phi / 20e9                    # host copy at 20 GB/s
        row(f"fig4/model/{arch}/gemini_overhead", 0.0,
            f"{t_gem * 0.5 / 60.0:.3f}")
        # fftrainer: razor shard as chunked STATE traffic sharing the NIC
        # with the gradient allreduce (TRAIN preempts) — overhead is the
        # schedule's spill past the compute boundary, not a closed form
        over_fft = _fftrainer_transport_overhead(
            phi, dps[arch], t_iter, nic, n_iters=5)
        row(f"fig4/model/{arch}/fftrainer_overhead", 0.0,
            f"{over_fft + 0.01:.3f}")


def _fftrainer_transport_overhead(phi: float, dp: int, t_iter: float,
                                  nic: float, n_iters: int = 5) -> float:
    """Drive n_iters of TRAIN (bf16 gradient ring-allreduce) + STATE (razor
    shard chunks) through one LinkScheduler; the exposed overhead is how far
    the last iteration's checkpoint chunks spill past the final boundary."""
    from repro.core.lccl import LinkScheduler, submit_chunked

    sched = LinkScheduler(nic, quantum=16 << 20)
    razor_bytes = 12.0 * phi / dp                 # Adam unique shard / DP
    wire = 2.0 * (dp - 1) / dp * 2.0 * phi        # bf16 grads on the ring
    state_transfers = []
    for i in range(n_iters):
        t0 = i * t_iter
        sched.submit("TRAIN", wire, t0)
        state_transfers.extend(submit_chunked(sched, "STATE", razor_bytes, t0))
    sched.drain()
    finish = max(tr.t_finish for tr in state_transfers)
    return max(finish - n_iters * t_iter, 0.0) / (n_iters * t_iter)


def run(tmp: Path = Path("/tmp/repro_bench_fig4"), tiny: bool = False) -> None:
    _measured(tmp, tiny=tiny)
    _modeled()


if __name__ == "__main__":
    from benchmarks.common import bench_main
    bench_main(run)
