"""Paper Fig. 4: per-iteration checkpoint overhead by engine.

Two parts:
  (a) REAL measurement: per-step wall time of a smoke-scale training loop in
      the cluster simulator with instant checkpointing ON vs OFF (the razor +
      ring-copy overhead FFTrainer adds to each iteration).
  (b) Engine model at paper scale: overhead per iteration for vanilla
      Megatron/DeepSpeed (full CKPT over storage), Gemini (CPU-memory, every
      minute), FFTrainer (razor + idle links) using the paper's bandwidths.
"""
import dataclasses
from pathlib import Path

from benchmarks.common import row, timeit
from repro.configs import get_arch, reduce_for_smoke
from repro.core.analytic import ckpt_time_full, ckpt_time_razor
from repro.models import param_count


def _measured(tmp: Path) -> None:
    from repro.runtime.cluster import SimCluster
    cfg = dataclasses.replace(reduce_for_smoke(get_arch("qwen3-0.6b")),
                              dtype="float32")
    base, inst = [], []
    for with_ckpt in (False, True):
        clu = SimCluster(cfg, dp=4, global_batch=8, seq_len=16,
                         ckpt_dir=tmp / f"c{with_ckpt}", full_every=10**9)
        if not with_ckpt:
            clu._shard_and_backup = lambda: None  # disable instant ckpt
        clu.run(3)  # warmup + compile
        import time
        t0 = time.perf_counter()
        clu.run(5)
        dt = (time.perf_counter() - t0) / 5 * 1e6
        (inst if with_ckpt else base).append(dt)
    row("fig4/measured/per_iter_no_ckpt_us", base[0], "")
    row("fig4/measured/per_iter_instant_ckpt_us", inst[0], "")
    row("fig4/measured/overhead_frac", 0.0,
        f"{(inst[0] - base[0]) / base[0]:.4f}")


def _modeled() -> None:
    # paper measurement: async CKPT in a background thread inflates the
    # iteration ~7x while I/O is active (GPU-host PCIe contention, (3.1)) —
    # the dominant term, calibrated as CONTENTION
    disk, nic, CONTENTION = 2e9, 25e9, 7.0
    per_iter = {"gpt2-2.7b": 21.0, "llama3-8b": 11.0,
                "llama2-13b": 36.0, "llama3-70b": 77.0}
    dps = {"gpt2-2.7b": 16, "llama3-8b": 4, "llama2-13b": 4, "llama3-70b": 2}
    pts = {"gpt2-2.7b": 8, "llama3-8b": 32, "llama2-13b": 32,
           "llama3-70b": 64}
    for arch, t_iter in per_iter.items():
        phi = param_count(get_arch(arch)) / pts[arch]  # params per GPU
        t_full = ckpt_time_full(phi, nic, disk)        # megatron-style
        # contention-inflated overhead amortized over the 5-iteration period
        over = (t_full * (CONTENTION - 1)) / (5 * t_iter)
        row(f"fig4/model/{arch}/megatron_overhead", 0.0, f"{over:.3f}")
        # gemini: CPU-memory ckpt each minute, mild contention
        t_gem = 2 * 16 * phi / 20e9                    # host copy at 20 GB/s
        row(f"fig4/model/{arch}/gemini_overhead", 0.0,
            f"{t_gem * 0.5 / 60.0:.3f}")
        # fftrainer: razor shard rides idle links; hidden iff FCR >= 1
        t_razor = ckpt_time_razor(phi / dps[arch], nic)
        row(f"fig4/model/{arch}/fftrainer_overhead", 0.0,
            f"{max(t_razor - t_iter, 0.0) / t_iter + 0.01:.3f}")


def run(tmp: Path = Path("/tmp/repro_bench_fig4")) -> None:
    _measured(tmp)
    _modeled()


if __name__ == "__main__":
    run()
