"""Paper Fig. 5: relative MFU loss vs cluster MTBF for four failover systems
(per-iteration / per-minute / per-30-min / per-hour CKPT intervals)."""
from benchmarks.common import row
from repro.core.analytic import mfu_loss

SYSTEMS = {
    # (ckpt interval s, ckpt overhead s, MTTR s)
    "fftrainer": (12.0, 0.05, 29.0),      # per-iteration, ~free, fast failover
    "gemini": (60.0, 0.5, 900.0),         # per-minute, fast ckpt, slow restart
    "megatron": (1800.0, 120.0, 1000.0),  # per-30-min, heavy ckpt
    "megascale": (3600.0, 60.0, 300.0),   # per-hour, fast restart
}


def run() -> None:
    for mtbf_h in (2, 3, 4, 6):
        for name, (t_i, t_c, mttr) in SYSTEMS.items():
            l = mfu_loss(t_c, t_i, mttr, mtbf_h * 3600.0)
            row(f"fig5/mtbf{mtbf_h}h/{name}/mfu_loss", 0.0,
                f"{l.total:.4f}")
            row(f"fig5/mtbf{mtbf_h}h/{name}/rollback_part", 0.0,
                f"{l.rollback:.4f}")


if __name__ == "__main__":
    run()
