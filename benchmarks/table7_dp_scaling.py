"""Paper Table 7: CKPT-engine cost vs data-parallel degree (GPT-2 2.7B).
Measured on the cluster simulator: per-iteration time with instant
checkpointing on/off at dp = 2,4,8 (fixed per-worker batch, like the paper),
plus the razor's unique-bytes scaling (the mechanism behind the flat cost)."""
import dataclasses
import time
from pathlib import Path

from benchmarks.common import row
from repro.configs import get_arch, reduce_for_smoke
from repro.core.razor import razor_bytes_formula
from repro.models import param_count
from repro.runtime.cluster import ClusterConfig, SimCluster


def run(tmp: Path = Path("/tmp/repro_bench_t7")) -> None:
    cfg = dataclasses.replace(reduce_for_smoke(get_arch("gpt2-2.7b")),
                              dtype="float32")
    for dp in (2, 4, 8):
        times = {}
        for with_ckpt in (False, True):
            clu = SimCluster(cfg, cluster=ClusterConfig(
                dp=dp, global_batch=2 * dp, seq_len=16,
                ckpt_dir=tmp / f"dp{dp}_{with_ckpt}", full_every=10**9))
            if not with_ckpt:
                clu._shard_and_backup = lambda: None
            clu.run(2)
            t0 = time.perf_counter()
            clu.run(5)
            times[with_ckpt] = (time.perf_counter() - t0) / 5
        slowdown = times[True] / times[False] - 1.0
        row(f"table7/dp{dp}/fftrainer_slowdown", times[True] * 1e6,
            f"{max(slowdown, 0.0):.4f}")
    # razor scaling at paper scale
    phi = param_count(get_arch("gpt2-2.7b"))
    for dp in (2, 4, 8, 16):
        row(f"table7/dp{dp}/razor_unique_gb", 0.0,
            f"{razor_bytes_formula(phi, dp) / 1e9:.2f}")


if __name__ == "__main__":
    run()
