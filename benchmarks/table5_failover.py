"""Paper Table 5: failover breakdown (seconds) Gemini-style baseline vs
FFTrainer at 16 and 128 GPUs — FFTrainer's overlapped timeline measured on
the runtime simulator with real state movement."""
import dataclasses
from pathlib import Path

from benchmarks.common import row
from repro.configs import get_arch, reduce_for_smoke
from repro.runtime.failover import baseline_timeline, fftrainer_timeline


def run(tmp: Path = Path("/tmp/repro_bench_t5"), tiny: bool = False) -> None:
    state_bytes = 13e9 / 4     # LLaMA2-13B-ish unique shard per worker
    for n in ((16,) if tiny else (16, 128)):
        base = baseline_timeline(n, state_bytes)
        fft = fftrainer_timeline(n, state_bytes)
        for k in ("detection", "pod_creation", "dependency_install"):
            row(f"table5/{n}gpu/baseline/{k}", 0.0, f"{base[k]:.1f}")
            row(f"table5/{n}gpu/fftrainer/{k}", 0.0, f"{fft[k]:.1f}")
        row(f"table5/{n}gpu/baseline/state_recovery", 0.0,
            f"{base['network_recovery'] + base['state_recovery']:.1f}")
        row(f"table5/{n}gpu/fftrainer/state_recovery", 0.0,
            f"{fft['network_and_state']:.1f}")
        row(f"table5/{n}gpu/baseline/total", 0.0, f"{base['total']:.1f}")
        row(f"table5/{n}gpu/fftrainer/total", 0.0, f"{fft['total']:.1f}")
        row(f"table5/{n}gpu/reduction", 0.0,
            f"{1 - fft['total'] / base['total']:.3f}")
        # recovery while healthy DP groups keep training: their allreduce
        # preempts the recovery chunks on the shared link (§5.3) — the
        # timeline stretches by exactly the scheduler's answer
        busy = [(0.1 * i, 20e9) for i in range(10)]   # saturating allreduce
        fftp = fftrainer_timeline(n, state_bytes, train_traffic=busy)
        row(f"table5/{n}gpu/fftrainer/state_recovery_preempted", 0.0,
            f"{fftp['network_and_state']:.1f}")
        # per-edge fabric: the recovery fetch rides a multi-hop ring path
        # with one throttled hotspot edge — the timeline is bottlenecked by
        # exactly that edge's residual bandwidth (ISSUE 2)
        from repro.core.lccl import LinkTopology
        topo = LinkTopology(min(n, 16), 50e9, quantum=4 << 20)
        topo.set_bandwidth(1, 2, 5e9)
        ffe = fftrainer_timeline(n, state_bytes, topology=topo,
                                 path=topo.path(0, 3))
        row(f"table5/{n}gpu/fftrainer/state_recovery_hotspot_edge", 0.0,
            f"{ffe['network_and_state']:.1f}")

    # end-to-end measured on the simulator (real chunked state movement)
    from repro.runtime.cluster import SimCluster
    cfg = dataclasses.replace(reduce_for_smoke(get_arch("qwen3-0.6b")),
                              dtype="float32")
    clu = SimCluster(cfg, dp=4, global_batch=8, seq_len=16, ckpt_dir=tmp)
    clu.run(2 if tiny else 4)
    clu.inject_failure([1])
    rep = clu.recover()
    row("table5/sim/recovery_total_s", 0.0, f"{rep.total_time:.1f}")
    row("table5/sim/rolled_back_iters", 0.0, rep.rolled_back_iterations)
    row("table5/sim/recovery_chunks", 0.0, rep.chunks_sent)
    row("table5/sim/instant_hidden_iters", 0.0, clu.instant_hidden)


if __name__ == "__main__":
    from benchmarks.common import bench_main
    bench_main(run)
