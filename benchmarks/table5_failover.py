"""Paper Table 5: failover breakdown (seconds) Gemini-style baseline vs
FFTrainer at 16 and 128 GPUs — FFTrainer's overlapped timeline measured on
the runtime simulator with real state movement — plus the recovery-policy
head-to-head (ISSUE 6): stream vs checkpoint-free compute-replay vs hybrid,
on a healthy fabric and through a storm-degraded DCN where compute wins."""
import dataclasses
from pathlib import Path

from benchmarks.common import row
from repro.configs import get_arch, reduce_for_smoke
from repro.runtime.failover import (baseline_timeline,
                                    compute_recovery_timeline,
                                    fftrainer_timeline)


def run(tmp: Path = Path("/tmp/repro_bench_t5"), tiny: bool = False) -> None:
    state_bytes = 13e9 / 4     # LLaMA2-13B-ish unique shard per worker
    for n in ((16,) if tiny else (16, 128)):
        base = baseline_timeline(n, state_bytes)
        fft = fftrainer_timeline(n, state_bytes)
        for k in ("detection", "pod_creation", "dependency_install"):
            row(f"table5/{n}gpu/baseline/{k}", 0.0, f"{base[k]:.1f}")
            row(f"table5/{n}gpu/fftrainer/{k}", 0.0, f"{fft[k]:.1f}")
        # state-leg rows feed the CI trend gate (tools/bench_trend.py):
        # raw floats, not pre-rounded strings, so the >20% comparison isn't
        # amplified or masked by display quantization
        row(f"table5/{n}gpu/baseline/state_recovery", 0.0,
            base["network_recovery"] + base["state_recovery"])
        row(f"table5/{n}gpu/fftrainer/state_recovery", 0.0,
            fft["network_and_state"])
        row(f"table5/{n}gpu/baseline/total", 0.0, f"{base['total']:.1f}")
        row(f"table5/{n}gpu/fftrainer/total", 0.0, f"{fft['total']:.1f}")
        row(f"table5/{n}gpu/reduction", 0.0,
            f"{1 - fft['total'] / base['total']:.3f}")
        # recovery while healthy DP groups keep training: their allreduce
        # preempts the recovery chunks on the shared link (§5.3) — the
        # timeline stretches by exactly the scheduler's answer
        busy = [(0.1 * i, 20e9) for i in range(10)]   # saturating allreduce
        fftp = fftrainer_timeline(n, state_bytes, train_traffic=busy)
        row(f"table5/{n}gpu/fftrainer/state_recovery_preempted", 0.0,
            fftp["network_and_state"])
        # per-edge fabric: the recovery fetch rides a multi-hop ring path
        # with one throttled hotspot edge — the timeline is bottlenecked by
        # exactly that edge's residual bandwidth (ISSUE 2)
        from repro.core.lccl import LinkTopology
        topo = LinkTopology(min(n, 16), 50e9, quantum=4 << 20)
        topo.set_bandwidth(1, 2, 5e9)
        ffe = fftrainer_timeline(n, state_bytes, topology=topo,
                                 path=topo.path(0, 3))
        row(f"table5/{n}gpu/fftrainer/state_recovery_hotspot_edge", 0.0,
            ffe["network_and_state"])

        # bidirectional ring routing (ISSUE 3): split the recovery across
        # BOTH directions of a symmetric idle ring by residual bandwidth —
        # the state leg (the part routing can change; connection building
        # overlaps it either way) is strictly faster than the single
        # BFS-first direction, ~halved on an idle ring
        from repro.runtime.failover import schedule_state_phase
        topo_uni = LinkTopology(min(n, 16), 50e9, quantum=4 << 20)
        t_uni = schedule_state_phase(state_bytes, 50e9, quantum=4 << 20,
                                     topology=topo_uni,
                                     path=topo_uni.path(0, 1))
        topo_bi = LinkTopology(min(n, 16), 50e9, quantum=4 << 20)
        t_bi = schedule_state_phase(state_bytes, 50e9, quantum=4 << 20,
                                    topology=topo_bi,
                                    paths=topo_bi.disjoint_paths(0, 1))
        row(f"table5/{n}gpu/fftrainer/state_leg_unidirectional", 0.0, t_uni)
        row(f"table5/{n}gpu/fftrainer/state_leg_bidirectional", 0.0, t_bi)
        row(f"table5/{n}gpu/bidi_beats_uni", 0.0, t_bi < t_uni)

        # cross-pod recovery over a DARKENED pod (ISSUE 3): 4 pods of ICI
        # rings joined by a 5 GB/s, 1 ms DCN gateway ring; pod 1 is dark, so
        # the fetch pod0 -> pod2 races the other way around the gateway
        # ring. The timeline is bounded by the DCN residual bandwidth plus
        # the per-hop delivery latency of the detour
        from repro.core.lccl import PodFabric
        from repro.runtime.failover import FailoverCosts
        costs = FailoverCosts()
        fab = PodFabric(4, max(min(n, 16) // 4, 1), 50e9, costs.dcn_bw,
                        quantum=4 << 20, dcn_latency=costs.dcn_latency)
        fab.fail_pod(1)
        path = fab.path(fab.gateway(0), fab.gateway(2))
        n_dcn = sum(1 for e in path if fab.tier(*e) == "dcn")
        ffx = fftrainer_timeline(n, state_bytes, topology=fab, path=path)
        bound = (costs.state_ramp_fft + state_bytes / costs.dcn_bw +
                 n_dcn * costs.dcn_latency)
        row(f"table5/{n}gpu/fftrainer/state_recovery_crosspod_storm", 0.0,
            ffx["network_and_state"])
        row(f"table5/{n}gpu/fftrainer/crosspod_dcn_bound", 0.0,
            f"{bound:.2f}")
        row(f"table5/{n}gpu/crosspod_within_dcn_bound", 0.0,
            ffx["network_and_state"] <= bound * 1.05)

        # k-path striping (ISSUE 10): with 2 DCN uplinks per pod the
        # cross-pod leg has FOUR edge-disjoint routes; water-filling over
        # k=4 beats the k=2 split (both rows growth-gated via the
        # "state_leg" substring, the ratio min-gated via "speedup")
        fab_k = PodFabric(4, max(min(n, 16) // 4, 2), 50e9, costs.dcn_bw,
                          quantum=4 << 20, dcn_latency=costs.dcn_latency,
                          dcn_uplinks=2)
        src, dst = fab_k.gateway(0), fab_k.gateway(2)
        t_k2 = schedule_state_phase(
            state_bytes, 50e9, quantum=4 << 20, topology=fab_k,
            paths=fab_k.disjoint_paths(src, dst, k=2))
        fab_k4 = PodFabric(4, max(min(n, 16) // 4, 2), 50e9, costs.dcn_bw,
                           quantum=4 << 20, dcn_latency=costs.dcn_latency,
                           dcn_uplinks=2)
        t_k4 = schedule_state_phase(
            state_bytes, 50e9, quantum=4 << 20, topology=fab_k4,
            paths=fab_k4.disjoint_paths(src, dst, k=4))
        row(f"table5/{n}gpu/fftrainer/state_leg_k2", 0.0, t_k2)
        row(f"table5/{n}gpu/fftrainer/state_leg_k4", 0.0, t_k4)
        row(f"table5/{n}gpu/kpath_speedup", 0.0, t_k2 / t_k4)

        # mid-transfer re-balancing vs the static stripe: one of the four
        # DCN routes browns out to 10% mid-flight; the re-balancing
        # transport moves the not-yet-started chunks to the survivors
        # (same fabric, same degrade instant, same bytes delivered)
        import numpy as np
        from repro.ckpt.stream import (ChunkedStream, StreamAssembler,
                                       TopologyTransport)
        reb_bytes = state_bytes / 8           # keep the event count sane
        t_deg = 0.25 * t_k4 / 8               # brown-out mid-transfer
        finishes = {}
        for mode, auto in (("rebalanced", True), ("static", False)):
            fab_r = PodFabric(4, max(min(n, 16) // 4, 2), 50e9,
                              costs.dcn_bw, quantum=4 << 20,
                              dcn_latency=costs.dcn_latency, dcn_uplinks=2)
            tp = TopologyTransport(fab_r, route_k=4, auto_rebalance=auto)
            stream = ChunkedStream.from_pytree(
                f"bench/kpath_{mode}",
                {"shard": np.zeros(int(reb_bytes) // 4, np.float32)},
                quantum=4 << 20)
            tk = tp.send(stream, 0.0,
                         assembler=StreamAssembler.for_stream(stream),
                         src=src, dst=dst, policy="split")
            tp.run(until=t_deg)
            fab_r.set_bandwidth(src, src + fab_r.pod_size, 0.1 * costs.dcn_bw)
            tp.drain()
            finishes[mode] = tk.finish_time
        row(f"table5/{n}gpu/fftrainer/state_leg_rebalanced", 0.0,
            finishes["rebalanced"])
        row(f"table5/{n}gpu/fftrainer/state_leg_static_degraded", 0.0,
            finishes["static"])
        row(f"table5/{n}gpu/rebalance_vs_static_speedup", 0.0,
            finishes["static"] / finishes["rebalanced"])

        # ---- recovery-policy head-to-head (ISSUE 6) ----
        # healthy fabric: streaming the shard over a 50 GB/s ICI link takes
        # well under a second; replaying it at the modeled recompute rate
        # costs seconds of neighbor compute — stream wins
        comp = compute_recovery_timeline(n, state_bytes)
        row(f"table5/{n}gpu/policy/healthy/stream/state_recovery", 0.0,
            fft["network_and_state"])
        row(f"table5/{n}gpu/policy/healthy/compute/replay_compute", 0.0,
            comp["replay_compute"])
        row(f"table5/{n}gpu/policy/healthy/compute/compute_seconds", 0.0,
            comp["compute_seconds_burned"])
        hybrid_healthy = min(fft["network_and_state"],
                             comp["replay_compute"])
        row(f"table5/{n}gpu/policy/healthy/hybrid/state_recovery", 0.0,
            hybrid_healthy)
        row(f"table5/{n}gpu/policy/healthy/stream_beats_compute", 0.0,
            fft["network_and_state"] < comp["replay_compute"])

        # storm-degraded DCN: pod 1 dark AND the surviving gateway detour
        # throttled to a residual 0.25 GB/s (ByteDance's correlated-failure
        # scenario) — the stream leg is DCN-bound while the replay leg does
        # not touch the fabric at all: compute-based recovery wins
        fab_storm = PodFabric(4, max(min(n, 16) // 4, 1), 50e9, 0.25e9,
                              quantum=4 << 20,
                              dcn_latency=costs.dcn_latency)
        fab_storm.fail_pod(1)
        storm_path = fab_storm.path(fab_storm.gateway(0),
                                    fab_storm.gateway(2))
        ffs = fftrainer_timeline(n, state_bytes, topology=fab_storm,
                                 path=storm_path)
        row(f"table5/{n}gpu/policy/storm/stream/state_recovery", 0.0,
            ffs["network_and_state"])
        row(f"table5/{n}gpu/policy/storm/compute/replay_compute", 0.0,
            comp["replay_compute"])
        hybrid_storm = min(ffs["network_and_state"], comp["replay_compute"])
        row(f"table5/{n}gpu/policy/storm/hybrid/state_recovery", 0.0,
            hybrid_storm)
        row(f"table5/{n}gpu/policy/storm/compute_beats_stream", 0.0,
            comp["replay_compute"] < ffs["network_and_state"])
        row(f"table5/{n}gpu/policy/hybrid_picks_min", 0.0,
            hybrid_storm <= min(ffs["network_and_state"],
                                comp["replay_compute"]) and
            hybrid_healthy <= min(fft["network_and_state"],
                                  comp["replay_compute"]))

        # per-tier FCR on the idle fabric matches the closed form (Eq. 2
        # evaluated at each tier's bandwidth)
        from repro.core.fcr import fcr_hidden_per_tier, fcr_per_tier
        s_tok, b_dev, c_flops = 4096, 8, 1e15
        closed = fcr_per_tier(fab, s_tok, b_dev, c_flops)
        hidden = fcr_hidden_per_tier(fab, s_tok, b_dev, c_flops, phi=1e8)
        for tier_name, value in sorted(closed.items()):
            row(f"table5/{n}gpu/fcr_{tier_name}", 0.0, f"{value:.2f}")
            row(f"table5/{n}gpu/fcr_{tier_name}_hidden_matches_closed", 0.0,
                hidden[tier_name] == (value >= 1.0))

    # end-to-end measured on the simulator (real chunked state movement)
    from repro.runtime.cluster import ClusterConfig, FabricConfig, SimCluster
    cfg = dataclasses.replace(reduce_for_smoke(get_arch("qwen3-0.6b")),
                              dtype="float32")
    clu = SimCluster(cfg, cluster=ClusterConfig(
        dp=4, global_batch=8, seq_len=16, ckpt_dir=tmp))
    clu.run(2 if tiny else 4)
    clu.inject_failure([1])
    rep = clu.recover()
    row("table5/sim/recovery_total_s", 0.0, rep.total_time)
    row("table5/sim/rolled_back_iters", 0.0, rep.rolled_back_iterations)
    row("table5/sim/recovery_chunks", 0.0, rep.chunks_sent)
    row("table5/sim/instant_hidden_iters", 0.0, clu.instant_hidden)

    # recovery-policy head-to-head on the SIMULATOR, through a seeded storm
    # on a 2-pod fabric whose DCN is throttled to a residual 0.2 MB/s: the
    # cross-pod recovery stream is DCN-bound, the replay leg is not — the
    # crossover the model-level rows predict shows up in the measured
    # end-to-end totals, and the byte accounting shows compute streaming
    # ZERO state bytes
    totals = {}
    for pname in ("stream", "compute", "hybrid"):
        pclu = SimCluster(
            cfg,
            cluster=ClusterConfig(dp=4, global_batch=8, seq_len=16,
                                  ckpt_dir=tmp / f"pol_{pname}"),
            fabric=FabricConfig(quantum=2048, pods=2, dcn_bw=2e5,
                                dcn_latency=1e-4),
            recovery=pname)
        pclu.run(2)
        pclu.inject_storm(7, pods=1)
        prep = pclu.recover()
        totals[pname] = prep.total_time
        row(f"table5/sim/policy/{pname}/recovery_total_s", 0.0,
            prep.total_time)
        row(f"table5/sim/policy/{pname}/state_bytes_streamed", 0.0,
            prep.state_bytes_streamed)
        row(f"table5/sim/policy/{pname}/replay_compute_seconds", 0.0,
            prep.compute_seconds)
    row("table5/sim/policy/storm_compute_beats_stream", 0.0,
        totals["compute"] < totals["stream"])
    # hybrid races per-worker ETAs from estimates, so it tracks the best
    # policy to within estimator error (the fixed stream ramp), not exactly
    row("table5/sim/policy/hybrid_tracks_best", 0.0,
        totals["hybrid"] <= min(totals["stream"], totals["compute"]) * 1.05)


if __name__ == "__main__":
    from benchmarks.common import bench_main
    bench_main(run)
