"""Shared benchmark utilities. Every table prints `name,us_per_call,derived`
CSV rows (us_per_call = wall-time of the measured operation where one exists,
0 for purely analytic rows; derived = the table's headline quantity)."""
import time


def row(name: str, us_per_call: float, derived) -> None:
    print(f"{name},{us_per_call:.3f},{derived}")


def timeit(fn, *args, repeat: int = 5, **kw) -> float:
    fn(*args, **kw)  # warmup
    t0 = time.perf_counter()
    for _ in range(repeat):
        fn(*args, **kw)
    return (time.perf_counter() - t0) / repeat * 1e6
