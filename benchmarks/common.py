"""Shared benchmark utilities. Every table prints `name,us_per_call,derived`
CSV rows (us_per_call = wall-time of the measured operation where one exists,
0 for purely analytic rows; derived = the table's headline quantity).

Rows are also collected in memory so a driver can dump them as JSON
(`dump_rows`) — the CI benchmark smoke job uploads these as build artifacts,
accumulating the perf trajectory across commits (`BENCH_*.json`)."""
import json
import time
from pathlib import Path
from typing import List

_ROWS: List[dict] = []


def row(name: str, us_per_call: float, derived) -> None:
    print(f"{name},{us_per_call:.3f},{derived}")
    _ROWS.append({"name": name, "us_per_call": round(us_per_call, 3),
                  "derived": derived if isinstance(derived, (int, float))
                  else str(derived)})


def reset_rows() -> None:
    _ROWS.clear()


def dump_rows(path) -> Path:
    """Write every row collected since the last reset as a JSON artifact."""
    path = Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(json.dumps(_ROWS, indent=1))
    return path


def bench_main(run_fn) -> None:
    """Uniform CLI for single-table benchmark modules: optional `--json OUT`
    artifact dump and a `--tiny` smoke mode (CI) that `run_fn` may honor via
    its `tiny` keyword."""
    import argparse
    import inspect

    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default=None, metavar="OUT",
                    help="also dump the rows as a JSON artifact")
    ap.add_argument("--tiny", action="store_true",
                    help="smoke-scale run (CI benchmark job)")
    args = ap.parse_args()
    kw = {}
    if "tiny" in inspect.signature(run_fn).parameters:
        kw["tiny"] = args.tiny
    run_fn(**kw)
    if args.json:
        print(f"wrote {dump_rows(args.json)}")


def timeit(fn, *args, repeat: int = 5, **kw) -> float:
    fn(*args, **kw)  # warmup
    t0 = time.perf_counter()
    for _ in range(repeat):
        fn(*args, **kw)
    return (time.perf_counter() - t0) / repeat * 1e6
