"""Paper Fig. 9: FCR (free checkpointing ratio) across token length, batch,
bandwidth and FLOPS — including the paper's two dashed reference lines (4090,
H100 at batch 256) and our TPU v5e target."""
from benchmarks.common import row
from repro.core.fcr import fcr, sweep, tpu_fcr
from repro.roofline import hw


def run() -> None:
    samples = sweep(
        seq_lens=(512, 2048, 8192, 32768),
        batches=(1, 8, 64, 256),
        bandwidths=(12.5e9, 25e9, 50e9, 100e9),
        flops=(83e12, 197e12, 989e12, 4e15),
    )
    free = sum(1 for s in samples if s.free)
    row("fig9/sweep/total", 0.0, len(samples))
    row("fig9/sweep/free_fraction", 0.0, f"{free / len(samples):.3f}")
    # paper's dashed lines
    row("fig9/rtx4090/fcr", 0.0,
        f"{fcr(4096, 256 / 8, 25e9, 83e12):.2f}")
    row("fig9/h100/fcr", 0.0,
        f"{fcr(4096, 256 / 8, 50e9, 989e12):.2f}")
    # our production cells
    row("fig9/v5e_train4k_dp16/fcr", 0.0, f"{tpu_fcr(4096, 256, 16):.2f}")
    row("fig9/v5e_train4k_dp32/fcr", 0.0, f"{tpu_fcr(4096, 256, 32):.2f}")


if __name__ == "__main__":
    run()
