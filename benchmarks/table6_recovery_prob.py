"""Paper Table 6: probability that failures are recoverable from CKPTs in
main memory — FFTrainer (Eq. 5) vs Gemini (m=2 replicas, Monte Carlo)."""
from benchmarks.common import row, timeit
from repro.core.analytic import (gemini_recovery_probability,
                                 recovery_probability)


def run() -> None:
    for hosts in (800, 1200, 1600, 2000):
        for h in (3, 12):
            us = timeit(recovery_probability, hosts, h, repeat=3)
            p = recovery_probability(hosts, h)
            row(f"table6/{hosts}hosts/H{h}/fftrainer", us, f"{p:.4f}")
            g = gemini_recovery_probability(hosts, h, m=2, samples=50_000)
            row(f"table6/{hosts}hosts/H{h}/gemini_m2", 0.0, f"{g:.4f}")


if __name__ == "__main__":
    run()
