"""Paper Fig. 8: network-state recovery time vs scale — our LCCL control
plane MEASURED (lock-free address array + group-free ring connections) vs a
serial-barrier baseline model (MegaScale-style O(N) barriered init)."""
import numpy as np

from benchmarks.common import row, timeit
from repro.core.lccl import LockFreeAddressArray, Role, RoleTable


def _lccl_init(n: int) -> float:
    arr = LockFreeAddressArray(n)
    for r in range(n):
        arr.publish(r, 10_000 + r)
    # every worker resolves its <=4 ring targets (group-free membership)
    for r in range(n):
        arr.connect_all(r, [(r + 1) % n, (r - 1) % n])
    return 0.0


def run() -> None:
    for n in (16, 128, 1024, 8192):
        us = timeit(_lccl_init, n, repeat=3)
        # LCCL total = 11 s one-time RDMA buffer registration (paper Fig. 10)
        # + measured lock-free control-plane resolution
        lccl_total = 11.0 + us / 1e6
        # baseline: serial TCP-store barrier, O(N) lock-held read-writes
        baseline_s = 0.5 + 0.08 * n
        row(f"fig8/{n}workers/lccl_resolution_us", us, f"{us / 1e6:.4f}")
        row(f"fig8/{n}workers/lccl_total_s", 0.0, f"{lccl_total:.1f}")
        row(f"fig8/{n}workers/baseline_model_s", 0.0, f"{baseline_s:.1f}")
        row(f"fig8/{n}workers/lccl_fraction", 0.0,
            f"{lccl_total / baseline_s:.3f}")
    # role rebinding speed (role/rank decoupling, the overlap enabler)
    table = RoleTable(16, 4, 2)
    us = timeit(lambda: (table.rebind(5, 999), table.rebind(999, 5)),
                repeat=100)
    row("fig8/role_rebind_us", us, "")


if __name__ == "__main__":
    run()
