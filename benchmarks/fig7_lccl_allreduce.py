"""Paper Fig. 7: cross-node allreduce wall time, LCCL vs NCCL, by payload.
Ring model calibrated to the paper's measurement (LCCL ~= 89% of NCCL
efficiency at 2 GB); plus a REAL measured allreduce on this host via a
jitted psum (the compiler-scheduled path our TPU design rides on)."""
import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import row, timeit
from repro.core.lccl import ring_allreduce_time

BW = 200e9 / 8   # 200 Gb/s IB


def run() -> None:
    for size_mb in (64, 256, 1024, 2048):
        size = size_mb * 1e6
        nccl = ring_allreduce_time(size, 2, BW, efficiency=0.92)
        lccl = ring_allreduce_time(size, 2, BW, efficiency=0.92 * 0.89)
        row(f"fig7/{size_mb}MB/nccl_model_s", 0.0, f"{nccl:.4f}")
        row(f"fig7/{size_mb}MB/lccl_model_s", 0.0, f"{lccl:.4f}")
        row(f"fig7/{size_mb}MB/lccl_vs_nccl", 0.0, f"{nccl / lccl:.3f}")

    # measured reduction throughput on this host (single device: the XLA
    # reduction path; establishes the harness is real, not the absolute BW)
    x = jnp.ones((8, 1 << 20), jnp.float32)
    f = jax.jit(lambda x: jnp.sum(x, axis=0))
    us = timeit(lambda: jax.block_until_ready(f(x)), repeat=5)
    row("fig7/measured/local_reduce_32MB_us", us,
        f"{x.nbytes / (us * 1e-6) / 1e9:.1f}GBps")


if __name__ == "__main__":
    run()
