"""Paper Fig. 10: state-controller scalability — heartbeat processing CPU
time and connection building measured on OUR controller at up to 32 768
workers (the paper's stress test, reproduced for real) — plus the closed
reliability loop measured end to end: a live `run_scenario` replay reports
the MEASURED detection latency / recovery total on the sim clock (gated by
`tools/bench_trend.py`), and a straggler run reports the measured
mitigation speedup (min-gated: losing the speedup fails CI)."""
import tempfile
import time

import numpy as np

from benchmarks.common import row, timeit
from repro.core.controller import HeartbeatTable, StateController


def _scaling_rows(tiny: bool) -> None:
    for n in ((1024,) if tiny else (1024, 8192, 32768)):
        hb = HeartbeatTable(n)
        workers = np.arange(n)
        us_beat = timeit(hb.beat_many, workers, 100.0, repeat=10)
        us_scan = timeit(hb.failed, 101.5, repeat=10)
        row(f"fig10/{n}workers/heartbeat_batch_us", us_beat,
            f"{us_beat / n * 1000:.1f}ns_per_worker")
        row(f"fig10/{n}workers/failure_scan_us", us_scan, "")
    # connection building: lock-free address array
    from repro.core.lccl import LockFreeAddressArray
    n_conn = 4096 if tiny else 32768
    def connect(n=n_conn):
        arr = LockFreeAddressArray(n)
        for r in range(n):
            arr.publish(r, r)
        for r in range(n):
            arr.connect_all(r, [(r + 1) % n, (r - 1) % n])
    us = timeit(connect, repeat=1)
    row(f"fig10/{n_conn}workers/connection_build_us", us, f"{us / 1e6:.2f}s")

    # detection identification via the controller primitive
    ctl = StateController(dp=64, pp=2, tp=4, global_batch=256)
    for w in range(ctl.n_workers):
        ctl.beat(w, now=100.0)
    ctl.beat(7, now=100.0)  # worker 7 then goes silent
    for w in range(ctl.n_workers):
        if w != 7:
            ctl.beat(w, now=101.6)
    failed = ctl.detect_failures(now=101.6)
    row("fig10/detection/identified", 0.0, str(failed == [7]))


def _measured_loop_rows() -> None:
    """MEASURED values from the closed reliability loop, not the analytic
    constants: replay a corpus scenario and report what the heartbeat scan
    actually observed on the sim clock. Deterministic, so the trend gate
    is noise-free."""
    from repro.runtime.scenarios import corpus, run_scenario
    scs = {s.name: s for s in corpus()}
    sc = scs["clean_software_failure"]
    with tempfile.TemporaryDirectory() as td:
        t0 = time.perf_counter()
        v = run_scenario(sc, ckpt_dir=td)
        wall_us = (time.perf_counter() - t0) * 1e6
    rel = sc.reliability
    analytic = rel.heartbeat_period + rel.scan_period + rel.notify_latency
    row("fig10/loop/detection_latency_s", wall_us, v.detection_latency_s)
    row("fig10/loop/detection_analytic_worst_s", 0.0, analytic)
    row("fig10/loop/recovery_total_s", 0.0, v.recovery_total_s)


def _measured_straggler_rows() -> None:
    """Measured straggler mitigation: run the live loop against a 2x
    straggler and report the max step time before and after the role
    migrates to a spare. `fig10/straggler/speedup` is MIN-gated in
    bench_trend: if the loop stops migrating, the speedup collapses to
    ~1.0 and CI fails."""
    from repro.runtime.scenarios import build_cluster, corpus
    sc = {s.name: s for s in corpus()}["persistent_straggler"]
    with tempfile.TemporaryDirectory() as td:
        clu = build_cluster(sc, td)
        clu.set_straggler(2, 2.0)
        slowed = mitigated = None
        for _ in range(sc.steps):
            clu.step()
            # last_step_times is consumed by the loop tick; the per-worker
            # history on each sim worker persists
            dt = max(w.step_times[-1] for w in clu.workers)
            migrated = any(e.kind == "straggler_migrate"
                           for e in clu.reliability.events)
            if not migrated:
                slowed = dt
            elif mitigated is None and dt < slowed:
                mitigated = dt          # first clean step after the rebind
    row("fig10/straggler/slowed_step_s", 0.0, slowed)
    row("fig10/straggler/mitigated_step_s", 0.0, mitigated)
    row("fig10/straggler/speedup", 0.0,
        slowed / mitigated if mitigated else 1.0)


def run(tiny: bool = False) -> None:
    _scaling_rows(tiny)
    _measured_loop_rows()
    _measured_straggler_rows()


if __name__ == "__main__":
    from benchmarks.common import bench_main
    bench_main(run)
