"""Paper Fig. 10: state-controller scalability — heartbeat processing CPU
time and connection building measured on OUR controller at up to 32 768
workers (the paper's stress test, reproduced for real)."""
import numpy as np

from benchmarks.common import row, timeit
from repro.core.controller import HeartbeatTable, StateController


def run() -> None:
    for n in (1024, 8192, 32768):
        hb = HeartbeatTable(n)
        workers = np.arange(n)
        us_beat = timeit(hb.beat_many, workers, 100.0, repeat=10)
        us_scan = timeit(hb.failed, 101.5, repeat=10)
        row(f"fig10/{n}workers/heartbeat_batch_us", us_beat,
            f"{us_beat / n * 1000:.1f}ns_per_worker")
        row(f"fig10/{n}workers/failure_scan_us", us_scan, "")
    # connection building: lock-free address array at 32k
    from repro.core.lccl import LockFreeAddressArray
    def connect(n=32768):
        arr = LockFreeAddressArray(n)
        for r in range(n):
            arr.publish(r, r)
        for r in range(n):
            arr.connect_all(r, [(r + 1) % n, (r - 1) % n])
    us = timeit(connect, repeat=1)
    row("fig10/32768workers/connection_build_us", us, f"{us / 1e6:.2f}s")

    # end-to-end detection latency via the controller
    ctl = StateController(dp=64, pp=2, tp=4, global_batch=256)
    for w in range(ctl.n_workers):
        ctl.beat(w, now=100.0)
    ctl.beat(7, now=100.0)  # worker 7 then goes silent
    for w in range(ctl.n_workers):
        if w != 7:
            ctl.beat(w, now=101.6)
    failed = ctl.detect_failures(now=101.6)
    row("fig10/detection/identified", 0.0, str(failed == [7]))


if __name__ == "__main__":
    run()
