"""Paper Table 1: per-iteration data input vs. output and training-network
utilization — the observation (links idle >97% of the time) that motivates
using the training network for STATE traffic.

Derived analytically from our model configs on the paper's testbed params
(8 workers/host, 200 Gb/s NIC) and on the TPU target (v5e ICI)."""
from benchmarks.common import row
from repro.configs import get_arch
from repro.models import param_count
from repro.roofline import hw

# paper's testbed: per-iteration wall time + (d,p,t) from Tables 1/4
PAPER = {  # arch: (iter_s, dp, pp, tp)
    "gpt2-2.7b": (21.0, 16, 2, 4),
    "llama3-8b": (11.0, 4, 8, 4),
    "llama2-13b": (36.0, 4, 8, 4),
    "llama3-70b": (77.0, 2, 8, 8),
}
NIC = 200e9 / 8            # 200 Gb/s -> bytes/s
SEQ, BATCH_PER_GPU = 4096, 1
GPUS = 8                   # GPUs sharing one NIC


def run() -> None:
    for arch, (t_iter, d, pp, tp) in PAPER.items():
        cfg = get_arch(arch)
        phi = param_count(cfg)
        nic_capacity_gb = NIC * t_iter / 1e9
        data_in_kb = GPUS * BATCH_PER_GPU * SEQ * 4 / 1024  # token ids
        # per-NIC output per iteration: ring-allreduce of each GPU's model
        # partition (phi / (t p)) in fp16, 2x traffic, 8 GPUs per NIC
        per_gpu = phi / (pp * tp)
        data_out_gb = GPUS * 2 * per_gpu * 2 / 1e9
        util = data_out_gb / max(nic_capacity_gb, 1e-9)
        row(f"table1/{arch}/nic_capacity_gb", 0.0, f"{nic_capacity_gb:.0f}")
        row(f"table1/{arch}/data_in_kb", 0.0, f"{data_in_kb:.0f}")
        row(f"table1/{arch}/data_out_gb", 0.0, f"{data_out_gb:.1f}")
        row(f"table1/{arch}/link_utilization", 0.0, f"{util:.3f}")


if __name__ == "__main__":
    run()
