#!/usr/bin/env bash
# Fetch the previous bench artifact for the trend gate.
#
#   fetch_prev_bench.sh <artifact-name-prefix> <output-dir>
#
# Walks the latest successful workflow runs on $BASELINE_BRANCH and unzips
# the newest non-expired artifact whose name starts with the prefix into
# the output dir. Two outcomes are fine and exit 0 with a note — no
# successful runs yet, or no matching artifact (first run / expired
# retention): tools/bench_trend.py then skips with "nothing to gate". Any
# OTHER failure (API error, bad token, rate limit, download/unzip breakage)
# emits a ::error annotation and exits 1, so a broken fetch fails the job
# loudly instead of silently disabling the regression gate.
#
# Requires: GH_TOKEN, GITHUB_REPOSITORY, BASELINE_BRANCH in the env.
set -u

prefix="${1:?usage: fetch_prev_bench.sh <artifact-prefix> <out-dir>}"
out="${2:?usage: fetch_prev_bench.sh <artifact-prefix> <out-dir>}"
mkdir -p "$out"
err="$(mktemp)"
trap 'rm -f "$err" prev.zip' EXIT

fail() {
  echo "::error title=bench artifact fetch failed::$1 — $(tr '\n' ' ' <"$err")"
  exit 1
}

runs=$(gh api \
  "repos/${GITHUB_REPOSITORY}/actions/runs?branch=${BASELINE_BRANCH}&status=success&per_page=20" \
  --jq '.workflow_runs[].id' 2>"$err") \
  || fail "listing successful runs on ${BASELINE_BRANCH}"
if [ -z "$runs" ]; then
  echo "no successful runs on ${BASELINE_BRANCH} yet; skipping trend gate"
  exit 0
fi

id=""
for rid in $runs; do
  id=$(gh api "repos/${GITHUB_REPOSITORY}/actions/runs/${rid}/artifacts" \
    --jq "[.artifacts[] | select(.name | startswith(\"${prefix}\"))
           | select(.expired | not)] | first | .id // empty" 2>"$err") \
    || fail "listing artifacts of run ${rid}"
  [ -n "$id" ] && break
done
if [ -z "$id" ]; then
  echo "no previous ${prefix}* artifact on ${BASELINE_BRANCH}; skipping trend gate"
  exit 0
fi

gh api "repos/${GITHUB_REPOSITORY}/actions/artifacts/${id}/zip" \
  >prev.zip 2>"$err" || fail "downloading artifact ${id}"
unzip -o prev.zip -d "$out" 2>"$err" || fail "unzipping artifact ${id}"
