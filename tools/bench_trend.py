"""Trend gate for the bench-smoke JSON artifacts.

The CI benchmark job uploads `BENCH_*.json` row dumps
(`benchmarks/common.py:dump_rows`) on every commit. This tool diffs the
current run against the previous commit's artifact and FAILS (exit 1) when
a gated row regressed by more than the threshold — so a change that slows
the simulated failover state leg can't land silently.

Gated rows are the state-leg rows of table5 (simulated seconds, fully
deterministic — a 20% jump is a real model regression, not runner noise)
plus the WALL-CLOCK rows of the fleet-scale benchmark: any row whose name
contains one of the `--match` substrings, default ``state_leg`` /
``state_recovery`` / ``recovery_total_s`` / ``replay_compute`` (the last
gates the checkpoint-free compute-recovery rows the same way) /
``wall_s`` (the fleet-bench job's `fleet/*/wall_s` rows — a >20% wall
slowdown on the same runner class means the compiled-plan fast path
regressed, which is exactly what that job exists to catch) /
``detection_latency`` (the scenario-fleet job's measured reliability-loop
detection rows — deterministic sim seconds, so any growth is a real
control-loop regression). Rows matching `--match-min` (default
``speedup``) gate the OPPOSITE direction: larger is better, and a >20%
DROP fails — e.g. `fig10/straggler/speedup` collapsing to ~1.0 means the
loop stopped migrating stragglers. All other
numeric rows are reported informationally. Non-numeric derived values
(booleans, labels) are skipped — unless the row is gated, in which case a
WARNING prints so the gate can't be disabled silently; likewise for a
gated row present on only one side (renamed/removed). A gated zero
baseline that goes positive counts as a regression (unbounded relative
growth). A missing previous artifact (first run, expired retention)
passes with a note.

Usage:
    python tools/bench_trend.py --current bench-out/BENCH_table5.json \
        --previous prev/BENCH_table5.json [--threshold 0.2] [--match SUBSTR]
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path
from typing import Dict, List, Optional, Sequence, Tuple

DEFAULT_MATCH = ("state_leg", "state_recovery", "recovery_total_s",
                 "replay_compute", "wall_s", "detection_latency")
DEFAULT_MATCH_MIN = ("speedup",)
DEFAULT_THRESHOLD = 0.2


def _rows(path: Path) -> Dict[str, dict]:
    return {r["name"]: r for r in json.loads(path.read_text())}


def _numeric(value) -> Optional[float]:
    if isinstance(value, bool):
        return None                    # bool is an int subclass: not a time
    try:
        return float(value)
    except (TypeError, ValueError):
        return None


def compare(current: Path, previous: Path,
            match: Sequence[str] = DEFAULT_MATCH,
            threshold: float = DEFAULT_THRESHOLD,
            match_min: Sequence[str] = DEFAULT_MATCH_MIN
            ) -> Tuple[List[str], List[str]]:
    """Diff two row dumps. Returns (report_lines, regressed_row_names):
    a growth-gated row regresses when its derived value grew by more than
    `threshold` relative to the previous run (larger = slower for every
    such row, all of which are seconds); a min-gated row (`match_min`)
    regresses when it SHRANK by more than `threshold` (larger = better,
    e.g. a mitigation speedup)."""
    cur, prev = _rows(current), _rows(previous)
    lines, regressions = [], []
    for name in sorted(set(cur) | set(prev)):
        cv = _numeric(cur[name]["derived"]) if name in cur else None
        pv = _numeric(prev[name]["derived"]) if name in prev else None
        gated_max = any(m in name for m in match)
        gated_min = any(m in name for m in match_min)
        gated = gated_max or gated_min
        if cv is None or pv is None:
            if gated:
                # a gated row vanishing (rename/removal) or turning
                # non-numeric must not silently disable its regression gate
                why = ("missing from the "
                       + ("previous" if name in cur else "current") + " run"
                       if (name in cur) != (name in prev)
                       else "non-numeric")
                lines.append(f"{name}: WARNING gated row {why} — "
                             "its gate did not apply")
            continue
        if pv > 0:
            delta_str = f"{(cv - pv) / pv:+.1%}"
        else:
            delta_str = "new load" if cv > 0 else "+0.0%"
        tag = " [gated]" if gated else ""
        # pv == 0 with any growth counts: a zero baseline going positive is
        # unbounded relative growth, not a free pass
        if gated_max and cv > pv * (1.0 + threshold) and cv > pv:
            regressions.append(name)
            tag = f" << REGRESSION (> {threshold:.0%})"
        elif gated_min and cv < pv * (1.0 - threshold) and cv < pv:
            regressions.append(name)
            tag = f" << REGRESSION (dropped > {threshold:.0%})"
        lines.append(f"{name}: {pv:.6g} -> {cv:.6g} ({delta_str}){tag}")
    return lines, regressions


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--current", required=True, type=Path,
                    help="this run's BENCH_*.json")
    ap.add_argument("--previous", required=True, type=Path,
                    help="the previous commit's artifact of the same table")
    ap.add_argument("--threshold", type=float, default=DEFAULT_THRESHOLD,
                    help="relative growth that fails a gated row "
                         "(default 0.2 = +20%%)")
    ap.add_argument("--match", action="append", default=None,
                    metavar="SUBSTR",
                    help="gate rows whose name contains SUBSTR "
                         f"(repeatable; default {list(DEFAULT_MATCH)})")
    ap.add_argument("--match-min", action="append", default=None,
                    metavar="SUBSTR",
                    help="min-gate rows (regression = value DROPPED by "
                         "more than the threshold; repeatable; default "
                         f"{list(DEFAULT_MATCH_MIN)})")
    args = ap.parse_args(argv)
    if not args.previous.exists():
        print(f"bench-trend: no previous artifact at {args.previous} "
              "(first run or expired retention) — nothing to gate")
        return 0
    lines, regressions = compare(args.current, args.previous,
                                 match=args.match or DEFAULT_MATCH,
                                 threshold=args.threshold,
                                 match_min=args.match_min
                                 or DEFAULT_MATCH_MIN)
    print(f"bench-trend: {args.previous} -> {args.current}")
    for line in lines:
        print("  " + line)
    if regressions:
        print(f"bench-trend: FAIL — {len(regressions)} gated row(s) "
              f"regressed > {args.threshold:.0%}: {regressions}")
        return 1
    print("bench-trend: OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
