# Namespace package marker so `python -m tools.simlint` resolves. The
# standalone scripts in this directory keep working as plain scripts.
