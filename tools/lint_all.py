"""Run every repo lint with one command.

Wraps the checks the ci `docs` job runs — docs snippets / module map /
public-API pin (`tools/check_docs.py`) and the internal legacy-kwarg ban
(`tools/check_deprecations.py`) — each in its own interpreter with
PYTHONPATH=src set for you, prints a PASS/FAIL summary, and exits with the
worst status. Use it locally before pushing instead of remembering the
individual tools:

    python tools/lint_all.py            # all lints
    python tools/lint_all.py --list     # show what would run
"""
from __future__ import annotations

import argparse
import os
import subprocess
import sys
from pathlib import Path
from typing import Optional, Sequence, Tuple

REPO = Path(__file__).resolve().parents[1]

# (label, argv relative to the repo root) — append new repo lints here and
# the ci docs job picks them up automatically
LINTS: Tuple[Tuple[str, Tuple[str, ...]], ...] = (
    ("check_docs", ("tools/check_docs.py",)),
    ("check_deprecations", ("tools/check_deprecations.py",)),
)


def run_all() -> int:
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src")
    worst = 0
    results = []
    for label, argv in LINTS:
        proc = subprocess.run([sys.executable, *argv], cwd=REPO, env=env)
        results.append((label, proc.returncode))
        worst = max(worst, proc.returncode)
    print("\nlint_all summary:")
    for label, rc in results:
        print(f"  {'PASS' if rc == 0 else f'FAIL (exit {rc})'}  {label}")
    return worst


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--list", action="store_true",
                    help="list the lints without running them")
    args = ap.parse_args(argv)
    if args.list:
        for label, lint_argv in LINTS:
            print(f"{label}: {' '.join(lint_argv)}")
        return 0
    return run_all()


if __name__ == "__main__":
    sys.exit(main())
