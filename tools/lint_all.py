"""Run every repo lint with one command.

Wraps the checks the CI `lint` job runs — simlint (determinism /
exactness invariants + the legacy-kwarg ban, `python -m tools.simlint`),
docs snippets / module map / public-API resolution (`tools/check_docs.py`)
and the type-error baseline (`tools/type_baseline.py`). Every lint runs
to completion even when an earlier one fails; output is streamed under a
per-lint header and the summary aggregates each exit code, so one broken
lint can never mask findings from the others. Exits with the worst
status.

    python tools/lint_all.py                     # all lints
    python tools/lint_all.py --list              # show what would run
    python tools/lint_all.py --artifacts DIR     # also write simlint.json

Append new repo lints to LINTS and the CI lint job picks them up
automatically.
"""
from __future__ import annotations

import argparse
import os
import subprocess
import sys
from pathlib import Path
from typing import List, Optional, Sequence, Tuple

REPO = Path(__file__).resolve().parents[1]

# (label, argv relative to the repo root). simlint subsumes the old
# standalone check_deprecations walk (SIM007 is one of its rules), so the
# shim script is not listed here — running it twice would be redundant.
LINTS: Tuple[Tuple[str, Tuple[str, ...]], ...] = (
    ("simlint", ("-m", "tools.simlint")),
    ("check_docs", ("tools/check_docs.py",)),
    ("type_baseline", ("tools/type_baseline.py",)),
)


def run_all(artifacts: Optional[Path] = None) -> int:
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    worst = 0
    results: List[Tuple[str, int]] = []
    for label, argv in LINTS:
        argv = list(argv)
        if label == "simlint" and artifacts is not None:
            artifacts.mkdir(parents=True, exist_ok=True)
            argv += ["--json", str(artifacts / "simlint.json")]
        print(f"=== {label}: {' '.join(argv)} ===", flush=True)
        try:
            proc = subprocess.run([sys.executable, *argv], cwd=REPO, env=env)
            rc = proc.returncode
        except OSError as e:         # keep going: a lint that cannot even
            print(f"lint_all: failed to launch {label}: {e}")
            rc = 2                   # start must not hide the others
        results.append((label, rc))
        worst = max(worst, rc)
        print(flush=True)
    print("lint_all summary:")
    for label, rc in results:
        print(f"  {'PASS' if rc == 0 else f'FAIL (exit {rc})'}  {label}")
    return worst


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--list", action="store_true",
                    help="list the lints without running them")
    ap.add_argument("--artifacts", metavar="DIR", default=None,
                    help="directory for machine-readable findings "
                         "(simlint.json) for CI upload")
    args = ap.parse_args(argv)
    if args.list:
        for label, lint_argv in LINTS:
            print(f"{label}: {' '.join(lint_argv)}")
        return 0
    return run_all(Path(args.artifacts) if args.artifacts else None)


if __name__ == "__main__":
    sys.exit(main())
