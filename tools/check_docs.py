#!/usr/bin/env python
"""Docs smoke checker (CI `docs` job, also run by tests/test_docs.py).

Two guarantees, so the docs can't silently rot:

1. Every ```python fenced block in README.md and docs/*.md has its
   `import repro...` / `from repro... import ...` lines executed — a doc
   referencing a moved or renamed symbol fails the build. Bash fences are
   scanned for `python -m <module>` invocations and each module must be
   importable (spec-resolvable) without running it.
2. Every package under src/repro/ is mentioned in the README module map
   (as `repro/<name>`), so the map stays complete as the codebase grows.
3. The public API surface (`repro.__all__`) matches the pinned list in
   `tools/simlint/rules/api_pin.py` (rule SIM008) and every pinned name
   resolves — the export list, the README quickstart and this checker
   fail together or not at all.

Exit code 0 = clean; nonzero prints every failure.
"""
from __future__ import annotations

import importlib.util
import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(ROOT))

# The pinned public API (ISSUE 6) is single-sourced in simlint's SIM008
# rule, which statically checks `repro.__all__`/`_EXPORTS`/README against
# it. This checker adds the DYNAMIC half: every pinned name must actually
# resolve through the lazy importer.
from tools.simlint.rules.api_pin import PUBLIC_API  # noqa: E402

FENCE = re.compile(r"```(\w+)?\n(.*?)```", re.DOTALL)
IMPORT = re.compile(r"^\s*(?:import\s+repro|from\s+repro[\w.]*\s+import)\s",
                    re.MULTILINE)
PY_M = re.compile(r"python\s+-m\s+([\w.]+)")


def doc_files() -> list[Path]:
    return [ROOT / "README.md"] + sorted((ROOT / "docs").glob("*.md"))


def iter_fences(path: Path):
    for lang, body in FENCE.findall(path.read_text()):
        yield (lang or "").lower(), body


def _import_stmts(body: str) -> list[str]:
    """The repro import statements of one fenced block, including
    parenthesized multi-line `from repro import (...)` forms."""
    lines = body.splitlines()
    stmts, i = [], 0
    while i < len(lines):
        if IMPORT.match(lines[i]):
            stmt = lines[i].strip()
            while stmt.count("(") > stmt.count(")") and i + 1 < len(lines):
                i += 1
                stmt += "\n" + lines[i]
            stmts.append(stmt)
        i += 1
    return stmts


def check_python_imports(path: Path, body: str) -> list[str]:
    """Exec the repro import statements of one fenced python block."""
    errors = []
    for stmt in _import_stmts(body):
        try:
            exec(stmt, {})
        except Exception as e:  # noqa: BLE001 - report, don't crash
            head = stmt.splitlines()[0]
            errors.append(f"{path.name}: import failed: {head!r} "
                          f"({type(e).__name__}: {e})")
    return errors


def check_bash_modules(path: Path, body: str) -> list[str]:
    errors = []
    for mod in PY_M.findall(body):
        try:
            found = importlib.util.find_spec(mod) is not None
        except (ImportError, ModuleNotFoundError):
            found = False
        if not found:
            errors.append(f"{path.name}: `python -m {mod}` does not resolve")
    return errors


def check_module_map() -> list[str]:
    readme = (ROOT / "README.md").read_text()
    errors = []
    pkg_root = ROOT / "src" / "repro"
    for child in sorted(pkg_root.iterdir()):
        if child.name.startswith("__"):
            continue
        name = child.name if child.is_dir() else \
            (child.name[:-3] if child.suffix == ".py" else None)
        if name is None:
            continue
        if f"repro/{name}" not in readme:
            errors.append(f"README.md module map is missing repro/{name}")
    return errors


def check_public_api() -> list[str]:
    """`repro.__all__` equals the pinned PUBLIC_API and every name
    resolves (the lazy `__getattr__` actually finds it)."""
    errors = []
    import repro
    declared, pinned = set(repro.__all__), set(PUBLIC_API)
    for name in sorted(pinned - declared):
        errors.append(f"public API: {name} pinned here but missing from "
                      "repro.__all__")
    for name in sorted(declared - pinned):
        errors.append(f"public API: repro.__all__ exports {name} but it is "
                      "not pinned in tools/simlint/rules/api_pin.py")
    for name in sorted(declared & pinned):
        try:
            getattr(repro, name)
        except Exception as e:  # noqa: BLE001 - report, don't crash
            errors.append(f"public API: repro.{name} does not resolve "
                          f"({type(e).__name__}: {e})")
    readme = (ROOT / "README.md").read_text()
    for name in sorted(pinned):
        if name not in readme:
            errors.append(f"public API: README.md never mentions {name}")
    return errors


def main() -> int:
    sys.path.insert(0, str(ROOT / "src"))
    sys.path.insert(0, str(ROOT))      # for `python -m benchmarks.*`
    errors: list[str] = []
    for path in doc_files():
        if not path.exists():
            errors.append(f"missing doc file: {path}")
            continue
        for lang, body in iter_fences(path):
            if lang == "python":
                errors.extend(check_python_imports(path, body))
            elif lang == "bash":
                errors.extend(check_bash_modules(path, body))
    errors.extend(check_module_map())
    errors.extend(check_public_api())
    for e in errors:
        print(f"FAIL: {e}")
    if not errors:
        print(f"docs OK: {len(doc_files())} files checked, "
              f"module map complete, public API pinned "
              f"({len(PUBLIC_API)} names)")
    return 1 if errors else 0


if __name__ == "__main__":
    raise SystemExit(main())
