"""simlint engine: file collection, pragma parsing, rule registry, output.

The simulator's headline results only hold because the fabric clock is
exact and replays are bit-identical. Those invariants are easy to violate
with one innocuous line (`time.monotonic()` in a heartbeat, a shared
mutable default policy — both shipped in PR 7 and had to be hand-fixed),
so they are enforced here as a machine-checked contract: an AST +
lightweight-dataflow analysis with one rule per invariant, run over
`src/repro` in CI (`tools/lint_all.py`).

Suppression pragmas (per line, justification REQUIRED):

    something_suspicious()   # simlint: disable=SIM001 -- host-side CLI timer

A pragma may also sit alone on the line directly above the finding, or on
any line of a multi-line statement's span. A pragma without a
justification (`-- reason`) is itself a finding (SIM000). The legacy
`# deprecated-ok: reason` spelling is honored as `disable=SIM007` and
warns once per run.
"""
from __future__ import annotations

import ast
import dataclasses
import io
import json
import re
import sys
import tokenize
from dataclasses import dataclass, field
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

ROOT = Path(__file__).resolve().parents[2]

PRAGMA_RE = re.compile(
    r"#\s*simlint:\s*disable=([A-Z]{3}\d{3}(?:\s*,\s*[A-Z]{3}\d{3})*)"
    r"(?:\s+--\s*(.*\S))?\s*$")
LEGACY_PRAGMA_RE = re.compile(r"#\s*deprecated-ok\b:?\s*(.*\S)?\s*$")
PRAGMA_ONLY_LINE_RE = re.compile(r"^\s*#")


def scan_pragmas(source: str) -> Dict[int, "Pragma"]:
    """Pragmas by line, from REAL comment tokens only — a docstring that
    talks about `# simlint: disable=...` is not a suppression."""
    out: Dict[int, Pragma] = {}
    try:
        tokens = tokenize.generate_tokens(io.StringIO(source).readline)
        for tok in tokens:
            if tok.type != tokenize.COMMENT:
                continue
            i = tok.start[0]
            m = PRAGMA_RE.search(tok.string)
            if m:
                codes = tuple(c.strip() for c in m.group(1).split(","))
                out[i] = Pragma(i, codes, m.group(2), legacy=False)
                continue
            m = LEGACY_PRAGMA_RE.search(tok.string)
            if m:
                out[i] = Pragma(i, ("SIM007",), m.group(1), legacy=True)
    except tokenize.TokenError:
        pass                    # unparseable files are reported via SIM000
    return out


@dataclass(frozen=True)
class Finding:
    """One rule violation, anchored to a source location."""
    code: str                  # e.g. "SIM001"
    path: str                  # repo-relative posix path
    line: int                  # 1-indexed
    col: int                   # 0-indexed (ast convention)
    message: str
    justification: Optional[str] = None   # set when suppressed

    def format(self) -> str:
        return f"{self.path}:{self.line}:{self.col}: {self.code} {self.message}"

    def to_dict(self) -> Dict:
        d = dataclasses.asdict(self)
        if self.justification is None:
            d.pop("justification")
        return d


@dataclass(frozen=True)
class Pragma:
    line: int
    codes: Tuple[str, ...]
    justification: Optional[str]
    legacy: bool


@dataclass
class FileCtx:
    """One parsed source file plus its suppression pragmas."""
    path: Path
    rel: str
    source: str
    lines: List[str]
    tree: ast.AST
    pragmas: Dict[int, Pragma] = field(default_factory=dict)

    @classmethod
    def parse(cls, path: Path, rel: str) -> "FileCtx":
        source = path.read_text()
        tree = ast.parse(source, filename=str(path))
        ctx = cls(path, rel, source, source.splitlines(), tree)
        ctx.pragmas = scan_pragmas(source)
        return ctx

    def pragma_for(self, code: str, span: Tuple[int, int]) -> Optional[Pragma]:
        """The pragma suppressing `code` over line span [start, end]: on any
        line of the span, or in the contiguous comment block just above
        (so a pragma's justification may continue over several comment
        lines)."""
        start, end = span
        for i in range(start, end + 1):
            p = self.pragmas.get(i)
            if p and code in p.codes:
                return p
        i = start - 1
        while 0 < i <= len(self.lines) and \
                PRAGMA_ONLY_LINE_RE.match(self.lines[i - 1]):
            p = self.pragmas.get(i)
            if p and code in p.codes:
                return p
            i -= 1
        return None


@dataclass
class Project:
    """Cross-file context shared by all rules in one run."""
    root: Path
    files: List[FileCtx]
    # class name -> frozen? for every @dataclass seen in the scanned files
    # (SIM003 flags defaults that construct a non-frozen dataclass)
    dataclasses_frozen: Dict[str, bool] = field(default_factory=dict)


class Rule:
    """One invariant. Subclasses set `code`/`name`/`description` and
    implement `check` (per file) and/or `check_project` (once per run);
    `applies` scopes the rule to repo-relative path prefixes."""
    code: str = "SIM000"
    name: str = "base"
    description: str = ""

    def applies(self, rel: str) -> bool:
        return True

    def check(self, ctx: FileCtx, project: Project) -> Iterable[Finding]:
        return ()

    def check_project(self, project: Project) -> Iterable[Finding]:
        return ()

    # Span the suppression pragma is honored over; rules that anchor a
    # finding inside a multi-line statement pass the statement node.
    @staticmethod
    def span(node: ast.AST) -> Tuple[int, int]:
        return (node.lineno, getattr(node, "end_lineno", node.lineno)
                or node.lineno)


def _scan_dataclasses(files: Sequence[FileCtx]) -> Dict[str, bool]:
    """Project pre-pass: every @dataclass class name -> frozen flag."""
    out: Dict[str, bool] = {}
    for ctx in files:
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.ClassDef):
                continue
            for dec in node.decorator_list:
                target = dec.func if isinstance(dec, ast.Call) else dec
                dname = target.attr if isinstance(target, ast.Attribute) \
                    else getattr(target, "id", None)
                if dname != "dataclass":
                    continue
                frozen = False
                if isinstance(dec, ast.Call):
                    for kw in dec.keywords:
                        if kw.arg == "frozen" and \
                                isinstance(kw.value, ast.Constant):
                            frozen = bool(kw.value.value)
                out[node.name] = frozen
    return out


def collect_files(paths: Sequence[str], root: Path = ROOT) -> List[Path]:
    out: List[Path] = []
    for p in paths:
        base = (root / p) if not Path(p).is_absolute() else Path(p)
        if base.is_file() and base.suffix == ".py":
            out.append(base)
        elif base.is_dir():
            out.extend(sorted(f for f in base.rglob("*.py")
                              if "__pycache__" not in f.parts))
        else:
            raise FileNotFoundError(f"simlint: no such path: {p}")
    seen: Set[Path] = set()
    uniq = []
    for f in out:
        if f not in seen:
            seen.add(f)
            uniq.append(f)
    return uniq


@dataclass
class Report:
    findings: List[Finding]
    suppressed: List[Finding]
    parse_errors: List[Finding]
    n_files: int
    legacy_pragma_files: List[str]

    @property
    def failed(self) -> bool:
        return bool(self.findings or self.parse_errors)

    def to_dict(self) -> Dict:
        return {
            "tool": "simlint",
            "files_scanned": self.n_files,
            "findings": [f.to_dict() for f in self.findings],
            "suppressed": [f.to_dict() for f in self.suppressed],
            "parse_errors": [f.to_dict() for f in self.parse_errors],
            "summary": {"findings": len(self.findings),
                        "suppressed": len(self.suppressed),
                        "parse_errors": len(self.parse_errors)},
        }


def run(paths: Sequence[str], rules: Sequence[Rule],
        root: Path = ROOT) -> Report:
    """Lint `paths` (files or directories, relative to `root`) with
    `rules`, applying suppression pragmas. Findings keep source order."""
    parse_errors: List[Finding] = []
    files: List[FileCtx] = []
    for f in collect_files(paths, root):
        rel = f.relative_to(root).as_posix() if f.is_relative_to(root) \
            else f.as_posix()
        try:
            files.append(FileCtx.parse(f, rel))
        except SyntaxError as e:
            parse_errors.append(Finding("SIM000", rel, e.lineno or 1, 0,
                                        f"unparseable: {e.msg}"))
    project = Project(root=root, files=files,
                      dataclasses_frozen=_scan_dataclasses(files))
    raw: List[Tuple[Finding, Tuple[int, int], FileCtx]] = []
    for rule in rules:
        for ctx in files:
            if not rule.applies(ctx.rel):
                continue
            for fnd in rule.check(ctx, project):
                raw.append((fnd, getattr(fnd, "_span", None) or
                            (fnd.line, fnd.line), ctx))
        for fnd in rule.check_project(project):
            raw.append((fnd, (fnd.line, fnd.line), None))

    ctx_by_rel = {c.rel: c for c in files}
    findings: List[Finding] = []
    suppressed: List[Finding] = []
    for fnd, span, ctx in raw:
        ctx = ctx or ctx_by_rel.get(fnd.path)
        pragma = ctx.pragma_for(fnd.code, span) if ctx else None
        if pragma is None:
            findings.append(fnd)
        else:
            suppressed.append(dataclasses.replace(
                fnd, justification=pragma.justification or ""))

    # every suppression must say why: a pragma with no `-- reason` is a
    # finding in its own right (and legacy pragmas must carry trailing text)
    for ctx in files:
        findings.extend(justification_findings(ctx))

    legacy = sorted({c.rel for c in files
                     for p in c.pragmas.values() if p.legacy})
    findings.sort(key=lambda f: (f.path, f.line, f.code))
    suppressed.sort(key=lambda f: (f.path, f.line, f.code))
    return Report(findings, suppressed, parse_errors, len(files), legacy)


def lint_text(source: str, rel: str = "src/repro/_fixture_.py",
              rules: Optional[Sequence[Rule]] = None) -> List[Finding]:
    """Lint a source string as if it lived at repo path `rel` — the unit
    of the fixture tests. Project context is built from this file alone."""
    from tools.simlint.rules import default_rules
    rules = list(rules) if rules is not None else default_rules()
    tree = ast.parse(source)
    ctx = FileCtx(Path("/fixture") / rel, rel, source,
                  source.splitlines(), tree)
    ctx.pragmas = scan_pragmas(source)
    project = Project(root=ROOT, files=[ctx],
                      dataclasses_frozen=_scan_dataclasses([ctx]))
    out: List[Finding] = []
    for rule in rules:
        if not rule.applies(rel):
            continue
        for fnd in rule.check(ctx, project):
            span = getattr(fnd, "_span", None) or (fnd.line, fnd.line)
            if ctx.pragma_for(fnd.code, span) is None:
                out.append(fnd)
    out.extend(justification_findings(ctx))
    out.sort(key=lambda f: (f.path, f.line, f.code))
    return out


def justification_findings(ctx: FileCtx) -> List[Finding]:
    """SIM000 for every suppression pragma that doesn't say why."""
    out: List[Finding] = []
    for p in ctx.pragmas.values():
        if p.justification:
            continue
        spelling = "# deprecated-ok" if p.legacy else \
            f"# simlint: disable={','.join(p.codes)}"
        out.append(Finding(
            "SIM000", ctx.rel, p.line, 0,
            f"suppression `{spelling}` has no justification — append "
            "` -- <why this is safe>`"))
    return out


def attach_span(fnd: Finding, node: ast.AST) -> Finding:
    """Anchor the pragma-matching span of `fnd` to `node`'s full line
    range (for findings inside multi-line statements)."""
    object.__setattr__(fnd, "_span", Rule.span(node))
    return fnd
