"""Lightweight flow/type analyses shared by the simlint rules.

Nothing here executes code: everything is a conservative approximation
over the AST, tuned for the idioms this repo actually uses (annotated
`self.x: Dict[...] = {}` attributes, small imperative methods). The two
entry points:

* `every_path_reaches` — statement-level path analysis: from a given
  statement, does EVERY execution path to function exit pass a matching
  call? (SIM004's topology-mutation/`_bump_epoch` contract.)
* `ContainerKinds` — per-function set/dict typing from annotations and
  constructor assignments (SIM006's unordered-iteration check).
"""
from __future__ import annotations

import ast
import re
from typing import Callable, Dict, Iterable, List, Optional, Sequence, Tuple

StmtSeq = Tuple[ast.stmt, ...]
Frames = Tuple[StmtSeq, ...]


# --------------------------------------------------------------------------- #
# Path analysis (SIM004)
# --------------------------------------------------------------------------- #
def stmt_contains_call(stmt: ast.AST, match: Callable[[ast.Call], bool]
                       ) -> bool:
    return any(isinstance(n, ast.Call) and match(n)
               for n in ast.walk(stmt))


def _all_paths_call(frames: Frames, match: Callable[[ast.Call], bool]
                    ) -> bool:
    """True iff every path through the remaining statements (`frames` is a
    stack of statement sequences, innermost first) contains a matching
    call before the function exits normally. `return` exits without one;
    `raise` is treated as an exit too (the mutation already happened, so
    an exceptional exit with a stale epoch is still a violation). Loops
    are assumed skippable (0 iterations), so a call inside a loop body
    never satisfies the requirement on its own."""
    if not frames:
        return False                    # fell off the end: no call seen
    head, rest = frames[0], frames[1:]
    if not head:
        return _all_paths_call(rest, match)
    s, tail = head[0], tuple(head[1:])
    cont: Frames = (tail,) + rest
    if isinstance(s, (ast.Return, ast.Raise, ast.Continue, ast.Break)):
        # a matching call in the returned expression still counts
        return stmt_contains_call(s, match)
    if isinstance(s, ast.If):
        return (_all_paths_call((tuple(s.body),) + cont, match)
                and _all_paths_call((tuple(s.orelse),) + cont, match))
    if isinstance(s, (ast.For, ast.AsyncFor, ast.While)):
        # body may run zero times: only the continuation counts
        return _all_paths_call(cont, match)
    if isinstance(s, (ast.With, ast.AsyncWith)):
        return _all_paths_call((tuple(s.body),) + cont, match)
    if isinstance(s, ast.Try):
        # conservative: the happy path is body -> orelse -> finally; a
        # handler path must ALSO reach the call (or re-raise) on its own
        happy = tuple(s.body) + tuple(s.orelse) + tuple(s.finalbody)
        if not _all_paths_call((happy,) + cont, match):
            return False
        for h in s.handlers:
            hpath = tuple(h.body) + tuple(s.finalbody)
            if not _all_paths_call((hpath,) + cont, match):
                return False
        return True
    if isinstance(s, ast.Match):
        return all(_all_paths_call((tuple(c.body),) + cont, match)
                   for c in s.cases) and bool(s.cases)
    # simple statement: a matching call anywhere in it covers all paths
    if stmt_contains_call(s, match):
        return True
    return _all_paths_call(cont, match)


def walk_with_continuations(body: Sequence[ast.stmt], frames: Frames = ()
                            ) -> Iterable[Tuple[ast.stmt, Frames]]:
    """Yield every statement in `body` (recursively) together with the
    continuation frames that follow it — what executes after the
    statement completes, innermost sequence first."""
    for i, s in enumerate(body):
        cont: Frames = (tuple(body[i + 1:]),) + frames
        yield s, cont
        if isinstance(s, ast.If):
            yield from walk_with_continuations(s.body, cont)
            yield from walk_with_continuations(s.orelse, cont)
        elif isinstance(s, (ast.For, ast.AsyncFor, ast.While)):
            yield from walk_with_continuations(s.body, cont)
            yield from walk_with_continuations(s.orelse, cont)
        elif isinstance(s, (ast.With, ast.AsyncWith)):
            yield from walk_with_continuations(s.body, cont)
        elif isinstance(s, ast.Try):
            after_body: Frames = ((tuple(s.orelse) + tuple(s.finalbody)),) \
                + cont
            yield from walk_with_continuations(s.body, after_body)
            for h in s.handlers:
                yield from walk_with_continuations(
                    h.body, (tuple(s.finalbody),) + cont)
            yield from walk_with_continuations(s.orelse,
                                               (tuple(s.finalbody),) + cont)
            yield from walk_with_continuations(s.finalbody, cont)
        elif isinstance(s, ast.Match):
            for c in s.cases:
                yield from walk_with_continuations(c.body, cont)


def every_path_reaches(stmt: ast.stmt, cont: Frames,
                       match: Callable[[ast.Call], bool]) -> bool:
    """Does every path from (and including) `stmt` to function exit pass a
    matching call? `cont` comes from `walk_with_continuations`."""
    if stmt_contains_call(stmt, match):
        return True
    return _all_paths_call(cont, match)


# --------------------------------------------------------------------------- #
# Container-kind inference (SIM006)
# --------------------------------------------------------------------------- #
_SET_ANN = re.compile(r"\b(?:set|Set|AbstractSet|frozenset|FrozenSet)\b")
_DICT_ANN = re.compile(
    r"\b(?:dict|Dict|defaultdict|DefaultDict|OrderedDict|Counter|Mapping|"
    r"MutableMapping)\b")
_SET_METHODS = {"intersection", "union", "difference",
                "symmetric_difference"}


def _ann_kind(ann: Optional[ast.expr]) -> Optional[str]:
    if ann is None:
        return None
    text = ast.unparse(ann)
    if _SET_ANN.search(text):
        return "set"
    if _DICT_ANN.search(text):
        return "dict"
    return None


def _key_of(target: ast.expr) -> Optional[str]:
    """Binding key for a Name (`x`) or a self attribute (`self.x`)."""
    if isinstance(target, ast.Name):
        return target.id
    if isinstance(target, ast.Attribute) and \
            isinstance(target.value, ast.Name) and target.value.id == "self":
        return f"self.{target.attr}"
    return None


class ContainerKinds:
    """name / "self.attr" -> "set" | "dict", inferred from annotations and
    literal/constructor assignments over a class body + one function."""

    def __init__(self, func: ast.AST,
                 enclosing_class: Optional[ast.ClassDef] = None):
        self.kinds: Dict[str, str] = {}
        if enclosing_class is not None:
            for node in ast.walk(enclosing_class):
                self._learn(node)
        for node in ast.walk(func):
            self._learn(node)

    def _learn(self, node: ast.AST) -> None:
        if isinstance(node, ast.arg) and node.annotation is not None:
            kind = _ann_kind(node.annotation)
            if kind and node.arg not in self.kinds:
                self.kinds[node.arg] = kind
        elif isinstance(node, ast.AnnAssign):
            key = _key_of(node.target)
            kind = _ann_kind(node.annotation)
            if key and kind:
                self.kinds[key] = kind
        elif isinstance(node, ast.Assign) and len(node.targets) == 1:
            key = _key_of(node.targets[0])
            kind = self.expr_kind(node.value, learning=True)
            if key and kind and key not in self.kinds:
                self.kinds[key] = kind

    def expr_kind(self, expr: ast.expr, learning: bool = False
                  ) -> Optional[str]:
        """The container kind of `expr`, or None if unknown/ordered.
        `sorted(...)`/`list(...)`/`tuple(...)` wrappers return None — they
        impose an order, which is the approved escape hatch."""
        if isinstance(expr, (ast.Set, ast.SetComp)):
            return "set"
        if isinstance(expr, (ast.Dict, ast.DictComp)):
            return "dict"
        if isinstance(expr, ast.BinOp) and isinstance(
                expr.op, (ast.BitOr, ast.BitAnd, ast.Sub, ast.BitXor)):
            left = self.expr_kind(expr.left)
            right = self.expr_kind(expr.right)
            if "set" in (left, right):
                return "set"
            return None
        if isinstance(expr, ast.Call):
            fn = expr.func
            if isinstance(fn, ast.Name):
                if fn.id in ("set", "frozenset"):
                    return "set"
                if fn.id in ("dict", "defaultdict", "Counter",
                             "OrderedDict"):
                    return "dict"
                return None
            if isinstance(fn, ast.Attribute):
                if fn.attr in _SET_METHODS:
                    return "set"
                if fn.attr in ("keys", "values", "items") and not learning:
                    # view over a known dict: unordered for our purposes
                    return "dict" if self.expr_kind(fn.value) == "dict" \
                        else None
                if fn.attr == "copy":
                    return self.expr_kind(fn.value)
            return None
        key = _key_of(expr)
        if key is not None:
            return self.kinds.get(key)
        return None
