"""CLI: `python -m tools.simlint [paths...] [--json out] [--select codes]`.

Exit 0 = no unsuppressed findings; exit 1 = findings (each printed as
`path:line:col: CODE message`); exit 2 = usage error. Run from the repo
root (paths are repo-relative). Default paths cover everything the CI
lint lane checks.
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

from tools.simlint.engine import ROOT, run
from tools.simlint.rules import default_rules

DEFAULT_PATHS = ("src", "tests", "benchmarks", "examples", "tools")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m tools.simlint",
        description="flow-aware determinism lint for the FFTrainer repro")
    ap.add_argument("paths", nargs="*", default=list(DEFAULT_PATHS),
                    help="files/dirs relative to the repo root "
                         f"(default: {' '.join(DEFAULT_PATHS)})")
    ap.add_argument("--json", metavar="FILE", default=None,
                    help="also write the full report (findings + "
                         "suppressions) as JSON")
    ap.add_argument("--select", metavar="CODES", default=None,
                    help="comma-separated rule codes to run "
                         "(e.g. SIM001,SIM004)")
    ap.add_argument("--list-rules", action="store_true",
                    help="print the rule catalog and exit")
    args = ap.parse_args(argv)

    rules = default_rules()
    if args.list_rules:
        for r in rules:
            print(f"{r.code}  {r.name}: {r.description}")
        return 0
    if args.select:
        wanted = {c.strip().upper() for c in args.select.split(",")}
        unknown = wanted - {r.code for r in rules}
        if unknown:
            print(f"simlint: unknown rule code(s): {sorted(unknown)}",
                  file=sys.stderr)
            return 2
        rules = [r for r in rules if r.code in wanted]

    # drop default paths that don't exist in this checkout (e.g. examples/)
    paths = [p for p in args.paths
             if (ROOT / p).exists() or Path(p).exists()]
    if not paths:
        print("simlint: no paths to scan", file=sys.stderr)
        return 2
    report = run(paths, rules)

    for f in report.parse_errors + report.findings:
        print(f.format())
    if report.legacy_pragma_files:
        print("simlint: note: legacy `# deprecated-ok` pragma(s) in "
              f"{', '.join(report.legacy_pragma_files)} — prefer "
              "`# simlint: disable=SIM007 -- reason`", file=sys.stderr)
    if args.json:
        out = Path(args.json)
        out.parent.mkdir(parents=True, exist_ok=True)
        out.write_text(json.dumps(report.to_dict(), indent=2) + "\n")
    status = "FAIL" if report.failed else "OK"
    print(f"simlint {status}: {report.n_files} files, "
          f"{len(report.findings)} finding(s), "
          f"{len(report.suppressed)} suppressed, "
          f"{len(report.parse_errors)} parse error(s)")
    return 1 if report.failed else 0


if __name__ == "__main__":
    raise SystemExit(main())
