"""simlint — flow-aware static analysis for the simulator's determinism
and exactness invariants. See docs/simlint.md for the rule catalog.

Programmatic entry points:

    from tools.simlint import run, default_rules, lint_text
    report = run(["src/repro"], default_rules())
"""
from tools.simlint.engine import (Finding, Pragma, Report, Rule, lint_text,
                                  run)
from tools.simlint.rules import default_rules

__all__ = ["Finding", "Pragma", "Report", "Rule", "default_rules",
           "lint_text", "run"]
