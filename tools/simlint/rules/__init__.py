"""simlint rule registry.

One module per invariant; `default_rules()` is the registry the CLI and
the fixture tests run. Adding a rule = add a module with a `Rule`
subclass, list it here, document it in docs/simlint.md.
"""
from __future__ import annotations

from typing import List

from tools.simlint.engine import Rule
from tools.simlint.rules.wallclock import WallClockRule
from tools.simlint.rules.randomness import UnseededRandomRule
from tools.simlint.rules.mutable_defaults import MutableDefaultRule
from tools.simlint.rules.epoch_bump import EpochBumpRule
from tools.simlint.rules.float_eq import FloatClockEqRule
from tools.simlint.rules.unordered_iter import UnorderedIterRule
from tools.simlint.rules.deprecations import DeprecatedKwargsRule
from tools.simlint.rules.api_pin import PublicApiPinRule


def default_rules() -> List[Rule]:
    return [
        WallClockRule(),
        UnseededRandomRule(),
        MutableDefaultRule(),
        EpochBumpRule(),
        FloatClockEqRule(),
        UnorderedIterRule(),
        DeprecatedKwargsRule(),
        PublicApiPinRule(),
    ]


__all__ = ["default_rules"]
