"""SIM003 — mutable default arguments and dataclass field defaults.

The PR 7 straggler bug: a class-level `StragglerPolicy()` default was
shared by every ReliabilityController, so one controller's mitigation
state leaked into the next scenario's replay. Python only raises for
list/dict/set defaults on dataclass *fields*; plain function defaults
and mutable dataclass-instance defaults slip through — this rule flags
all of them. Use `None` + in-body init, or `field(default_factory=...)`.
"""
from __future__ import annotations

import ast
from typing import Iterable, List, Optional, Tuple

from tools.simlint.engine import FileCtx, Finding, Project, Rule

MUTABLE_CTORS = {"list", "dict", "set", "bytearray", "deque", "defaultdict",
                 "Counter", "OrderedDict"}
DISPLAY_NODES = (ast.List, ast.Dict, ast.Set, ast.ListComp, ast.DictComp,
                 ast.SetComp)


def _ctor_name(call: ast.Call) -> Optional[str]:
    fn = call.func
    if isinstance(fn, ast.Name):
        return fn.id
    if isinstance(fn, ast.Attribute):
        return fn.attr
    return None


class MutableDefaultRule(Rule):
    code = "SIM003"
    name = "mutable-default"
    description = ("mutable default argument / dataclass field default — "
                   "shared across calls/instances; use "
                   "field(default_factory=...) or None")

    def applies(self, rel: str) -> bool:
        return rel.startswith("src/repro/")

    def _is_mutable_default(self, node: ast.expr,
                            project: Project) -> Optional[str]:
        """Reason string if `node` is a mutable default, else None."""
        if isinstance(node, DISPLAY_NODES):
            return "literal %s" % type(node).__name__.lower()
        if isinstance(node, ast.Call):
            name = _ctor_name(node)
            if name in MUTABLE_CTORS:
                return f"{name}() instance"
            if name in project.dataclasses_frozen and \
                    not project.dataclasses_frozen[name]:
                return f"non-frozen dataclass {name}() instance"
        return None

    def check(self, ctx: FileCtx, project: Project) -> Iterable[Finding]:
        dataclass_bodies = {
            id(stmt)
            for node in ast.walk(ctx.tree) if isinstance(node, ast.ClassDef)
            and node.name in project.dataclasses_frozen
            for stmt in node.body}
        for node in ast.walk(ctx.tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                yield from self._check_function(ctx, project, node)
            elif isinstance(node, ast.AnnAssign) and \
                    id(node) in dataclass_bodies and node.value is not None:
                # dataclass raises on list/dict/set itself; the gap is
                # instances of mutable classes (the PR 7 bug)
                reason = self._is_mutable_default(node.value, project)
                if reason:
                    yield Finding(
                        self.code, ctx.rel, node.value.lineno,
                        node.value.col_offset,
                        f"dataclass field default is a {reason}, shared by "
                        "every instance — use field(default_factory=...)")

    def _check_function(self, ctx: FileCtx, project: Project,
                        fn) -> Iterable[Finding]:
        args = fn.args
        defaults: List[Tuple[ast.arg, ast.expr]] = []
        pos = args.posonlyargs + args.args
        for a, d in zip(pos[len(pos) - len(args.defaults):], args.defaults):
            defaults.append((a, d))
        for a, d in zip(args.kwonlyargs, args.kw_defaults):
            if d is not None:
                defaults.append((a, d))
        for a, d in defaults:
            reason = self._is_mutable_default(d, project)
            if reason:
                yield Finding(
                    self.code, ctx.rel, d.lineno, d.col_offset,
                    f"default for `{a.arg}` is a {reason}, shared across "
                    "calls — default to None and construct in the body")
