"""SIM007 — internal callers of the deprecated flat-kwargs API.

Port of `tools/check_deprecations.py` into the simlint engine (that
script is now a thin shim over this rule). The ISSUE 6 redesign keeps
`SimCluster(dp=..., link_bw=...)` / `recover(hardware=...)` working for
downstream users; repo-internal code must use
`ClusterConfig`/`FabricConfig`/`FaultScript`. Back-compat tests that
exercise the shims on purpose carry `# simlint: disable=SIM007 -- ...`
(the legacy `# deprecated-ok: reason` spelling still works).
"""
from __future__ import annotations

import ast
from typing import Iterable, Optional

from tools.simlint.engine import FileCtx, Finding, Project, Rule, attach_span

LEGACY_CLUSTER_KWARGS = {
    "dp", "global_batch", "seq_len", "dataset_size", "hp", "ckpt_dir",
    "full_every", "seed", "link_bw", "quantum", "t_iter_model", "topology",
    "edge_bw", "pods", "dcn_bw", "ici_latency", "dcn_latency", "compile_plan",
}
LEGACY_RECOVER_KWARGS = {"hardware", "interrupt_after_chunks",
                         "corrupt_chunks"}
SCAN_PREFIXES = ("src/", "tests/", "benchmarks/", "examples/", "tools/")


def _call_name(node: ast.Call) -> Optional[str]:
    fn = node.func
    if isinstance(fn, ast.Attribute):
        return fn.attr
    if isinstance(fn, ast.Name):
        return fn.id
    return None


class DeprecatedKwargsRule(Rule):
    code = "SIM007"
    name = "deprecated-kwargs"
    description = ("internal caller of the shimmed legacy kwargs — use "
                   "ClusterConfig/FabricConfig/FaultScript")

    def applies(self, rel: str) -> bool:
        return rel.startswith(SCAN_PREFIXES) and \
            not rel.startswith("tools/simlint/")

    def check(self, ctx: FileCtx, project: Project) -> Iterable[Finding]:
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Call):
                continue
            name = _call_name(node)
            kwnames = {k.arg for k in node.keywords if k.arg}
            bad = None
            if name == "SimCluster" and kwnames & LEGACY_CLUSTER_KWARGS:
                bad = (f"SimCluster({sorted(kwnames & LEGACY_CLUSTER_KWARGS)}"
                       ") — use cluster=ClusterConfig(...) / "
                       "fabric=FabricConfig(...)")
            elif name == "from_kwargs" and \
                    isinstance(node.func, ast.Attribute):
                bad = "SimCluster.from_kwargs(...) — deprecated shim"
            elif name == "recover" and isinstance(node.func, ast.Attribute) \
                    and kwnames & LEGACY_RECOVER_KWARGS:
                bad = (f"recover({sorted(kwnames & LEGACY_RECOVER_KWARGS)}"
                       ") — use faults=FaultScript(...)")
            if bad is None:
                continue
            yield attach_span(Finding(
                self.code, ctx.rel, node.lineno, node.col_offset,
                f"deprecated call: {bad}"), node)
