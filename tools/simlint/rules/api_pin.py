"""SIM008 — the pinned public API surface.

Single source of truth for the `repro` export list (moved here from
`tools/check_docs.py`, which now imports `PUBLIC_API` from this module).
Statically parses `src/repro/__init__.py` — no imports, so it runs on a
checkout without jax — and checks three things stay in lockstep:

1. `__all__` equals the pin (both directions),
2. `_EXPORTS` (the lazy-import table) covers exactly `__all__`,
3. README.md mentions every pinned name.

Changing the surface means changing the pin HERE, `repro/__init__.py`,
and the README together — exactly the failure mode this makes loud.
"""
from __future__ import annotations

import ast
from typing import Dict, Iterable, List, Optional

from tools.simlint.engine import FileCtx, Finding, Project, Rule

PUBLIC_API = (
    "SimCluster",
    "ClusterConfig",
    "FabricConfig",
    "FaultScript",
    "RecoveryPolicy",
    "RecoveryPlan",
    "RecoveryReport",
    "RecoveryError",
    "RoutingError",
    "StreamRecovery",
    "ComputeRecovery",
    "HybridRecovery",
    "fftrainer_timeline",
    "baseline_timeline",
    "compute_recovery_timeline",
    "PodFabric",
    "TrafficPlan",
    "compile_traffic_plan",
    "ReliabilityConfig",
    "Scenario",
    "run_scenario",
)

INIT_REL = "src/repro/__init__.py"


def _str_list(node: ast.expr) -> Optional[List[str]]:
    if isinstance(node, (ast.List, ast.Tuple)) and all(
            isinstance(e, ast.Constant) and isinstance(e.value, str)
            for e in node.elts):
        return [e.value for e in node.elts]
    return None


def _str_dict_keys(node: ast.expr) -> Optional[List[str]]:
    if isinstance(node, ast.Dict) and all(
            isinstance(k, ast.Constant) and isinstance(k.value, str)
            for k in node.keys):
        return [k.value for k in node.keys]
    return None


class PublicApiPinRule(Rule):
    code = "SIM008"
    name = "public-api-pin"
    description = ("repro.__all__ / _EXPORTS drifted from the pinned "
                   "public API (or README stopped mentioning a name)")

    def applies(self, rel: str) -> bool:
        return rel == INIT_REL

    def check(self, ctx: FileCtx, project: Project) -> Iterable[Finding]:
        all_names: Optional[List[str]] = None
        exports: Optional[List[str]] = None
        lineno: Dict[str, int] = {"__all__": 1, "_EXPORTS": 1}
        for node in ctx.tree.body:
            if isinstance(node, ast.Assign) and len(node.targets) == 1 and \
                    isinstance(node.targets[0], ast.Name):
                tname = node.targets[0].id
                if tname == "__all__":
                    all_names = _str_list(node.value)
                    lineno["__all__"] = node.lineno
                elif tname == "_EXPORTS":
                    exports = _str_dict_keys(node.value)
                    lineno["_EXPORTS"] = node.lineno

        def fnd(key: str, msg: str) -> Finding:
            return Finding(self.code, ctx.rel, lineno[key], 0, msg)

        if all_names is None:
            yield fnd("__all__", "could not statically read __all__ (must "
                      "be a literal list of strings)")
            return
        declared, pinned = set(all_names), set(PUBLIC_API)
        for name in sorted(pinned - declared):
            yield fnd("__all__", f"public API: `{name}` is pinned "
                      "(tools/simlint/rules/api_pin.py) but missing from "
                      "repro.__all__")
        for name in sorted(declared - pinned):
            yield fnd("__all__", f"public API: repro.__all__ exports "
                      f"`{name}` but it is not pinned in "
                      "tools/simlint/rules/api_pin.py")
        if exports is None:
            yield fnd("_EXPORTS", "could not statically read _EXPORTS "
                      "(must be a literal dict with string keys)")
        else:
            table = set(exports)
            for name in sorted(declared - table):
                yield fnd("_EXPORTS", f"public API: `{name}` is in "
                          "__all__ but has no _EXPORTS entry — lazy "
                          "import will AttributeError")
            for name in sorted(table - declared):
                yield fnd("_EXPORTS", f"public API: _EXPORTS maps "
                          f"`{name}` which is not in __all__")
        readme = project.root / "README.md"
        if readme.exists():
            text = readme.read_text()
            for name in sorted(pinned):
                if name not in text:
                    yield fnd("__all__",
                              f"public API: README.md never mentions "
                              f"`{name}`")
