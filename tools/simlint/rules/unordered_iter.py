"""SIM006 — unordered iteration feeding event submission / verdict booking.

Iterating a `set` (or a dict whose insertion order is itself
hash-dependent) and submitting events per element makes the event
queue's tie-break order depend on `PYTHONHASHSEED` — replays diverge
with no error. Any loop or comprehension over a set/dict expression
whose body calls a scheduling/booking sink must go through
`sorted(...)` first (which this rule treats as the escape hatch), or
carry a pragma explaining why the order is already deterministic
(e.g. a dict built by insertion from a sorted edge list).
"""
from __future__ import annotations

import ast
import re
from typing import Iterable, List, Optional, Tuple

from tools.simlint.engine import FileCtx, Finding, Project, Rule, attach_span
from tools.simlint.dataflow import ContainerKinds

# Calls that feed the event queue or book results. Deliberately NOT
# plain `append`: accumulating into a local list is only a problem if
# the list is consumed unsorted, and those consumers are themselves
# sinks here.
SINK_RE = re.compile(
    r"^(submit\w*|send\w*|_?emit\w*|push\w*|enqueue\w*|schedule\w*|beat|"
    r"fail_node|fail_edge|restore_node|restore_edge|observe|report_\w+|"
    r"note_\w+|record\w*|book\w*|heappush|insort\w*)$")


def _sink_call(node: ast.Call) -> Optional[str]:
    fn = node.func
    name = fn.attr if isinstance(fn, ast.Attribute) else \
        getattr(fn, "id", None)
    if name and SINK_RE.match(name):
        return name
    return None


def _first_sink(body: List[ast.stmt]) -> Optional[str]:
    for stmt in body:
        for n in ast.walk(stmt):
            if isinstance(n, ast.Call):
                name = _sink_call(n)
                if name:
                    return name
    return None


class UnorderedIterRule(Rule):
    code = "SIM006"
    name = "unordered-iteration"
    description = ("iteration over a set/dict feeds an event-submission or "
                   "booking sink without sorted(...) — replay order "
                   "becomes hash-seed dependent")

    def applies(self, rel: str) -> bool:
        return rel.startswith("src/repro/")

    def check(self, ctx: FileCtx, project: Project) -> Iterable[Finding]:
        # enclosing class for each function, for self.attr annotations
        parents = {}
        for cls in ast.walk(ctx.tree):
            if isinstance(cls, ast.ClassDef):
                for fn in cls.body:
                    if isinstance(fn, (ast.FunctionDef,
                                       ast.AsyncFunctionDef)):
                        parents[id(fn)] = cls
        funcs = [n for n in ast.walk(ctx.tree)
                 if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))]
        for fn in funcs:
            kinds = ContainerKinds(fn, parents.get(id(fn)))
            for node in ast.walk(fn):
                if isinstance(node, (ast.For, ast.AsyncFor)):
                    kind = kinds.expr_kind(node.iter)
                    if kind is None:
                        continue
                    sink = _first_sink(node.body)
                    if sink is None:
                        continue
                    yield attach_span(Finding(
                        self.code, ctx.rel, node.lineno, node.col_offset,
                        f"loop over unordered {kind} "
                        f"`{ast.unparse(node.iter)}` calls sink "
                        f"`{sink}(...)` — wrap the iterable in sorted(...) "
                        "or justify the insertion order"), node)
                elif isinstance(node, (ast.ListComp, ast.SetComp,
                                       ast.GeneratorExp)):
                    sink = None
                    for n in ast.walk(node.elt):
                        if isinstance(n, ast.Call):
                            sink = _sink_call(n)
                            if sink:
                                break
                    if sink is None:
                        continue
                    for gen in node.generators:
                        kind = kinds.expr_kind(gen.iter)
                        if kind is None:
                            continue
                        yield attach_span(Finding(
                            self.code, ctx.rel, node.lineno,
                            node.col_offset,
                            f"comprehension over unordered {kind} "
                            f"`{ast.unparse(gen.iter)}` calls sink "
                            f"`{sink}(...)` — wrap in sorted(...) or "
                            "justify the insertion order"), node)
                        break
