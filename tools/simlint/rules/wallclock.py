"""SIM001 — wall-clock reads inside the simulator.

The fabric clock is event-driven and exact; any `time.monotonic()` /
`time.time()` / `datetime.now()` read inside `src/repro` couples sim
results to host scheduling (the PR 7 heartbeat bug: `beat(now=None)`
silently fell back to `time.monotonic()`). Sim code must thread the sim
clock explicitly. Host-side launch/CLI timing under `src/repro/launch/`
is exempt by allowlist.
"""
from __future__ import annotations

import ast
from typing import Iterable, Set

from tools.simlint.engine import FileCtx, Finding, Project, Rule

BANNED_TIME = {"time", "time_ns", "monotonic", "monotonic_ns",
               "perf_counter", "perf_counter_ns", "clock", "process_time",
               "process_time_ns"}
BANNED_DATETIME = {"now", "utcnow", "today"}
ALLOW_PREFIXES = ("src/repro/launch/",)


class WallClockRule(Rule):
    code = "SIM001"
    name = "wall-clock-ban"
    description = ("wall-clock read (`time.*`, `datetime.now`) inside the "
                   "simulator — thread the sim clock instead")

    def applies(self, rel: str) -> bool:
        return rel.startswith("src/repro/") and \
            not rel.startswith(ALLOW_PREFIXES)

    def check(self, ctx: FileCtx, project: Project) -> Iterable[Finding]:
        # `from time import monotonic [as m]` binds bare names to ban
        from_time: Set[str] = set()
        from_datetime: Set[str] = set()
        for node in ast.walk(ctx.tree):
            if isinstance(node, ast.ImportFrom) and node.level == 0:
                if node.module == "time":
                    from_time.update(a.asname or a.name for a in node.names
                                     if a.name in BANNED_TIME)
                elif node.module in ("datetime",):
                    from_datetime.update(a.asname or a.name
                                         for a in node.names
                                         if a.name in ("datetime", "date"))
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Call):
                continue
            fn = node.func
            if isinstance(fn, ast.Name) and fn.id in from_time:
                yield self._finding(ctx, node, f"time.{fn.id}()")
            elif isinstance(fn, ast.Attribute):
                base = fn.value
                if isinstance(base, ast.Name) and base.id == "time" \
                        and fn.attr in BANNED_TIME:
                    yield self._finding(ctx, node, f"time.{fn.attr}()")
                elif fn.attr in BANNED_DATETIME and self._is_datetime(
                        base, from_datetime):
                    yield self._finding(
                        ctx, node, f"{ast.unparse(fn)}()")

    @staticmethod
    def _is_datetime(base: ast.expr, from_datetime: Set[str]) -> bool:
        if isinstance(base, ast.Name) and \
                base.id in ({"datetime", "date"} | from_datetime):
            return True
        # datetime.datetime.now() / datetime.date.today()
        return (isinstance(base, ast.Attribute)
                and isinstance(base.value, ast.Name)
                and base.value.id == "datetime"
                and base.attr in ("datetime", "date"))

    def _finding(self, ctx: FileCtx, node: ast.Call, what: str) -> Finding:
        return Finding(
            self.code, ctx.rel, node.lineno, node.col_offset,
            f"wall-clock read {what} in simulator code — pass the sim "
            "clock (`now=`) explicitly; host-side timing belongs under "
            "src/repro/launch/")
