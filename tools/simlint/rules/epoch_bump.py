"""SIM004 — topology mutation without an epoch bump (flow-sensitive).

Compiled `TrafficPlan`s (PR 6) are invalidated by `LinkTopology._epoch`:
any method that mutates node/edge/bandwidth state MUST call
`self._bump_epoch()` on every path to exit, or a stale plan replays
traffic over a topology that no longer exists — silently wrong
timings, no crash. This rule finds every mutation of tracked topology
state inside a topology class and checks, path-by-path (if/else, loops
as zero-iteration-possible, try/except, early returns), that a bump is
unavoidable downstream.
"""
from __future__ import annotations

import ast
from typing import Iterable, Optional, Set

from tools.simlint.engine import FileCtx, Finding, Project, Rule, attach_span
from tools.simlint.dataflow import every_path_reaches, walk_with_continuations

TOPOLOGY_CLASSES = {"LinkTopology", "PodFabric"}
BUMP = "_bump_epoch"
# instance attributes that participate in routing/plan compilation
TRACKED = {"dark_nodes", "dark_edges", "links", "edge_tier", "nodes",
           "edges"}
SET_MUTATORS = {"add", "discard", "remove", "clear", "update", "pop",
                "popitem", "setdefault", "difference_update",
                "intersection_update", "symmetric_difference_update"}
# methods where mutation is construction, not reconfiguration
EXEMPT_METHODS = {"__init__", "__post_init__", "_init_fabric", BUMP}


def _is_topology_class(cls: ast.ClassDef) -> bool:
    if cls.name in TOPOLOGY_CLASSES:
        return True
    for b in cls.bases:
        name = b.attr if isinstance(b, ast.Attribute) else \
            getattr(b, "id", None)
        if name in TOPOLOGY_CLASSES:
            return True
    # duck-typed: defines its own _bump_epoch contract
    return any(isinstance(s, (ast.FunctionDef, ast.AsyncFunctionDef))
               and s.name == BUMP for s in cls.body)


def _self_tracked_attr(expr: ast.expr) -> Optional[str]:
    """`self.<tracked>` -> attr name."""
    if isinstance(expr, ast.Attribute) and \
            isinstance(expr.value, ast.Name) and expr.value.id == "self" \
            and expr.attr in TRACKED:
        return expr.attr
    return None


def _derives_from_tracked(expr: ast.expr) -> bool:
    """Does `expr` syntactically reach through self.<tracked> or
    self.edge(...)/self.link(...)? Catches `self.links[k].bw = x` and
    `self.edge(u, v).bw = x`."""
    for n in ast.walk(expr):
        if _self_tracked_attr(n) is not None:
            return True
        if isinstance(n, ast.Call) and isinstance(n.func, ast.Attribute) \
                and isinstance(n.func.value, ast.Name) \
                and n.func.value.id == "self" \
                and n.func.attr in ("edge", "link", "get_edge"):
            return True
    return False


def _mutation_reason(stmt: ast.stmt) -> Optional[str]:
    """Reason string if `stmt` mutates tracked topology state."""
    if isinstance(stmt, (ast.Assign, ast.AugAssign)):
        targets = stmt.targets if isinstance(stmt, ast.Assign) \
            else [stmt.target]
        for t in targets:
            attr = _self_tracked_attr(t)
            if attr:
                return f"rebinds self.{attr}"
            if isinstance(t, ast.Subscript) and _derives_from_tracked(
                    t.value):
                return f"writes into {ast.unparse(t.value)}[...]"
            if isinstance(t, ast.Attribute) and \
                    t.attr in ("bw", "bandwidth", "latency", "tier") and \
                    _derives_from_tracked(t.value):
                return f"sets .{t.attr} on a tracked edge"
    elif isinstance(stmt, ast.Delete):
        for t in stmt.targets:
            if _self_tracked_attr(t) or (
                    isinstance(t, ast.Subscript)
                    and _derives_from_tracked(t.value)):
                return f"deletes {ast.unparse(t)}"
    elif isinstance(stmt, ast.Expr) and isinstance(stmt.value, ast.Call):
        fn = stmt.value.func
        if isinstance(fn, ast.Attribute) and fn.attr in SET_MUTATORS:
            attr = _self_tracked_attr(fn.value)
            if attr:
                return f"self.{attr}.{fn.attr}(...)"
    return None


def _is_bump_call(call: ast.Call) -> bool:
    fn = call.func
    return isinstance(fn, ast.Attribute) and fn.attr == BUMP and \
        isinstance(fn.value, ast.Name) and fn.value.id == "self"


class EpochBumpRule(Rule):
    code = "SIM004"
    name = "epoch-bump"
    description = ("topology mutation with a path to exit that skips "
                   "self._bump_epoch() — compiled TrafficPlans go stale "
                   "silently")

    def applies(self, rel: str) -> bool:
        return rel.startswith("src/repro/")

    def check(self, ctx: FileCtx, project: Project) -> Iterable[Finding]:
        for cls in ast.walk(ctx.tree):
            if not isinstance(cls, ast.ClassDef) or \
                    not _is_topology_class(cls):
                continue
            for fn in cls.body:
                if not isinstance(fn, (ast.FunctionDef,
                                       ast.AsyncFunctionDef)):
                    continue
                if fn.name in EXEMPT_METHODS:
                    continue
                reported: Set[int] = set()
                for stmt, cont in walk_with_continuations(fn.body):
                    reason = _mutation_reason(stmt)
                    if reason is None or stmt.lineno in reported:
                        continue
                    if every_path_reaches(stmt, cont, _is_bump_call):
                        continue
                    reported.add(stmt.lineno)
                    yield attach_span(Finding(
                        self.code, ctx.rel, stmt.lineno, stmt.col_offset,
                        f"{cls.name}.{fn.name} {reason} but some path to "
                        "exit never calls self._bump_epoch() — stale "
                        "TrafficPlans replay the old topology"), stmt)
