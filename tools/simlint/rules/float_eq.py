"""SIM005 — `==` / `!=` on float clock/timing values.

The event loop guarantees windowed timings equal drained timings to
float precision, not bit-for-bit across code paths: comparing two sim
times with `==` works until an optimization reassociates one sum.
Timing comparisons must use a tolerance helper (`math.isclose`,
`abs(a - b) < eps`) or ordering (`<=`).
"""
from __future__ import annotations

import ast
import re
from typing import Iterable, Optional

from tools.simlint.engine import FileCtx, Finding, Project, Rule

# identifier "looks like a clock value": whole segment match on common
# timing words, or a units suffix. Deliberately NOT `_at`/`_iter`: those
# are iteration counters in this codebase (ints compare exactly).
TIMEY_SEGMENT = re.compile(
    r"^(t|t0|t1|dt|now|clock|time|deadline|finish|start|latency|eta|"
    r"elapsed|until|mtbf)$")
TIMEY_SUFFIX = re.compile(r"(_s|_sec|_secs|_seconds|_latency|_time)$")


def _timey_name(expr: ast.expr) -> Optional[str]:
    if isinstance(expr, ast.Name):
        name = expr.id
    elif isinstance(expr, ast.Attribute):
        name = expr.attr
    else:
        return None
    if TIMEY_SUFFIX.search(name):
        return name
    if any(TIMEY_SEGMENT.match(seg) for seg in name.split("_") if seg):
        return name
    return None


class FloatClockEqRule(Rule):
    code = "SIM005"
    name = "float-clock-eq"
    description = ("== / != between float clock/timing values — use "
                   "math.isclose or an explicit tolerance")

    def applies(self, rel: str) -> bool:
        return rel.startswith("src/repro/")

    def check(self, ctx: FileCtx, project: Project) -> Iterable[Finding]:
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Compare):
                continue
            operands = [node.left] + list(node.comparators)
            for i, op in enumerate(node.ops):
                if not isinstance(op, (ast.Eq, ast.NotEq)):
                    continue
                left, right = operands[i], operands[i + 1]
                lname, rname = _timey_name(left), _timey_name(right)
                # flag clock-vs-clock compares, or clock vs a float
                # literal. One timey name against an arbitrary non-timey
                # expression (tier tags, iteration counters, None/int
                # sentinels, float("inf")) compares exactly.
                if lname and rname:
                    name = lname
                elif (lname or rname) and any(
                        isinstance(o, ast.Constant)
                        and isinstance(o.value, float)
                        for o in (left, right)):
                    name = lname or rname
                else:
                    continue
                sym = "==" if isinstance(op, ast.Eq) else "!="
                yield Finding(
                    self.code, ctx.rel, node.lineno, node.col_offset,
                    f"`{sym}` on timing value `{name}` — float clock "
                    "comparisons need math.isclose(...) or an explicit "
                    "tolerance")
