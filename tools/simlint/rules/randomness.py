"""SIM002 — unseeded randomness inside the simulator.

Replays must be bit-identical, so every random draw must come from an
explicitly seeded generator: `np.random.default_rng(seed)`,
`random.Random(seed)`, or a threaded `jax.random` key. Module-level
`random.random()` / `np.random.shuffle()` draws from hidden global state
seeded by the host and breaks replay.
"""
from __future__ import annotations

import ast
from typing import Iterable, Set

from tools.simlint.engine import FileCtx, Finding, Project, Rule

# numpy.random names that CONSTRUCT a generator; fine when given a seed
# argument, flagged when called with no arguments (host-entropy seeding).
NP_SAFE_CTORS = {"default_rng", "Generator", "RandomState", "SeedSequence",
                 "Philox", "PCG64", "PCG64DXSM", "MT19937"}
JAX_KEY_FNS = {"PRNGKey", "key"}


class UnseededRandomRule(Rule):
    code = "SIM002"
    name = "unseeded-randomness"
    description = ("draw from unseeded/global RNG state — use an "
                   "explicitly seeded Generator or threaded jax key")

    def applies(self, rel: str) -> bool:
        return rel.startswith("src/repro/")

    def check(self, ctx: FileCtx, project: Project) -> Iterable[Finding]:
        numpy_aliases: Set[str] = set()
        jax_aliases: Set[str] = set()
        random_aliases: Set[str] = set()
        np_random_aliases: Set[str] = set()   # from numpy import random as r
        from_random: Set[str] = set()         # from random import shuffle
        for node in ast.walk(ctx.tree):
            if isinstance(node, ast.Import):
                for a in node.names:
                    bound = a.asname or a.name.split(".")[0]
                    if a.name in ("numpy", "numpy.random"):
                        numpy_aliases.add(bound)
                    elif a.name in ("jax", "jax.random"):
                        jax_aliases.add(bound)
                    elif a.name == "random":
                        random_aliases.add(bound)
            elif isinstance(node, ast.ImportFrom) and node.level == 0:
                if node.module == "numpy":
                    np_random_aliases.update(a.asname or a.name
                                             for a in node.names
                                             if a.name == "random")
                elif node.module == "random":
                    from_random.update(a.asname or a.name for a in node.names
                                       if a.name not in ("Random",
                                                         "SystemRandom"))
                elif node.module in ("jax", "jax.random"):
                    # `from jax import random` — treat like jax alias base
                    np_done = False
                    for a in node.names:
                        if node.module == "jax" and a.name == "random":
                            jax_aliases.add(a.asname or a.name)
                            np_done = True
                    del np_done

        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Call):
                continue
            fn = node.func
            # bare `shuffle(x)` from `from random import shuffle`
            if isinstance(fn, ast.Name) and fn.id in from_random:
                yield self._finding(ctx, node, f"random.{fn.id}()",
                                    "seed a `random.Random(seed)` instance")
                continue
            if not isinstance(fn, ast.Attribute):
                continue
            base = fn.value
            # random.<fn>() on the stdlib module (Random(seed) is fine)
            if isinstance(base, ast.Name) and base.id in random_aliases:
                if fn.attr == "Random" and node.args:
                    continue
                if fn.attr in ("Random", "SystemRandom") and not node.args:
                    yield self._finding(
                        ctx, node, f"random.{fn.attr}()",
                        "pass an explicit seed: `random.Random(seed)`")
                    continue
                if fn.attr == "SystemRandom":
                    continue
                yield self._finding(
                    ctx, node, f"random.{fn.attr}()",
                    "module-level stdlib RNG draws from hidden global "
                    "state; use a seeded `random.Random(seed)`")
                continue
            # np.random.<fn>() / `from numpy import random as nr`
            is_np_random = (
                (isinstance(base, ast.Attribute)
                 and isinstance(base.value, ast.Name)
                 and base.value.id in numpy_aliases
                 and base.attr == "random")
                or (isinstance(base, ast.Name)
                    and base.id in np_random_aliases))
            if is_np_random:
                if fn.attr in NP_SAFE_CTORS:
                    if not node.args and not node.keywords:
                        yield self._finding(
                            ctx, node, f"np.random.{fn.attr}()",
                            "zero-arg constructor seeds from host entropy; "
                            "pass an explicit seed")
                    continue
                yield self._finding(
                    ctx, node, f"np.random.{fn.attr}()",
                    "legacy global-state numpy RNG; use "
                    "`np.random.default_rng(seed)`")
                continue
            # jax.random.PRNGKey(<call>) — seed itself nondeterministic
            is_jax_random = (
                isinstance(base, ast.Attribute)
                and isinstance(base.value, ast.Name)
                and base.value.id in jax_aliases
                and base.attr == "random") or (
                isinstance(base, ast.Name) and base.id in jax_aliases
                and base.id == "random")
            if is_jax_random and fn.attr in JAX_KEY_FNS:
                if any(isinstance(a, ast.Call) for a in node.args):
                    yield self._finding(
                        ctx, node, f"jax.random.{fn.attr}(<call>)",
                        "key seeded from a runtime call is not "
                        "replayable; derive it from the config seed")

    def _finding(self, ctx: FileCtx, node: ast.Call, what: str,
                 fix: str) -> Finding:
        return Finding(self.code, ctx.rel, node.lineno, node.col_offset,
                       f"unseeded randomness {what} — {fix}")
