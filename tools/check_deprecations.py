#!/usr/bin/env python
"""Deprecation lint — thin shim over simlint rule SIM007.

The AST scan for internal callers of the legacy `SimCluster` flat kwargs
/ `recover(hardware=, ...)` shims now lives in
`tools/simlint/rules/deprecations.py` (rule SIM007), so it shares the
engine's pragma handling, JSON output, and fixtures. This wrapper keeps
the old entry point (`python tools/check_deprecations.py`) and exit
semantics for scripts and muscle memory; `tools/lint_all.py` runs the
full simlint engine instead. Suppress intentional shim usage with
`# simlint: disable=SIM007 -- reason` (the legacy `# deprecated-ok:
reason` spelling still works, with a nag).
"""
from __future__ import annotations

import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
SCAN_DIRS = ("src", "tests", "benchmarks", "examples", "tools")


def main() -> int:
    sys.path.insert(0, str(ROOT))
    from tools.simlint.engine import run
    from tools.simlint.rules.deprecations import DeprecatedKwargsRule

    paths = [d for d in SCAN_DIRS if (ROOT / d).exists()]
    report = run(paths, [DeprecatedKwargsRule()])
    findings = [f for f in report.findings if f.code == "SIM007"]
    for f in findings:
        print(f"FAIL: {f.path}:{f.line}: deprecated call: {f.message}")
    if report.legacy_pragma_files:
        print("note: legacy `# deprecated-ok` pragma(s) in "
              f"{', '.join(report.legacy_pragma_files)} — prefer "
              "`# simlint: disable=SIM007 -- reason`", file=sys.stderr)
    if not findings:
        print(f"deprecations OK: {report.n_files} files scanned, no "
              "internal callers of the shimmed kwarg forms "
              f"({len(report.suppressed)} suppressed)")
    return 1 if findings else 0


if __name__ == "__main__":
    raise SystemExit(main())
