#!/usr/bin/env python
"""Deprecation lint (CI `docs` job, also run by tests/test_docs.py).

The ISSUE 6 API redesign keeps the old `SimCluster` flat kwargs and
`recover(hardware=, ...)` keywords working through shims — for DOWNSTREAM
users. Repo-internal code (src/, tests/, benchmarks/, examples/) must use
the new `ClusterConfig`/`FabricConfig`/`FaultScript` surface, or CI fails
here. Back-compat tests that exercise the shims on purpose mark the call
with a `# deprecated-ok` comment anywhere in the call's line span.

Pure AST scan: no imports, no execution, works on files that need optional
deps. Exit code 0 = clean; nonzero prints every offending call site.
"""
from __future__ import annotations

import ast
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
SCAN_DIRS = ("src", "tests", "benchmarks", "examples", "tools")
PRAGMA = "deprecated-ok"

LEGACY_CLUSTER_KWARGS = {
    "dp", "global_batch", "seq_len", "dataset_size", "hp", "ckpt_dir",
    "full_every", "seed", "link_bw", "quantum", "t_iter_model", "topology",
    "edge_bw", "pods", "dcn_bw", "ici_latency", "dcn_latency", "compile_plan",
}
LEGACY_RECOVER_KWARGS = {"hardware", "interrupt_after_chunks",
                         "corrupt_chunks"}


def _call_name(node: ast.Call) -> str | None:
    fn = node.func
    if isinstance(fn, ast.Attribute):
        return fn.attr
    if isinstance(fn, ast.Name):
        return fn.id
    return None


def check_file(path: Path) -> list[str]:
    src = path.read_text()
    lines = src.splitlines()
    try:
        tree = ast.parse(src)
    except SyntaxError as e:
        return [f"{path.relative_to(ROOT)}: unparseable ({e})"]
    errors = []
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        name = _call_name(node)
        kwnames = {k.arg for k in node.keywords if k.arg}
        bad = None
        if name == "SimCluster" and kwnames & LEGACY_CLUSTER_KWARGS:
            bad = (f"SimCluster({sorted(kwnames & LEGACY_CLUSTER_KWARGS)}"
                   ") — use cluster=ClusterConfig(...) / "
                   "fabric=FabricConfig(...)")
        elif name == "from_kwargs" and isinstance(node.func, ast.Attribute):
            bad = "SimCluster.from_kwargs(...) — deprecated shim"
        elif name == "recover" and isinstance(node.func, ast.Attribute) \
                and kwnames & LEGACY_RECOVER_KWARGS:
            bad = (f"recover({sorted(kwnames & LEGACY_RECOVER_KWARGS)}"
                   ") — use faults=FaultScript(...)")
        if bad is None:
            continue
        span = range(node.lineno, (node.end_lineno or node.lineno) + 1)
        if any(PRAGMA in lines[i - 1] for i in span if i - 1 < len(lines)):
            continue
        errors.append(f"{path.relative_to(ROOT)}:{node.lineno}: "
                      f"deprecated call: {bad}")
    return errors


def main() -> int:
    errors: list[str] = []
    n_files = 0
    for d in SCAN_DIRS:
        base = ROOT / d
        if not base.exists():
            continue
        for path in sorted(base.rglob("*.py")):
            n_files += 1
            errors.extend(check_file(path))
    for e in errors:
        print(f"FAIL: {e}")
    if not errors:
        print(f"deprecations OK: {n_files} files scanned, no internal "
              "callers of the shimmed kwarg forms")
    return 1 if errors else 0


if __name__ == "__main__":
    raise SystemExit(main())
