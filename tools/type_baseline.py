#!/usr/bin/env python
"""Type-error baseline gate for `tools/` and `src/repro/runtime/`.

Runs mypy (or pyright, whichever is installed) over the covered paths and
compares the errors against the committed baseline
(`tools/type_baseline.json`): NEW errors fail, legacy ones are tolerated
until someone burns them down. Fingerprints are `path::code::message`
with no line numbers, so unrelated edits that shift lines don't churn
the baseline.

    python tools/type_baseline.py              # gate against the baseline
    python tools/type_baseline.py --update     # re-record the baseline
    python tools/type_baseline.py --require    # fail if no checker found

Without `--require`, a machine with neither checker installed skips with
exit 0 (the repro container intentionally has no type checker; CI
installs mypy and passes `--require`). The committed baseline records
which checker produced it; results from the other checker are compared
best-effort against an empty legacy set only when the baseline's checker
is missing.
"""
from __future__ import annotations

import argparse
import json
import re
import shutil
import subprocess
import sys
from pathlib import Path
from typing import List, Optional, Sequence, Tuple

ROOT = Path(__file__).resolve().parent.parent
BASELINE = Path(__file__).resolve().parent / "type_baseline.json"
COVERED = ("tools", "src/repro/runtime")

MYPY_LINE = re.compile(
    r"^(?P<path>[^:]+):\d+(?::\d+)?: error: (?P<msg>.*?)"
    r"(?:\s+\[(?P<code>[\w-]+)\])?$")


def find_checker() -> Optional[Tuple[str, List[str]]]:
    """(name, argv prefix) of the first available checker."""
    if shutil.which("mypy") is not None:
        return "mypy", ["mypy"]
    try:
        import mypy  # noqa: F401
        return "mypy", [sys.executable, "-m", "mypy"]
    except ImportError:
        pass
    if shutil.which("pyright") is not None:
        return "pyright", ["pyright", "--outputjson"]
    return None


def run_mypy(prefix: List[str]) -> List[str]:
    argv = prefix + [
        "--no-error-summary", "--show-error-codes", "--ignore-missing-imports",
        "--follow-imports=silent", *COVERED]
    proc = subprocess.run(argv, cwd=ROOT, capture_output=True, text=True)
    fps = []
    for line in proc.stdout.splitlines():
        m = MYPY_LINE.match(line.strip())
        if m:
            path = Path(m.group("path")).as_posix()
            fps.append(f"{path}::{m.group('code') or 'misc'}"
                       f"::{m.group('msg')}")
    return fps


def run_pyright(prefix: List[str]) -> List[str]:
    proc = subprocess.run(prefix + list(COVERED), cwd=ROOT,
                          capture_output=True, text=True)
    try:
        data = json.loads(proc.stdout)
    except json.JSONDecodeError:
        return [f"<pyright>::parse::unreadable output "
                f"(exit {proc.returncode})"]
    fps = []
    for d in data.get("generalDiagnostics", []):
        if d.get("severity") != "error":
            continue
        path = Path(d.get("file", "?"))
        rel = path.relative_to(ROOT).as_posix() if path.is_absolute() and \
            str(path).startswith(str(ROOT)) else path.as_posix()
        fps.append(f"{rel}::{d.get('rule', 'misc')}::{d.get('message', '')}")
    return fps


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--update", action="store_true",
                    help="re-record the baseline from the current errors")
    ap.add_argument("--require", action="store_true",
                    help="fail (exit 2) when no type checker is installed")
    args = ap.parse_args(argv)

    checker = find_checker()
    if checker is None:
        msg = "type_baseline: no mypy/pyright installed"
        if args.require:
            print(f"{msg} — required (CI installs mypy)", file=sys.stderr)
            return 2
        print(f"{msg}; skipping (CI runs this with --require)")
        return 0
    name, prefix = checker
    current = sorted(set(
        run_mypy(prefix) if name == "mypy" else run_pyright(prefix)))

    if args.update:
        BASELINE.write_text(json.dumps(
            {"checker": name, "paths": list(COVERED),
             "errors": current}, indent=2) + "\n")
        print(f"type_baseline: recorded {len(current)} {name} error(s) "
              f"to {BASELINE.name}")
        return 0

    if BASELINE.exists():
        base = json.loads(BASELINE.read_text())
    else:
        base = {"checker": name, "errors": []}
    legacy = set(base.get("errors", [])) if base.get("checker") == name \
        else set()
    if base.get("checker") not in (None, name):
        print(f"type_baseline: baseline was recorded with "
              f"{base.get('checker')}, comparing {name} results against "
              "an empty legacy set", file=sys.stderr)

    new = [fp for fp in current if fp not in legacy]
    fixed = sorted(legacy - set(current))
    for fp in new:
        print(f"FAIL: new type error: {fp}")
    if fixed:
        print(f"type_baseline: {len(fixed)} legacy error(s) no longer "
              "fire — run `python tools/type_baseline.py --update` to "
              "shrink the baseline")
    if not new:
        print(f"type_baseline OK ({name}): {len(current)} error(s), all in "
              f"the committed baseline of {len(legacy)}")
    return 1 if new else 0


if __name__ == "__main__":
    raise SystemExit(main())
