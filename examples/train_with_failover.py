"""End-to-end driver (deliverable b): train a ~100M-param model for a few
hundred steps with per-iteration instant checkpointing, a mid-run hardware
failure, recovery, and a bitwise cross-check against an uninterrupted run —
then a MULTI-FAILURE scenario: two concurrent DP-rank failures where the
second strikes mid-transfer and recovery resumes from partial chunks.

    PYTHONPATH=src python examples/train_with_failover.py [--steps 200]
"""
import argparse
import dataclasses
import time
from pathlib import Path

import jax
import numpy as np

from repro.configs import ArchConfig, register
from repro.optim import AdamWConfig
from repro.runtime.cluster import (ClusterConfig, FabricConfig, FaultScript,
                                   SimCluster)

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=200)
ap.add_argument("--fail-at", type=int, default=None)
args = ap.parse_args()

# ~100M params: 8 layers x d512 (llama-style), 32k vocab
cfg = ArchConfig(
    name="demo-100m", family="dense", num_layers=8, d_model=512,
    num_heads=8, num_kv_heads=4, head_dim=64, d_ff=2048, vocab_size=32000,
    mlp_type="swiglu", dtype="float32", remat_policy="none")
fail_at = args.fail_at if args.fail_at is not None else args.steps // 2

cluster = SimCluster(
    cfg,
    cluster=ClusterConfig(dp=4, global_batch=4, seq_len=128,
                          dataset_size=8192,
                          ckpt_dir=Path("/tmp/failover_demo_ckpt"),
                          full_every=100,
                          hp=AdamWConfig(lr=3e-4, warmup_steps=20,
                                         total_steps=args.steps)),
    fabric=FabricConfig(quantum=1 << 18))
n_params = sum(int(np.prod(x.shape))
               for x in jax.tree.leaves(cluster.state["params"]))
print(f"model: {n_params/1e6:.1f}M params, dp=4, seq 128")

t0 = time.time()
for step in range(args.steps):
    if step == fail_at:
        print(f"\n[{step}] HARDWARE FAILURE on worker 0 "
              f"(host RAM lost; neighbor holds its shard)")
        cluster.inject_failure([0], hardware=True)
        rep = cluster.recover(FaultScript(hardware=True))
        print(f"[{step}] recovered via {rep.recovered_from}, rollback="
              f"{rep.rolled_back_iterations}, {rep.chunks_sent} state "
              f"chunks streamed, modeled MTTR={rep.total_time:.1f}s\n")
    loss = cluster.step()
    if step % 20 == 0 or step == args.steps - 1:
        dt = (time.time() - t0) / (step + 1)
        print(f"step {cluster.iteration:4d}  loss {loss:.4f}  ({dt:.2f}s/it)")

print(f"\nfinal loss: {cluster.loss_history[-1]:.4f} "
      f"(started at {cluster.loss_history[0]:.4f})")
assert cluster.loss_history[-1] < cluster.loss_history[0], "did not learn"
print("training improved the loss through a failure — OK")

# ---------------------------------------------------------------------------
# Multi-failure: worker 1 dies; while its shard is streaming back, worker 3
# (non-adjacent — its backup holder is alive) dies too. The second recover()
# resumes worker 1's transfer from the chunks that already landed instead of
# restarting it, then recovers both with zero rollback.
# ---------------------------------------------------------------------------
print("\n--- multi-failure: second failure mid-transfer ---")
cluster.inject_failure([1], hardware=True)
partial = cluster.recover(FaultScript(hardware=True,
                                      interrupt_after_chunks=4))
print(f"transfer interrupted after {partial.chunks_sent}/"
      f"{partial.chunks_total} chunks (second failure strikes)")
assert partial.kind == "interrupted"

cluster.inject_failure([3], hardware=True)
rep2 = cluster.recover(FaultScript(hardware=True))
print(f"resumed: reused {rep2.chunks_reused} partial chunks, streamed "
      f"{rep2.chunks_sent} more ({rep2.chunks_total} total), rollback="
      f"{rep2.rolled_back_iterations}")
assert rep2.chunks_reused == partial.chunks_sent
assert rep2.rolled_back_iterations == 0

post = cluster.run(5)
assert all(np.isfinite(l) for l in post)
print(f"trained 5 more steps after double failure, loss {post[-1]:.4f} — OK")
