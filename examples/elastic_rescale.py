"""Elastic rescale: lose a worker with NO spare capacity — the controller
shrinks the DP degree, re-partitions the TID data indexing (exact cover
preserved), and training continues at reduced throughput.

    PYTHONPATH=src python examples/elastic_rescale.py
"""
import dataclasses
from pathlib import Path

import numpy as np

from repro.configs import get_arch, reduce_for_smoke
from repro.runtime.cluster import ClusterConfig, SimCluster

cfg = dataclasses.replace(reduce_for_smoke(get_arch("gemma-2b")),
                          dtype="float32")
cluster = SimCluster(cfg, cluster=ClusterConfig(
    dp=4, global_batch=8, seq_len=16, ckpt_dir=Path("/tmp/elastic_ckpt")))

print("dp=4:", [f"{l:.3f}" for l in cluster.run(3)])

print("\nworker 3 lost, no spare -> shrink to dp=3")
cluster.inject_failure([3], hardware=True)
cluster.workers[3].alive = True  # mark handled; we rescale instead of replace
new_dp = cluster.shrink([3])
print(f"new dp={new_dp}, global batch -> {cluster.global_batch}")

losses = cluster.run(3)
print("dp=3:", [f"{l:.3f}" for l in losses])
assert all(np.isfinite(l) for l in losses)

# exact-cover data indexing still holds after the rescale
parts = [w.loader.indexer.indices(cluster.iteration, i, cluster.dp)
         for i, w in enumerate(cluster.workers)]
total = np.concatenate(parts)
assert len(total) == cluster.global_batch == len(np.unique(total))
print("exact-cover data partition preserved after rescale — OK")
