"""Serve a small model with batched requests: prefill + KV-cache greedy
decode, including a Mamba2 (attention-free) model whose decode state is O(1).

    PYTHONPATH=src python examples/serve_decode.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch, reduce_for_smoke
from repro.models import build_model

for arch in ("qwen3-0.6b", "mamba2-2.7b"):
    cfg = reduce_for_smoke(get_arch(arch))
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    rng = np.random.default_rng(0)
    b, prompt, gen = 4, 12, 12

    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size,
                                                (b, prompt)), jnp.int32),
             "max_len": prompt + gen}
    logits, cache = model.prefill(params, batch)
    decode = jax.jit(model.decode_step)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    toks = [tok]
    t0 = time.time()
    for _ in range(gen - 1):
        logits, cache = decode(params, cache, tok)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        toks.append(tok)
    jax.block_until_ready(tok)
    out = np.stack([np.asarray(t) for t in toks], 1)
    state_kind = "KV cache" if "k" in cache else "SSM state (O(1) in seq!)"
    print(f"{arch}: generated {out.shape} tokens in {time.time()-t0:.2f}s "
          f"via {state_kind}")
    print("  seq0:", out[0].tolist())
