"""Quickstart: train a smoke-scale model for a few steps with FFTrainer's
instant checkpointing, then kill a worker and recover with zero rollback.

    PYTHONPATH=src python examples/quickstart.py
"""
import dataclasses
from pathlib import Path

from repro.configs import get_arch, reduce_for_smoke
from repro.optim import AdamWConfig
from repro.runtime.cluster import ClusterConfig, SimCluster

cfg = dataclasses.replace(reduce_for_smoke(get_arch("qwen3-0.6b")),
                          dtype="float32")
cluster = SimCluster(cfg, cluster=ClusterConfig(
    dp=4, global_batch=8, seq_len=16,
    ckpt_dir=Path("/tmp/quickstart_ckpt"),
    hp=AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=50)))

print("training 5 steps...")
for loss in cluster.run(5):
    print(f"  loss {loss:.4f}")

print("\nkilling worker 2 (its ZeRO shard lives on in worker 3's RAM)...")
cluster.inject_failure([2])
report = cluster.recover()
print(f"recovered from {report.recovered_from}; "
      f"rollback = {report.rolled_back_iterations} iterations; "
      f"modeled wall time = {report.total_time:.1f}s "
      f"(vs ~900s for a serial baseline)")

print("\ncontinuing training...")
for loss in cluster.run(5):
    print(f"  loss {loss:.4f}")
print("\ndone — instant checkpoints taken:",
      cluster.workers[0].engine.instant_count)
