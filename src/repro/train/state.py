"""TrainState assembly: params (bf16, TP-sharded) + AdamW state (fp32,
ZeRO-1-sharded) + step counter, with the matching PartitionSpec pytrees."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict

import jax
import jax.numpy as jnp
from jax.sharding import Mesh
from jax.sharding import PartitionSpec as P

from repro.configs import ArchConfig
from repro.models import Model
from repro.optim import adamw_init
from repro.parallel import sharding as shd

PyTree = Any


@dataclass(frozen=True)
class StatePlan:
    """Shapes + shardings of the full train state."""
    state_specs: PyTree       # ShapeDtypeStructs
    state_pspecs: PyTree      # PartitionSpecs
    param_pspecs: PyTree
    opt_pspecs: PyTree        # ZeRO-1 specs for master/m/v


def make_state_specs(model: Model) -> PyTree:
    param_specs = model.param_specs()
    opt_specs = jax.eval_shape(adamw_init, param_specs)
    return {
        "step": jax.ShapeDtypeStruct((), jnp.int32),
        "params": param_specs,
        "opt": opt_specs,
    }


def make_state_plan(model: Model, mesh: Mesh, *,
                    fsdp_params: bool = False) -> StatePlan:
    cfg = model.cfg
    state_specs = make_state_specs(model)
    param_pspecs = shd.param_pspecs(cfg, state_specs["params"], mesh,
                                    fsdp=fsdp_params)
    opt_pspecs = {
        k: shd.zero_pspecs(param_pspecs, state_specs["params"], mesh)
        for k in ("master", "m", "v")
    }
    state_pspecs = {"step": P(), "params": param_pspecs, "opt": opt_pspecs}
    return StatePlan(state_specs, state_pspecs, param_pspecs, opt_pspecs)


def init_state(model: Model, key: jax.Array) -> PyTree:
    params = model.init(key)
    return {
        "step": jnp.zeros((), jnp.int32),
        "params": params,
        "opt": adamw_init(params),
    }
