"""Train/serve step builders.

``build_train_step`` returns a jit-compiled SPMD step:

    new_state, metrics, backup = step(state, batch)

with the paper's instant checkpoint fused in: ``backup`` is the ZeRO-unique
optimizer shard permuted one hop along the DP ring (core/instant.py), an
explicit collective-permute in the compiled HLO that XLA overlaps with
compute. ``backup`` leaves are None when instant checkpointing is disabled or
the leaf is razor-redundant.

Optional beyond-paper feature: int8 cross-pod gradient compression
(parallel/compression.py) applied before the optimizer update.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.core.instant import neighbor_backup
from repro.core.razor import RazorPlan, razor_plan
from repro.models import Model
from repro.optim import AdamWConfig, adamw_update, cast_params, cosine_schedule
from repro.parallel import sharding as shd
from repro.train.state import StatePlan, make_state_plan

PyTree = Any


@dataclass(frozen=True)
class StepArtifacts:
    step_fn: Callable            # jitted
    plan: StatePlan
    razor: RazorPlan
    input_pspecs: PyTree
    backup_pspecs: PyTree        # None-leaved pytree matching backup output


def build_train_step(
    model: Model,
    mesh: Mesh,
    hp: AdamWConfig = AdamWConfig(),
    *,
    instant_ckpt: bool = True,
    backup_axis: str = "data",
    compress_pod_grads: bool = False,
    fsdp_params: bool = True,
    microbatches: int = 1,
    donate: bool = True,
    shape=None,
) -> StepArtifacts:
    cfg = model.cfg
    plan = make_state_plan(model, mesh, fsdp_params=fsdp_params)
    razor = razor_plan(plan.state_specs["opt"], plan.opt_pspecs,
                       plan.state_specs["params"], mesh, zero_axis=backup_axis)

    # backup = unique opt leaves only (razor) when instant ckpt is on
    if instant_ckpt and mesh.shape.get(backup_axis, 1) > 1:
        backup_pspecs = jax.tree.map(
            lambda ps, m: ps if m else None, plan.opt_pspecs, razor.unique_mask,
            is_leaf=lambda x: isinstance(x, P))
    else:
        backup_pspecs = jax.tree.map(lambda ps: None, plan.opt_pspecs,
                                     is_leaf=lambda x: isinstance(x, P))

    input_pspecs = shd.input_pspecs(cfg, model.input_specs(shape), mesh) \
        if shape else None

    use_compression = (compress_pod_grads and "pod" in mesh.axis_names
                       and mesh.shape["pod"] > 1 and input_pspecs is not None)

    def train_step(state, batch):
        if use_compression:
            from repro.parallel.compression import \
                pod_compressed_value_and_grad
            vg = pod_compressed_value_and_grad(
                lambda p, b: model.loss(p, b), mesh, plan.param_pspecs,
                input_pspecs)
            (loss, aux), grads = vg(state["params"], batch)
        elif microbatches > 1:
            # gradient accumulation: scan over microbatches — divides the live
            # activation footprint by `microbatches` at the cost of
            # re-gathering FSDP-sharded params once per microbatch
            mb = jax.tree.map(
                lambda x: x.reshape(microbatches, x.shape[0] // microbatches,
                                    *x.shape[1:]), batch)
            gzero = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state["params"])

            def mb_body(gsum, b):
                (l, aux), g = jax.value_and_grad(
                    lambda p: model.loss(p, b), has_aux=True)(state["params"])
                gsum = jax.tree.map(
                    lambda a, x: a + x.astype(jnp.float32), gsum, g)
                return gsum, (l, aux)

            gsum, (ls, auxs) = jax.lax.scan(mb_body, gzero, mb)
            grads = jax.tree.map(lambda g: g / microbatches, gsum)
            loss = jnp.mean(ls)
            aux = jax.tree.map(jnp.mean, auxs)
        else:
            (loss, aux), grads = jax.value_and_grad(
                lambda p: model.loss(p, batch), has_aux=True)(state["params"])

        lr = cosine_schedule(state["step"], lr=hp.lr,
                             warmup_steps=hp.warmup_steps,
                             total_steps=hp.total_steps)
        _, new_opt = adamw_update(grads, state["opt"], state["step"], hp, lr)
        new_params = cast_params(new_opt["master"], state["params"])
        new_state = {"step": state["step"] + 1, "params": new_params,
                     "opt": new_opt}

        backup = _mask(new_opt, backup_pspecs)
        backup = neighbor_backup(backup, backup_pspecs, mesh, axis=backup_axis)

        metrics = {"loss": loss, **aux, "lr": lr}
        return new_state, metrics, backup

    metrics_shard = None  # replicated scalars; let XLA infer
    backup_shardings = jax.tree.map(
        lambda ps: NamedSharding(mesh, ps) if ps is not None else None,
        backup_pspecs, is_leaf=lambda x: isinstance(x, P) or x is None)

    jit_kwargs: Dict[str, Any] = dict(
        in_shardings=(shd.to_named(plan.state_pspecs, mesh),
                      shd.to_named(input_pspecs, mesh) if input_pspecs else None),
        out_shardings=(shd.to_named(plan.state_pspecs, mesh),
                       metrics_shard, backup_shardings),
    )
    if donate:
        jit_kwargs["donate_argnums"] = (0,)

    if fsdp_params:
        from repro.models.modes import fsdp_unshard

        def traced(state, batch):
            with fsdp_unshard():
                return train_step(state, batch)

        step_fn = jax.jit(traced, **jit_kwargs)
    else:
        step_fn = jax.jit(train_step, **jit_kwargs)
    return StepArtifacts(step_fn, plan, razor, input_pspecs, backup_pspecs)


def _mask(tree: PyTree, mask_pspecs: PyTree) -> PyTree:
    is_p = lambda x: isinstance(x, P) or x is None
    return jax.tree.map(lambda ps, x: None if ps is None else x,
                        mask_pspecs, tree, is_leaf=is_p)


# --------------------------------------------------------------------------- #
# Link-traffic accounting (paper §5.3): what one training iteration puts on
# the wire, per worker (all volumes in bytes). The runtime submits
# `train_bytes` as TRAIN traffic to the StateStream transport — the volume
# that preempts checkpoint chunks — while the instant-ckpt shard rides the
# fabric as STATE. On a hierarchical PodFabric the allreduce is two-level
# (intra-pod ring + inter-pod gateway ring), so the profile carries a
# per-tier wire volume.
# --------------------------------------------------------------------------- #
@dataclass(frozen=True)
class TrafficProfile:
    train_bytes: float   # per-ICI-edge gradient allreduce volume (preempting)
    state_bytes: float   # razor-unique instant-ckpt shard, one DP-ring hop
    dcn_bytes: float = 0.0  # per-DCN-edge inter-pod allreduce volume


def step_traffic(grad_bytes: float, dp: int,
                 razor: Optional[RazorPlan] = None,
                 state_bytes: Optional[float] = None) -> TrafficProfile:
    """Per-iteration wire volumes for one worker (flat DP ring). Ring
    allreduce moves 2(dp-1)/dp of the gradient bytes; the instant checkpoint
    moves the razor-unique optimizer shard one hop along the DP ring."""
    wire = 2.0 * (dp - 1) / dp * grad_bytes if dp > 1 else 0.0
    if state_bytes is None:
        state_bytes = float(razor.unique_bytes_per_device_ring) if razor \
            else 0.0
    return TrafficProfile(wire, state_bytes)


def hierarchical_step_traffic(grad_bytes: float, n_pods: int, pod_size: int,
                              razor: Optional[RazorPlan] = None,
                              state_bytes: Optional[float] = None
                              ) -> TrafficProfile:
    """Per-iteration wire volumes for the two-level allreduce on a
    `PodFabric` (bytes).

    Intra-pod: ring reduce-scatter + allgather over the `pod_size`-node ICI
    ring moves ``2(s-1)/s * grad_bytes`` across every ICI edge
    (`train_bytes`). Inter-pod: after the reduce-scatter each node holds a
    ``grad_bytes / s`` shard; the gateways allreduce those shards around the
    `n_pods`-pod DCN ring, putting ``2(P-1)/P * grad_bytes / s`` on every
    DCN edge (`dcn_bytes`). Degenerates to `step_traffic` shapes when
    P == 1 (no DCN leg) or s == 1 (pure DCN ring of gateways)."""
    s, p = pod_size, n_pods
    ici = 2.0 * (s - 1) / s * grad_bytes if s > 1 else 0.0
    shard = grad_bytes / max(s, 1)
    dcn = 2.0 * (p - 1) / p * shard if p > 1 else 0.0
    if state_bytes is None:
        state_bytes = float(razor.unique_bytes_per_device_ring) if razor \
            else 0.0
    return TrafficProfile(ici, state_bytes, dcn)


def artifacts_traffic(artifacts: StepArtifacts, grad_bytes: float, dp: int
                      ) -> TrafficProfile:
    """TrafficProfile for a built train step (razor plan already resolved)."""
    return step_traffic(grad_bytes, dp, razor=artifacts.razor)


# --------------------------------------------------------------------------- #
# Checkpoint-free replay-compute cost model ("All is Not Lost", PAPERS.md):
# instead of streaming a lost worker's state over the fabric, its pipeline/DP
# neighbors re-execute redundant compute to rebuild the shard from their own
# replicas — recovery then costs worker compute-seconds instead of fabric
# bytes, which is exactly the currency that stays cheap when a storm has
# darkened the cross-pod links.
# --------------------------------------------------------------------------- #
@dataclass(frozen=True)
class ReplayCostModel:
    """Knobs for compute-based (checkpoint-free) recovery.

    `recompute_rate` is how many bytes of lost optimizer/param state one
    replaying worker can rebuild per second of redundant compute (forward
    replay at the training step rate, amortized). `replay_overhead`
    multiplies the state volume: redundant compute interleaves with the
    replayer's own step, so rebuilding B bytes burns more than B worth of
    step time. `setup_seconds` is the fixed cost of re-materializing
    activations and swapping the replay schedule in."""
    recompute_rate: float = 2e9        # bytes of state rebuilt / s / replayer
    replay_overhead: float = 1.25      # redundant-compute amplification
    setup_seconds: float = 0.5         # schedule swap + activation re-mat


@dataclass(frozen=True)
class ReplayCost:
    """One failed worker's replay bill: `wall_seconds` is the elapsed time
    with the replayers working in parallel; `compute_seconds` is the total
    worker compute burned (the resource compute-based recovery spends
    instead of fabric bytes)."""
    wall_seconds: float
    compute_seconds: float
    bytes_rebuilt: float
    n_replayers: int


def replay_compute_cost(state_bytes: float, n_replayers: int = 2,
                        model: ReplayCostModel = ReplayCostModel()
                        ) -> ReplayCost:
    """Cost of rebuilding `state_bytes` of a lost worker's state by replaying
    redundant compute on `n_replayers` healthy neighbors. The replayers
    split the replay evenly, so wall time divides by their count while the
    total compute burned does not. Submits NO fabric traffic."""
    n = max(int(n_replayers), 1)
    burn = state_bytes * model.replay_overhead / model.recompute_rate
    wall = model.setup_seconds + burn / n
    return ReplayCost(wall_seconds=wall, compute_seconds=burn,
                      bytes_rebuilt=float(state_bytes), n_replayers=n)


def submit_step_traffic(transport, profile: TrafficProfile, t: float):
    """Put one iteration's allreduce volume on the fabric, edge by edge.

    A ring allreduce moves 2(n-1) messages of S/n bytes across EVERY ring
    edge, so the per-edge wire volume equals the per-worker volume
    (`profile.train_bytes`) — on a `TopologyTransport` this loads each live
    ring edge with exactly that, and checkpoint STATE chunks then contend
    per-edge; on a single-link transport it degrades to the global
    submission. A profile with a `dcn_bytes` leg (hierarchical allreduce)
    loads each tier with its own volume instead. Returns the submitted
    transfer(s)."""
    if profile.dcn_bytes and hasattr(transport, "submit_train_tiers"):
        from repro.core.lccl import TIER_DCN, TIER_ICI
        return transport.submit_train_tiers(
            {TIER_ICI: profile.train_bytes, TIER_DCN: profile.dcn_bytes}, t)
    return transport.submit_train(profile.train_bytes, t)
