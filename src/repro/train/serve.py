"""Serving step builders: prefill and KV/SSM-cache decode, SPMD-sharded.

decode: cache is donated (in-place update) — the per-token working set is the
cache read + params read, which is what the decode roofline measures.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
from jax.sharding import Mesh

from repro.models import Model
from repro.parallel import sharding as shd
from repro.train.state import make_state_plan

PyTree = Any


def build_prefill_step(model: Model, mesh: Mesh, shape):
    cfg = model.cfg
    plan = make_state_plan(model, mesh)
    input_pspecs = shd.input_pspecs(cfg, model.input_specs(shape), mesh)
    cache_sp = shd.cache_pspecs(
        cfg, model.cache_specs(shape.global_batch, shape.seq_len), mesh)

    def prefill(params, batch):
        return model.prefill(params, batch)

    fn = jax.jit(
        prefill,
        in_shardings=(shd.to_named(plan.param_pspecs, mesh),
                      shd.to_named(input_pspecs, mesh)),
        out_shardings=(None, shd.to_named(cache_sp, mesh)),
    )
    return fn, plan, input_pspecs


def build_decode_step(model: Model, mesh: Mesh, shape):
    cfg = model.cfg
    plan = make_state_plan(model, mesh)
    input_specs = model.input_specs(shape)
    input_pspecs = shd.input_pspecs(cfg, input_specs, mesh)

    def decode(params, cache, token):
        return model.decode_step(params, cache, token)

    fn = jax.jit(
        decode,
        in_shardings=(shd.to_named(plan.param_pspecs, mesh),
                      shd.to_named(input_pspecs["cache"], mesh),
                      shd.to_named(input_pspecs["token"], mesh)),
        out_shardings=(None, shd.to_named(input_pspecs["cache"], mesh)),
        donate_argnums=(1,),
    )
    return fn, plan, input_pspecs
