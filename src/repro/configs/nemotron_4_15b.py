"""Nemotron-4-15B — dense, GQA, squared-ReLU MLP [arXiv:2402.16819; unverified]."""
from repro.configs import ArchConfig, register

register(ArchConfig(
    name="nemotron-4-15b",
    family="dense",
    num_layers=32,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=24576,
    vocab_size=256000,
    mlp_type="sq_relu",    # squared-ReLU, ungated: w_up (D,F) + w_down (F,D)
    source="arXiv:2402.16819; unverified",
))
