"""DeepSeek-67B — dense LLaMA-style decoder [arXiv:2401.02954; hf]."""
from repro.configs import ArchConfig, register

register(ArchConfig(
    name="deepseek-67b",
    family="dense",
    num_layers=95,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=22016,
    vocab_size=102400,
    mlp_type="swiglu",
    source="arXiv:2401.02954; hf",
))
