"""Qwen3-0.6B — dense, qk-norm, GQA [hf:Qwen/Qwen3-8B family; hf]."""
from repro.configs import ArchConfig, register

register(ArchConfig(
    name="qwen3-0.6b",
    family="dense",
    num_layers=28,
    d_model=1024,
    num_heads=16,
    num_kv_heads=8,
    head_dim=128,          # Qwen3 uses head_dim 128 (q proj widens to 2048)
    d_ff=3072,
    vocab_size=151936,
    mlp_type="swiglu",
    use_qk_norm=True,
    tie_embeddings=True,
    rope_theta=1_000_000.0,
    source="hf:Qwen/Qwen3-0.6B; hf",
))
