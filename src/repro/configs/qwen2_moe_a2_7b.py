"""Qwen1.5-MoE-A2.7B — 60 routed experts top-4 + 4 shared experts
[hf:Qwen/Qwen1.5-MoE-A2.7B; hf]. 60 experts are padded to 64 for EP-16 (router
logits of pad experts masked to -inf; see ArchConfig.padded_experts)."""
from repro.configs import ArchConfig, register

register(ArchConfig(
    name="qwen2-moe-a2.7b",
    family="moe",
    num_layers=24,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    head_dim=128,
    d_ff=0,
    vocab_size=151936,
    mlp_type="swiglu",
    num_experts=60,
    top_k=4,
    moe_d_ff=1408,
    num_shared_experts=4,
    shared_expert_d_ff=5632,   # 4 shared experts fused into one (D,4*1408) MLP
    source="hf:Qwen/Qwen1.5-MoE-A2.7B; hf",
))
