"""Whisper-small — encoder-decoder audio transformer backbone
[arXiv:2212.04356; unverified]. Conv frontend is a STUB: input_specs() provides
precomputed frame embeddings (B, encoder_seq, d_model)."""
from repro.configs import ArchConfig, register

register(ArchConfig(
    name="whisper-small",
    family="encdec",
    num_layers=12,          # decoder layers
    encoder_layers=12,
    encoder_seq=1500,       # 30 s of audio after the (stubbed) conv frontend
    d_model=768,
    num_heads=12,
    num_kv_heads=12,        # MHA
    head_dim=64,
    d_ff=3072,
    vocab_size=51865,
    mlp_type="gelu",
    source="arXiv:2212.04356; unverified",
))
