"""Qwen3-30B-A3B — MoE, 128 experts top-8, qk-norm [hf:Qwen/Qwen3-30B-A3B; hf]."""
from repro.configs import ArchConfig, register

register(ArchConfig(
    name="qwen3-moe-30b-a3b",
    family="moe",
    num_layers=48,
    d_model=2048,
    num_heads=32,
    num_kv_heads=4,
    head_dim=128,
    d_ff=0,                 # every MLP is MoE
    vocab_size=151936,
    mlp_type="swiglu",
    use_qk_norm=True,
    rope_theta=1_000_000.0,
    num_experts=128,
    top_k=8,
    moe_d_ff=768,
    source="hf:Qwen/Qwen3-30B-A3B; hf",
))
