"""Architecture & shape registry.

Every assigned architecture (plus the paper's own four workloads) is a frozen
``ArchConfig``. Shapes are the assignment's four (seq_len, global_batch) cells.
Configs are pure data — model code lives in ``repro.models``.
"""
from __future__ import annotations

import dataclasses
import importlib
from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@dataclass(frozen=True)
class ArchConfig:
    """One architecture. All sizes are the *full* production config."""

    name: str
    family: str  # dense | moe | ssm | hybrid | encdec | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // num_heads
    # --- MLP / norm flavor ---
    mlp_type: str = "swiglu"  # swiglu | geglu | sq_relu | gelu
    use_qk_norm: bool = False
    tie_embeddings: bool = False
    rope_theta: float = 10_000.0
    # --- MoE ---
    num_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    num_shared_experts: int = 0
    shared_expert_d_ff: int = 0
    capacity_factor: float = 1.25
    # --- SSM (Mamba2 / SSD) ---
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_conv_kernel: int = 4
    ssm_chunk: int = 256
    # --- hybrid (Zamba2-style): one shared attention block every k layers ---
    attn_every: int = 0
    # --- encoder-decoder (Whisper) ---
    encoder_layers: int = 0
    encoder_seq: int = 0  # precomputed frame embeddings (conv frontend stubbed)
    # --- VLM (InternVL2): precomputed patch embeddings (ViT frontend stubbed) ---
    num_patch_tokens: int = 0
    # --- numerics / memory ---
    dtype: str = "bfloat16"
    remat_policy: str = "full"  # none | full | dots
    # --- capability flags ---
    sub_quadratic: bool = False  # can run long_500k
    source: str = ""

    # ------------------------------------------------------------------ #
    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // self.num_heads if self.num_heads else 0

    @property
    def padded_vocab(self) -> int:
        """Vocab padded to a multiple of 256 so TP-16 shards evenly (Megatron-style)."""
        return _round_up(self.vocab_size, 256)

    @property
    def padded_experts(self) -> int:
        """Experts padded to a multiple of 16 so EP-16 shards evenly; pads are
        masked to -inf in the router."""
        return _round_up(self.num_experts, 16) if self.num_experts else 0

    @property
    def ssm_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.ssm_inner // self.ssm_head_dim if self.ssm_state else 0

    @property
    def is_moe(self) -> bool:
        return self.num_experts > 0

    def layer_kinds(self) -> Tuple[str, ...]:
        """Per-layer block kind for the decoder stack."""
        if self.family == "ssm":
            return ("mamba",) * self.num_layers
        if self.family == "hybrid":
            k = self.attn_every
            return tuple(
                "mamba_attn" if (i % k == k - 1) else "mamba"
                for i in range(self.num_layers)
            )
        return ("attn",) * self.num_layers


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode
    requires_sub_quadratic: bool = False


SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode", requires_sub_quadratic=True),
}

_REGISTRY: Dict[str, ArchConfig] = {}

# The ten assigned architectures (dry-run + roofline targets).
ASSIGNED: Tuple[str, ...] = (
    "deepseek-67b",
    "qwen3-0.6b",
    "nemotron-4-15b",
    "gemma-2b",
    "whisper-small",
    "mamba2-2.7b",
    "zamba2-7b",
    "qwen3-moe-30b-a3b",
    "qwen2-moe-a2.7b",
    "internvl2-26b",
)

# The paper's own evaluation workloads (Table 4).
PAPER_WORKLOADS: Tuple[str, ...] = (
    "gpt2-2.7b",
    "llama3-8b",
    "llama2-13b",
    "llama3-70b",
)

_MODULES = (
    "deepseek_67b",
    "qwen3_0_6b",
    "nemotron_4_15b",
    "gemma_2b",
    "whisper_small",
    "mamba2_2_7b",
    "zamba2_7b",
    "qwen3_moe_30b_a3b",
    "qwen2_moe_a2_7b",
    "internvl2_26b",
    "paper_workloads",
)


def register(cfg: ArchConfig) -> ArchConfig:
    if cfg.name in _REGISTRY:
        raise ValueError(f"duplicate arch {cfg.name}")
    _REGISTRY[cfg.name] = cfg
    return cfg


def _load_all() -> None:
    for mod in _MODULES:
        importlib.import_module(f"repro.configs.{mod}")


def get_arch(name: str) -> ArchConfig:
    if not _REGISTRY:
        _load_all()
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}") from None


def list_archs() -> Tuple[str, ...]:
    if not _REGISTRY:
        _load_all()
    return tuple(sorted(_REGISTRY))


def get_shape(name: str) -> ShapeConfig:
    try:
        return SHAPES[name]
    except KeyError:
        raise KeyError(f"unknown shape {name!r}; known: {sorted(SHAPES)}") from None


def dryrun_cells(include_skips: bool = False):
    """All (arch, shape) dry-run cells; skips long_500k for full-attention archs."""
    cells = []
    for arch_name in ASSIGNED:
        cfg = get_arch(arch_name)
        for shape in SHAPES.values():
            skip = shape.requires_sub_quadratic and not cfg.sub_quadratic
            if skip and not include_skips:
                continue
            cells.append((cfg, shape, skip))
    return cells


def reduce_for_smoke(cfg: ArchConfig, *, seq_hint: int = 32) -> ArchConfig:
    """Shrink a production config to a CPU-smoke-testable size, preserving family
    structure (MoE stays MoE with >=8 experts, hybrid keeps its attention cadence,
    enc-dec keeps both stacks)."""
    changes = dict(
        name=cfg.name + "-smoke",
        num_layers=min(cfg.num_layers, 4 if cfg.family in ("hybrid",) else 2),
        d_model=64,
        num_heads=4,
        num_kv_heads=min(cfg.num_kv_heads, 2) if cfg.num_kv_heads < cfg.num_heads else 4,
        head_dim=16,
        d_ff=128 if cfg.d_ff else 0,
        vocab_size=256,
        remat_policy="none",
    )
    if cfg.num_kv_heads == 1:
        changes["num_kv_heads"] = 1
    if cfg.is_moe:
        changes.update(num_experts=8, top_k=min(cfg.top_k, 2), moe_d_ff=32)
        if cfg.num_shared_experts:
            changes.update(num_shared_experts=2, shared_expert_d_ff=32)
    if cfg.ssm_state:
        changes.update(ssm_state=16, ssm_head_dim=16, ssm_chunk=8)
    if cfg.family == "hybrid":
        changes.update(attn_every=2)
    if cfg.encoder_layers:
        changes.update(encoder_layers=2, encoder_seq=max(8, seq_hint // 2))
    if cfg.num_patch_tokens:
        changes.update(num_patch_tokens=8)
    return dataclasses.replace(cfg, **changes)
