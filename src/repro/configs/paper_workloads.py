"""The paper's own evaluation workloads (FFTrainer Table 4)."""
from repro.configs import ArchConfig, register

register(ArchConfig(
    name="gpt2-2.7b", family="dense",
    num_layers=32, d_model=2560, num_heads=32, num_kv_heads=32, head_dim=80,
    d_ff=10240, vocab_size=50257, mlp_type="gelu",
    source="paper Table 4 (GPT-2 2.7B)",
))
register(ArchConfig(
    name="llama3-8b", family="dense",
    num_layers=32, d_model=4096, num_heads=32, num_kv_heads=8, head_dim=128,
    d_ff=14336, vocab_size=128256, mlp_type="swiglu", rope_theta=500_000.0,
    source="paper Table 4 (LLaMA3-8B)",
))
register(ArchConfig(
    name="llama2-13b", family="dense",
    num_layers=40, d_model=5120, num_heads=40, num_kv_heads=40, head_dim=128,
    d_ff=13824, vocab_size=32000, mlp_type="swiglu",
    source="paper Table 4 (LLaMA2-13B)",
))
register(ArchConfig(
    name="llama3-70b", family="dense",
    num_layers=80, d_model=8192, num_heads=64, num_kv_heads=8, head_dim=128,
    d_ff=28672, vocab_size=128256, mlp_type="swiglu", rope_theta=500_000.0,
    source="paper Table 4 (LLaMA3-70B)",
))
