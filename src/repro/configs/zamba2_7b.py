"""Zamba2-7B — hybrid Mamba2 + shared attention blocks [arXiv:2411.15242; unverified].

Modeling note (DESIGN.md §5): the shared transformer block (weights shared across all
its applications) is applied every ``attn_every`` layers within the scanned Mamba2
stack; the real model interleaves two shared blocks — we use one shared block at the
same cadence, which preserves the parameter-sharing structure the checkpoint razor
must handle."""
from repro.configs import ArchConfig, register

register(ArchConfig(
    name="zamba2-7b",
    family="hybrid",
    num_layers=81,
    d_model=3584,
    num_heads=32,
    num_kv_heads=32,        # MHA in the shared block
    head_dim=112,
    d_ff=14336,
    vocab_size=32000,
    ssm_state=64,
    ssm_head_dim=64,        # d_inner = 7168 -> 112 SSD heads
    ssm_expand=2,
    attn_every=6,
    sub_quadratic=True,
    source="arXiv:2411.15242; unverified",
))
