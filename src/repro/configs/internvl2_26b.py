"""InternVL2-26B — InternViT + InternLM2 backbone [arXiv:2404.16821; hf].
The ViT frontend is a STUB: input_specs() provides precomputed patch embeddings
(B, num_patch_tokens, d_model); the assigned config specifies the LM backbone."""
from repro.configs import ArchConfig, register

register(ArchConfig(
    name="internvl2-26b",
    family="vlm",
    num_layers=48,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab_size=92553,
    mlp_type="swiglu",
    num_patch_tokens=1024,  # e.g. 4 tiles x 256 patch tokens
    source="arXiv:2404.16821; hf",
))
