"""Mamba2-2.7B — attention-free SSD (state-space duality) [arXiv:2405.21060; unverified]."""
from repro.configs import ArchConfig, register

register(ArchConfig(
    name="mamba2-2.7b",
    family="ssm",
    num_layers=64,
    d_model=2560,
    num_heads=0,
    num_kv_heads=0,
    d_ff=0,                 # Mamba2 blocks replace both attention and MLP
    vocab_size=50280,
    ssm_state=128,
    ssm_head_dim=64,        # d_inner = 2*2560 = 5120 -> 80 SSD heads
    ssm_expand=2,
    sub_quadratic=True,
    source="arXiv:2405.21060; unverified",
))
