"""Render EXPERIMENTS.md roofline/dry-run tables from the sweep JSONs.

    PYTHONPATH=src python -m repro.roofline.report [--dir experiments/dryrun]
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path

from repro.configs import ASSIGNED, SHAPES, dryrun_cells, get_arch
from repro.roofline import hw


def load(dir_: Path):
    cells = {}
    for p in sorted(dir_.glob("*.json")):
        d = json.loads(p.read_text())
        cells[(d["mesh"], d["arch"], d["shape"])] = d
    return cells


def fmt_ms(s):
    return f"{s * 1e3:.1f}"


def roofline_table(cells) -> str:
    rows = ["| arch | shape | kind | compute ms | memory ms | collective ms |"
            " bottleneck | useful | roofline | peak GiB | fits |",
            "|---|---|---|---|---|---|---|---|---|---|---|"[:-4]]
    for cfg, shape, skip in dryrun_cells(include_skips=True):
        key = ("pod16x16", cfg.name, shape.name)
        if skip:
            rows.append(f"| {cfg.name} | {shape.name} | — | — | — | — | "
                        f"skipped (full attention at 524k; DESIGN.md §5) "
                        f"| — | — | — | — |")
            continue
        d = cells.get(key)
        if d is None or "compute_s" not in d:
            rows.append(f"| {cfg.name} | {shape.name} | {shape.kind} "
                        f"| (pending) | | | | | | | |")
            continue
        peak = d["peak_memory_per_device"] / 2**30
        rows.append(
            f"| {cfg.name} | {shape.name} | {d['kind']} "
            f"| {fmt_ms(d['compute_s'])} | {fmt_ms(d['memory_s'])} "
            f"| {fmt_ms(d['collective_s'])} | {d['bottleneck']} "
            f"| {d['useful_ratio']:.2f} | {d['roofline_fraction']:.3f} "
            f"| {peak:.1f} | {'Y' if d['fits_hbm'] else 'N'} |")
    return "\n".join(rows)


def dryrun_table(cells) -> str:
    rows = ["| mesh | arch | shape | compile s | bytes/device GiB | "
            "collective schedule |",
            "|---|---|---|---|---|---|"]
    for (mesh, arch, shape), d in sorted(cells.items()):
        ma = d["memory_analysis"]
        per_dev = (ma["argument_size_in_bytes"] + ma["output_size_in_bytes"]
                   + ma["temp_size_in_bytes"] - ma["alias_size_in_bytes"]) / 2**30
        sched = d["production_collectives"]["count_by_kind"]
        rows.append(f"| {mesh} | {arch} | {shape} | {d['compile_s']:.0f} "
                    f"| {per_dev:.1f} | {sched} |")
    return "\n".join(rows)


def summary(cells) -> str:
    single = [d for (m, _, _), d in cells.items() if m == "pod16x16"]
    multi = [d for (m, _, _), d in cells.items() if m == "pod2x16x16"]
    done = [d for d in single if "roofline_fraction" in d]
    lines = [
        f"- single-pod cells compiled: {len(single)} / 32",
        f"- multi-pod cells compiled: {len(multi)} / 32",
    ]
    if done:
        worst = min(done, key=lambda d: d["roofline_fraction"])
        best = max(done, key=lambda d: d["roofline_fraction"])
        coll = max(done, key=lambda d: d["collective_s"])
        lines += [
            f"- worst roofline fraction: {worst['arch']} x {worst['shape']} "
            f"= {worst['roofline_fraction']:.3f} ({worst['bottleneck']}-bound)",
            f"- best roofline fraction: {best['arch']} x {best['shape']} "
            f"= {best['roofline_fraction']:.3f}",
            f"- most collective-bound: {coll['arch']} x {coll['shape']} "
            f"({coll['collective_s']*1e3:.0f} ms)",
        ]
    return "\n".join(lines)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    args = ap.parse_args()
    cells = load(Path(args.dir))
    print("## Summary\n")
    print(summary(cells))
    print("\n## Roofline (single pod, 16x16)\n")
    print(roofline_table(cells))
    print("\n## Per-cell diagnosis\n")
    print(diagnosis_table(cells))
    print("\n## Dry-run compiles\n")
    print(dryrun_table(cells))


if __name__ == "__main__":
    main()


# --------------------------------------------------------------------------- #
# Per-cell one-line diagnoses (assignment: "one sentence on what would move
# the dominant term down")
# --------------------------------------------------------------------------- #
def diagnose(d: dict) -> str:
    arch, shape, kind = d["arch"], d["shape"], d["kind"]
    bot = d.get("bottleneck", "?")
    cfg = get_arch(arch)
    if bot == "collective":
        if cfg.is_moe and kind != "decode":
            return ("explicit shard_map all-to-all dispatch (each device "
                    "receives only its experts' slots) would cut the "
                    "dispatch all-gather ~16x")
        if kind == "decode":
            return ("flash-decode sequence-sharded scores (implemented, "
                    "experiments/hillclimb) removes the cache replication")
        return ("hand-scheduled ring/Ulysses attention + collective-"
                "pipelined FSDP gathers would strip the dense-backward "
                "all-reduce upper bound and overlap the gather stream")
    if bot == "memory":
        if kind == "decode":
            b = d.get("collective_s", 0)
            return ("decode reads params+cache once per token — raise batch "
                    "or shrink the mesh slice to lift arithmetic intensity; "
                    "int8 KV cache would halve the traffic")
        return ("larger microbatching or offloaded activations would cut "
                "the activation stream; weights already stream once/pass")
    return ("compute-bound: fuse attention into the Pallas flash kernel and "
            "raise per-chip utilization (MXU-aligned tiles)")


def diagnosis_table(cells) -> str:
    rows = ["| arch | shape | bottleneck | what moves it down |",
            "|---|---|---|---|"]
    for cfg, shape, skip in dryrun_cells():
        d = cells.get(("pod16x16", cfg.name, shape.name))
        if d is None or "bottleneck" not in d:
            continue
        rows.append(f"| {cfg.name} | {shape.name} | {d['bottleneck']} "
                    f"| {diagnose(d)} |")
    return "\n".join(rows)
