"""First-principles per-device HBM traffic model for the memory roofline term.

Why a model: XLA:CPU's post-compile "bytes accessed" reflects CPU fusion
decisions (orders of magnitude above TPU reality for fused attention/loss
graphs), so the memory term is derived from the workload itself:

  * parameter / optimizer / cache bytes are EXACT per-device values computed
    from the ShapeDtypeStructs and their PartitionSpecs;
  * activation streams are counted as tensor passes over the residual stream
    and block-local intermediates (weight-stationary execution, flash-style
    attention with no score materialization), with remat re-reads included.

The measured XLA number is still recorded in the dry-run JSON for reference.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import numpy as np
from jax.sharding import Mesh

from repro.configs import ArchConfig, ShapeConfig

PyTree = Any


def _spec_div(pspec, mesh: Mesh) -> int:
    div = 1
    for part in pspec:
        if part is None:
            continue
        parts = part if isinstance(part, (tuple, list)) else (part,)
        for a in parts:
            div *= mesh.shape[a]
    return div


def sharded_bytes(specs: PyTree, pspecs: PyTree, mesh: Mesh) -> int:
    """Exact per-device bytes of a sharded pytree."""
    leaves, treedef = jax.tree_util.tree_flatten(specs)
    ps_leaves = treedef.flatten_up_to(pspecs)
    total = 0
    for leaf, ps in zip(leaves, ps_leaves):
        n = int(np.prod(leaf.shape)) * jax.dtypes.canonicalize_dtype(
            leaf.dtype).itemsize
        total += n // max(_spec_div(ps, mesh), 1) if ps is not None else n
    return total


def _activation_traffic(cfg: ArchConfig, shape: ShapeConfig, mesh: Mesh,
                        *, train: bool) -> float:
    """Per-device activation HBM bytes for one full forward (+backward)."""
    dp = int(np.prod([mesh.shape[a] for a in ("pod", "data")
                      if a in mesh.axis_names]))
    tp = mesh.shape.get("model", 1)
    b, s = shape.global_batch, shape.seq_len
    t_loc = b * s / dp                      # tokens per device
    d = cfg.d_model
    bt = 2.0                                # bf16

    def shard(n, k):                        # shard dim n over tp if divisible
        return n / tp if (n % tp == 0 and n >= tp) else n

    passes = 0.0
    l = cfg.num_layers
    if cfg.family in ("dense", "moe", "vlm", "encdec"):
        hd = cfg.resolved_head_dim
        qkv = shard(cfg.num_heads, tp) * hd + 2 * shard(cfg.num_kv_heads, tp) * hd
        # residual x: read by ln1/ln2 + written by attn/mlp adds (4 passes)
        per_layer = 4 * d
        # attention: q/k/v write+read, flash kv re-read per q block, out
        n_kv_blocks = max(s // 1024, 1)
        per_layer += 2 * qkv + 2 * shard(cfg.num_kv_heads, tp) * hd * n_kv_blocks \
            + 2 * shard(cfg.num_heads, tp) * hd
        if cfg.is_moe:
            fe = cfg.moe_d_ff
            e_loc = shard(cfg.padded_experts, tp)
            # dispatch buffer (E,C,D) write+read + expert h (E,C,Fe) w+r + out
            cap_ratio = cfg.top_k * cfg.capacity_factor
            per_layer += cap_ratio * (4 * d + 4 * fe)
            if cfg.num_shared_experts:
                per_layer += 4 * shard(cfg.shared_expert_d_ff, tp) + 2 * d
        else:
            per_layer += 4 * shard(cfg.d_ff, tp) + 2 * d
        passes = l * per_layer
        if cfg.family == "encdec":
            # encoder (same block shape, seq = encoder_seq) + cross-attention
            enc_t_loc = b * cfg.encoder_seq / dp
            passes += cfg.encoder_layers * (4 * d + 2 * qkv + 4 *
                                            shard(cfg.d_ff, tp) + 2 * d) \
                * (enc_t_loc / t_loc)
            passes += l * (2 * qkv + 2 * d)          # cross attn streams
    elif cfg.family in ("ssm", "hybrid"):
        inner = shard(cfg.ssm_heads, tp) * cfg.ssm_head_dim
        n_state = cfg.ssm_state
        # x/z/B/C/dt streams + conv + gated norm + out
        per_layer = 4 * d + 4 * inner + 4 * n_state + 2 * inner + 2 * d
        # chunked SSD: states (H,N,P) per chunk per device
        per_layer += 2 * inner * (n_state / cfg.ssm_chunk)
        passes = l * per_layer
        if cfg.family == "hybrid":
            n_attn = sum(1 for k in cfg.layer_kinds() if k == "mamba_attn")
            hd = cfg.resolved_head_dim
            qkv = shard(cfg.num_heads, tp) * hd + 2 * shard(cfg.num_kv_heads,
                                                            tp) * hd
            n_kv_blocks = max(s // 1024, 1)
            passes += n_attn * (4 * d + 2 * qkv +
                                2 * shard(cfg.num_kv_heads, tp) * hd * n_kv_blocks
                                + 4 * shard(cfg.d_ff, tp) + 2 * d)

    # logits: write + read fp32 over sharded vocab
    v_loc = shard(cfg.padded_vocab, tp)
    logits = 2 * v_loc * 4 / bt             # in units of bf16-elements
    fwd = (passes + logits) * t_loc * bt
    if not train:
        return fwd
    # backward: dgrad streams ~= forward streams; remat re-runs forward
    remat_mult = {"none": 2.0, "dots": 2.6, "full": 3.0}[cfg.remat_policy]
    return fwd * remat_mult


def analytic_hbm_traffic(cfg: ArchConfig, shape: ShapeConfig, mesh: Mesh,
                         plan, razor=None) -> Dict[str, float]:
    """Per-device HBM bytes for one step. `plan` is a StatePlan."""
    p_loc = sharded_bytes(plan.state_specs["params"], plan.param_pspecs, mesh)
    o_loc = sharded_bytes(plan.state_specs["opt"],
                          {"master": plan.opt_pspecs["master"],
                           "m": plan.opt_pspecs["m"],
                           "v": plan.opt_pspecs["v"]}, mesh)
    out: Dict[str, float] = {"params_local": float(p_loc),
                             "opt_local": float(o_loc)}
    if shape.kind == "train":
        # weights: fwd + bwd + remat re-read; grads write+read (bf16);
        # opt read+write; params re-write; backup shard read+write
        w_reads = 3 if cfg.remat_policy != "none" else 2
        traffic = (w_reads + 1 + 2) * p_loc + 2 * o_loc
        if razor is not None:
            traffic += 2 * razor.unique_bytes / max(mesh.size, 1)
        traffic += _activation_traffic(cfg, shape, mesh, train=True)
        out["traffic"] = float(traffic)
    elif shape.kind == "prefill":
        from repro.models import build_model
        from repro.parallel import sharding as shd
        model = build_model(cfg)
        cache_specs = model.cache_specs(shape.global_batch, shape.seq_len)
        cache_ps = shd.cache_pspecs(cfg, cache_specs, mesh)
        c_loc = sharded_bytes(cache_specs, cache_ps, mesh)
        out["cache_local"] = float(c_loc)
        traffic = p_loc + c_loc \
            + _activation_traffic(cfg, shape, mesh, train=False)
        out["traffic"] = float(traffic)
    else:  # decode: params + full cache read per token
        from repro.models import build_model
        from repro.parallel import sharding as shd
        model = build_model(cfg)
        cache_specs = model.cache_specs(shape.global_batch, shape.seq_len)
        cache_ps = shd.cache_pspecs(cfg, cache_specs, mesh)
        c_loc = sharded_bytes(cache_specs, cache_ps, mesh)
        # MoE: only routed experts are touched per decode step
        p_eff = p_loc
        if cfg.is_moe:
            e = cfg.padded_experts
            touched = min(e, shape.global_batch * cfg.top_k)
            expert_frac = touched / e
            from repro.models import param_count
            # expert params dominate; scale total conservatively
            p_eff = p_loc * (0.3 + 0.7 * expert_frac)
        out["cache_local"] = float(c_loc)
        out["traffic"] = float(p_eff + c_loc)
    return out
