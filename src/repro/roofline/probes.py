"""Probe-based exact cost measurement.

The analysis form (unrolled layers, dense attention, parallel SSD — see
repro.models.modes) makes every FLOP/byte/collective visible to XLA's cost
analysis, but compiling 95 unrolled production layers takes tens of minutes.
Costs are affine in the layer counts, so we compile SMALL-depth unrolled
probes and extrapolate:

    cost(features) = features . theta,   features = (1, n_layers[, n_attn])

Probes per family: dense/moe/ssm/vlm L in {2,4}; enc-dec k in {2,4} scaling
both stacks; hybrid (L, n_attn) in {(6,1),(7,1),(12,2)} to separate the
shared-attention block's cost from the Mamba2 blocks'.
"""
from __future__ import annotations

import dataclasses
import gc
from typing import Dict, List, Tuple

import numpy as np

from repro.configs import ArchConfig, ShapeConfig
from repro.models.modes import analysis_mode
from repro.roofline.analyze import parse_collectives


def probe_plan(cfg: ArchConfig) -> Tuple[List[ArchConfig], np.ndarray,
                                         np.ndarray]:
    """Returns (probe_cfgs, probe_features, target_features)."""
    if cfg.family == "hybrid":
        k = cfg.attn_every
        probes = [k, k + 1, 2 * k]
        cfgs = [dataclasses.replace(cfg, num_layers=l) for l in probes]
        feats = np.array([[1.0, l, l // k] for l in probes])
        n_attn = sum(1 for kind in cfg.layer_kinds() if kind == "mamba_attn")
        target = np.array([1.0, cfg.num_layers, n_attn])
    elif cfg.family == "encdec":
        ratio = cfg.encoder_layers / cfg.num_layers
        probes = [2, 4]
        cfgs = [dataclasses.replace(cfg, num_layers=l,
                                    encoder_layers=max(int(l * ratio), 1))
                for l in probes]
        feats = np.array([[1.0, l] for l in probes])
        target = np.array([1.0, cfg.num_layers])
    else:
        probes = [2, 4]
        cfgs = [dataclasses.replace(cfg, num_layers=l) for l in probes]
        feats = np.array([[1.0, l] for l in probes])
        target = np.array([1.0, cfg.num_layers])
    return cfgs, feats, target


def measure_costs(cfg: ArchConfig, shape: ShapeConfig, mesh,
                  *, instant_ckpt: bool = True) -> Dict[str, float]:
    """Compile unrolled analysis probes; extrapolate to production depth."""
    from repro.launch.dryrun import lower_cell
    cfgs, feats, target = probe_plan(cfg)
    rows = []
    for pc in cfgs:
        with analysis_mode():
            lowered = lower_cell(pc, shape, mesh, instant_ckpt=instant_ckpt)
        compiled = lowered.compile()
        cost = compiled.cost_analysis()
        coll = parse_collectives(compiled.as_text())
        rows.append({
            "flops": float(cost.get("flops", 0.0)),
            "bytes": float(cost.get("bytes accessed", 0.0)),
            "coll_bytes": float(coll["total_bytes"]),
            "wire_bytes": float(coll["wire_bytes"]),
            "coll_count": float(coll["total_count"]),
        })
        del compiled, lowered
        gc.collect()
    out: Dict[str, float] = {}
    for key in rows[0]:
        y = np.array([r[key] for r in rows])
        theta, *_ = np.linalg.lstsq(feats, y, rcond=None)
        out[key] = float(max(target @ theta, 0.0))
    out["probe_rows"] = rows  # type: ignore[assignment]
    return out
