"""Target-hardware constants (TPU v5e-class chip, per assignment):
197 TFLOP/s bf16 per chip, 819 GB/s HBM, ~50 GB/s/link ICI, 16 GiB HBM."""

PEAK_FLOPS = 197e12         # bf16 FLOP/s per chip
HBM_BW = 819e9              # bytes/s per chip
ICI_LINK_BW = 50e9          # bytes/s per ICI link
HBM_BYTES = 16 * 1024**3    # per-chip HBM capacity
