"""Roofline analysis from a compiled dry-run artifact.

Three terms per (arch, shape, mesh):

    compute    = HLO_FLOPs_per_device / PEAK_FLOPS
    memory     = HLO_bytes_per_device / HBM_BW
    collective = collective_bytes_per_device / ICI_LINK_BW

``cost_analysis()`` reports per-device FLOPs/bytes (verified empirically:
reported FLOPs ~= analytic_global / n_devices). Collective bytes are parsed
from the post-SPMD compiled HLO text — shapes there are per-device shard
shapes — by summing operand sizes of every all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute.
"""
from __future__ import annotations

import re
from collections import Counter
from dataclasses import asdict, dataclass, field
from typing import Dict, Optional

import numpy as np

from repro.roofline import hw

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# e.g. "f32[8,128]{1,0}" or "bf16[4,16,128]"
_SHAPE_RE = re.compile(r"\b(pred|s8|u8|s16|u16|bf16|f16|s32|u32|f32|s64|u64|f64)"
                       r"\[([0-9,]*)\]")
# `  %name = <result shapes> <op>(...)` — operands are %refs (no shapes), so we
# parse the result shape(s) and convert to operand bytes per op semantics.
_OP_RE = re.compile(
    r"=\s+(.*?)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start|-done)?\(")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_BRACE_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def _group_size(line: str) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return int(m.group(2))  # [num_groups, group_size]<=[...]
    m = _GROUPS_BRACE_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return 1


def parse_collectives(hlo_text: str) -> Dict:
    """Per-device collective bytes by kind from compiled (post-SPMD) HLO text.

    Reports two aggregates:
      * total_bytes      — sum of operand sizes (the assignment's definition)
      * wire_bytes       — ring-algorithm bytes actually crossing links per
                           device (2(g-1)/g x size for all-reduce, (g-1)/g for
                           gather/scatter/all-to-all, size for permute)
    """
    bytes_by_kind: Counter = Counter()
    wire_by_kind: Counter = Counter()
    count_by_kind: Counter = Counter()
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m:
            continue
        result_txt, kind, suffix = m.group(1), m.group(2), m.group(3)
        if suffix == "-done":
            continue  # async pair: -start already counted
        shapes = [_shape_bytes(d, dims)
                  for d, dims in _SHAPE_RE.findall(result_txt)]
        if not shapes:
            continue
        # async start ops carry (operand, result, ...) tuples: use the largest
        result_bytes = max(shapes) if suffix == "-start" else sum(shapes)
        g = max(_group_size(line), 1)
        if kind == "all-gather":
            operand = result_bytes // max(g, 1)
            wire = result_bytes * (g - 1) // max(g, 1)
        elif kind == "reduce-scatter":
            operand = result_bytes * g
            wire = operand * (g - 1) // max(g, 1)
        elif kind == "all-reduce":
            operand = result_bytes
            wire = 2 * result_bytes * (g - 1) // max(g, 1)
        elif kind == "all-to-all":
            operand = result_bytes
            wire = result_bytes * (g - 1) // max(g, 1)
        else:  # collective-permute
            operand = result_bytes
            wire = result_bytes
        bytes_by_kind[kind] += operand
        wire_by_kind[kind] += wire
        count_by_kind[kind] += 1
    return {
        "bytes_by_kind": dict(bytes_by_kind),
        "wire_by_kind": dict(wire_by_kind),
        "count_by_kind": dict(count_by_kind),
        "total_bytes": int(sum(bytes_by_kind.values())),
        "wire_bytes": int(sum(wire_by_kind.values())),
        "total_count": int(sum(count_by_kind.values())),
    }


@dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    n_devices: int
    flops_per_device: float
    hbm_bytes_per_device: float          # XLA-measured (CPU fusion; reference)
    hbm_bytes_flash_adj: float           # measured minus score-tensor traffic
    hbm_bytes_model: float               # first-principles model (memory term)
    collective_bytes_per_device: float
    collective_wire_bytes: float
    peak_memory_per_device: float        # from the PRODUCTION compile
    compute_s: float = 0.0
    memory_s: float = 0.0                # from flash-adjusted bytes
    memory_s_raw: float = 0.0
    collective_s: float = 0.0
    bottleneck: str = ""
    model_flops: float = 0.0             # 6*N*D train / 2*N*D inference
    useful_ratio: float = 0.0            # model_flops / (flops_per_device*n)
    roofline_fraction: float = 0.0
    collectives: Dict = field(default_factory=dict)
    fits_hbm: bool = True
    notes: str = ""

    def finalize(self) -> "RooflineReport":
        self.compute_s = self.flops_per_device / hw.PEAK_FLOPS
        self.memory_s = self.hbm_bytes_model / hw.HBM_BW
        self.memory_s_raw = self.hbm_bytes_per_device / hw.HBM_BW
        self.collective_s = self.collective_bytes_per_device / hw.ICI_LINK_BW
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        self.bottleneck = max(terms, key=terms.get)
        total_flops = self.flops_per_device * self.n_devices
        self.useful_ratio = (self.model_flops / total_flops) if total_flops else 0.0
        # achievable fraction: time of the ideal (pure model-FLOPs) step vs.
        # the dominant roofline term of this compilation.
        ideal = self.model_flops / (self.n_devices * hw.PEAK_FLOPS)
        dom = max(terms.values())
        self.roofline_fraction = (ideal / dom) if dom > 0 else 0.0
        self.fits_hbm = self.peak_memory_per_device <= hw.HBM_BYTES
        return self

    def to_dict(self) -> Dict:
        return asdict(self)


def attention_score_bytes(cfg, shape, n_devices: int) -> float:
    """Analytic per-device HBM traffic of the dense-form (Sq x Skv) score
    tensors that the production blockwise/flash form never materializes.
    Convention: 4 accesses/elt fp32 forward; x3 for train (remat re-fwd +
    dscore traffic). Decode has no score materialization worth adjusting."""
    if shape.kind == "decode":
        return 0.0
    b, s = shape.global_batch, shape.seq_len
    acc = 4 * (3 if shape.kind == "train" else 1) * 4  # accesses x bytes
    elems = 0.0
    if cfg.family in ("dense", "moe", "vlm"):
        elems = cfg.num_layers * b * cfg.num_heads * float(s) * s
    elif cfg.family == "encdec":
        se = cfg.encoder_seq
        elems = (cfg.encoder_layers * b * cfg.num_heads * float(se) * se
                 + cfg.num_layers * b * cfg.num_heads * (float(s) * s +
                                                         float(s) * se))
    elif cfg.family in ("ssm", "hybrid"):
        lc = cfg.ssm_chunk
        nc = (s + lc - 1) // lc
        elems = cfg.num_layers * b * cfg.ssm_heads * nc * float(lc) * lc
        if cfg.family == "hybrid":
            n_attn = sum(1 for k in cfg.layer_kinds() if k == "mamba_attn")
            elems += n_attn * b * cfg.num_heads * float(s) * s
    return acc * elems / n_devices


def analyze_from_costs(costs: Dict, production_compiled, *, arch: str, shape,
                       mesh_name: str, n_devices: int, model_flops: float,
                       cfg=None, hbm_model_bytes: float = 0.0,
                       notes: str = "") -> RooflineReport:
    """Build the report from probe-extrapolated costs (roofline/probes.py)."""
    mem = production_compiled.memory_analysis()
    peak_mem = (mem.argument_size_in_bytes + mem.output_size_in_bytes
                + mem.temp_size_in_bytes - mem.alias_size_in_bytes)
    raw_bytes = float(costs["bytes"])
    adj = attention_score_bytes(cfg, shape, n_devices) if cfg is not None else 0.0
    rep = RooflineReport(
        arch=arch, shape=shape.name, mesh=mesh_name, n_devices=n_devices,
        flops_per_device=float(costs["flops"]),
        hbm_bytes_per_device=raw_bytes,
        hbm_bytes_flash_adj=max(raw_bytes - adj, 0.0),
        hbm_bytes_model=float(hbm_model_bytes) or max(raw_bytes - adj, 0.0),
        collective_bytes_per_device=float(costs["coll_bytes"]),
        collective_wire_bytes=float(costs["wire_bytes"]),
        peak_memory_per_device=float(peak_mem),
        model_flops=float(model_flops),
        collectives={"extrapolated_count": costs["coll_count"]},
        notes=notes,
    )
    return rep.finalize()


def analyze(analysis_compiled, production_compiled, *, arch: str, shape,
            mesh_name: str, n_devices: int, model_flops: float,
            cfg=None, hbm_model_bytes: float = 0.0,
            notes: str = "") -> RooflineReport:
    cost = analysis_compiled.cost_analysis()
    coll = parse_collectives(analysis_compiled.as_text())
    mem = production_compiled.memory_analysis()
    peak_mem = (mem.argument_size_in_bytes + mem.output_size_in_bytes
                + mem.temp_size_in_bytes - mem.alias_size_in_bytes)
    raw_bytes = float(cost.get("bytes accessed", 0.0))
    adj = attention_score_bytes(cfg, shape, n_devices) if cfg is not None else 0.0
    rep = RooflineReport(
        arch=arch, shape=shape.name, mesh=mesh_name, n_devices=n_devices,
        flops_per_device=float(cost.get("flops", 0.0)),
        hbm_bytes_per_device=raw_bytes,
        hbm_bytes_flash_adj=max(raw_bytes - adj, 0.0),
        hbm_bytes_model=float(hbm_model_bytes) or max(raw_bytes - adj, 0.0),
        collective_bytes_per_device=float(coll["total_bytes"]),
        collective_wire_bytes=float(coll["wire_bytes"]),
        peak_memory_per_device=float(peak_mem),
        model_flops=float(model_flops),
        collectives=coll,
        notes=notes,
    )
    return rep.finalize()
