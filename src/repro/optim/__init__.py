from repro.optim.adamw import (AdamWConfig, adamw_init, adamw_update,
                               cast_params, global_norm)
from repro.optim.schedule import cosine_schedule

__all__ = ["AdamWConfig", "adamw_init", "adamw_update", "cast_params",
           "global_norm", "cosine_schedule"]
