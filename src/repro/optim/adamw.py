"""AdamW with ZeRO-1 layout: fp32 master + m + v, all sharded over the "data"
mesh axis (specs from ``repro.parallel.sharding.zero_pspecs``). bf16 params are
re-materialized from the master after each update (XLA turns the sharding
mismatch into reduce-scatter(grads) + all-gather(params) — ZeRO-1's exact
communication pattern, derived from sharding constraints alone).

The razor arithmetic depends on this layout: unique state per device is
master+m+v = 12·φ/d bytes (paper §4.2).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000


def adamw_init(params: PyTree) -> Dict[str, PyTree]:
    f32 = lambda p: p.astype(jnp.float32)
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "master": jax.tree.map(f32, params),
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
    }


def global_norm(tree: PyTree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def adamw_update(
    grads: PyTree,
    opt: Dict[str, PyTree],
    step: jax.Array,
    hp: AdamWConfig,
    lr: jax.Array,
) -> Tuple[PyTree, Dict[str, PyTree]]:
    """Returns (new_params_bf16_source=master, new_opt). Caller casts params."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, hp.grad_clip / jnp.maximum(gnorm, 1e-9))
    t = (step + 1).astype(jnp.float32)
    bc1 = 1.0 - hp.b1 ** t
    bc2 = 1.0 - hp.b2 ** t

    def upd(g, master, m, v):
        g = g.astype(jnp.float32) * scale
        m = hp.b1 * m + (1.0 - hp.b1) * g
        v = hp.b2 * v + (1.0 - hp.b2) * jnp.square(g)
        update = (m / bc1) / (jnp.sqrt(v / bc2) + hp.eps)
        master = master - lr * (update + hp.weight_decay * master)
        return master, m, v

    out = jax.tree.map(upd, grads, opt["master"], opt["m"], opt["v"])
    new_master = jax.tree.map(lambda x: x[0], out,
                              is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda x: x[1], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda x: x[2], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    return new_master, {"master": new_master, "m": new_m, "v": new_v}


def cast_params(master: PyTree, like: PyTree) -> PyTree:
    return jax.tree.map(lambda m, p: m.astype(p.dtype), master, like)
