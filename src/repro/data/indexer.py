"""TID-addressed data indexing (paper §4.1).

Workers never hold statically-partitioned data. The controller-side indexer
maps TID = (role, iteration) -> dataset indices with:

  * exact cover: each iteration's global batch partitions exactly across the
    ACTIVE dp ranks (no duplicates, no gaps) — property-tested;
  * determinism: same (seed, iteration, active_dp) -> same indices, so a
    recovered job replays identical data;
  * elasticity: shrinking/growing active_dp re-partitions the same global
    order, preserving the global sample sequence.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Tuple

import numpy as np


@dataclass(frozen=True)
class Tid:
    dp: int
    pp: int
    tp: int
    iteration: int

    def key(self) -> Tuple[int, int, int, int]:
        return (self.dp, self.pp, self.tp, self.iteration)


class TidIndexer:
    def __init__(self, dataset_size: int, global_batch: int, seed: int = 0):
        if global_batch > dataset_size:
            raise ValueError("global_batch larger than dataset")
        self.dataset_size = dataset_size
        self.global_batch = global_batch
        self.seed = seed
        self._perms: Dict[int, np.ndarray] = {}

    def _perm(self, epoch: int) -> np.ndarray:
        if epoch not in self._perms:
            rng = np.random.default_rng(self.seed + epoch)
            self._perms[epoch] = rng.permutation(self.dataset_size)
            if len(self._perms) > 2:           # keep current + next epoch only
                self._perms.pop(min(self._perms))
        return self._perms[epoch]

    def global_slice(self, iteration: int) -> np.ndarray:
        """The iteration's global batch in canonical order (epoch-shuffled)."""
        start = iteration * self.global_batch
        idx = np.arange(start, start + self.global_batch)
        epochs = idx // self.dataset_size
        offs = idx % self.dataset_size
        out = np.empty(self.global_batch, dtype=np.int64)
        for e in np.unique(epochs):
            m = epochs == e
            out[m] = self._perm(int(e))[offs[m]]
        return out

    def indices(self, iteration: int, dp_rank: int, active_dp: int
                ) -> np.ndarray:
        """TID -> indices. Exact cover over active_dp ranks."""
        if not (0 <= dp_rank < active_dp):
            raise ValueError(f"dp_rank {dp_rank} outside active_dp {active_dp}")
        g = self.global_slice(iteration)
        per = self.global_batch // active_dp
        extra = self.global_batch % active_dp
        lo = dp_rank * per + min(dp_rank, extra)
        hi = lo + per + (1 if dp_rank < extra else 0)
        return g[lo:hi]
