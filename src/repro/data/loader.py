"""FFTrainer data loader (paper §4.1): just-in-time preloading over the
training network with a bounded FIFO host buffer.

Buffer bound (paper): B = min(4*s*b*k, 6*s*b*phi*V/C) — never more than k
iterations ahead, never more than fits in the compute-hidden transfer window.

Sources: deterministic synthetic tokens (hash-seeded, reproducible across
recoveries) and a binary memmap corpus. Preloading is driven by the runtime:
STATE transfers are submitted to the LCCL link scheduler and only move when
the link is idle (§5.3).
"""
from __future__ import annotations

import collections
from dataclasses import dataclass
from pathlib import Path
from typing import Deque, Dict, Optional, Tuple

import numpy as np

from repro.data.indexer import TidIndexer


def buffer_bytes(seq_len: int, batch_per_rank: int, k: int, phi: float,
                 bandwidth: float, flops: float) -> float:
    """Paper §4.1: B = min(4 s b k, 6 s b phi V / C)."""
    return min(4.0 * seq_len * batch_per_rank * k,
               6.0 * seq_len * batch_per_rank * phi * bandwidth / flops)


class SyntheticTokens:
    """Deterministic virtual corpus: sample i is PRNG(seed, i) tokens."""

    def __init__(self, size: int, seq_len: int, vocab: int, seed: int = 0):
        self.size, self.seq_len, self.vocab, self.seed = size, seq_len, vocab, seed

    def fetch(self, indices: np.ndarray) -> np.ndarray:
        out = np.empty((len(indices), self.seq_len + 1), dtype=np.int32)
        for row, i in enumerate(indices):
            rng = np.random.default_rng((self.seed << 32) ^ int(i))
            out[row] = rng.integers(0, self.vocab, self.seq_len + 1)
        return out

    @property
    def sample_bytes(self) -> int:
        return 4 * (self.seq_len + 1)


class MemmapTokens:
    """Flat int32 binary corpus of shape (size, seq_len+1)."""

    def __init__(self, path: Path, seq_len: int):
        self.seq_len = seq_len
        self._mm = np.memmap(path, dtype=np.int32, mode="r")
        self._mm = self._mm.reshape(-1, seq_len + 1)
        self.size = self._mm.shape[0]

    def fetch(self, indices: np.ndarray) -> np.ndarray:
        return np.asarray(self._mm[indices])

    @property
    def sample_bytes(self) -> int:
        return 4 * (self.seq_len + 1)


@dataclass
class BufferedBatch:
    iteration: int
    tokens: np.ndarray


class PrefetchingLoader:
    """Per-DP-rank loader: FIFO buffer of up to k future iterations; evicts
    after consumption; throttles preloading against the buffer bound."""

    def __init__(self, source, indexer: TidIndexer, dp_rank: int,
                 active_dp: int, k: int = 10,
                 byte_limit: Optional[float] = None):
        self.source = source
        self.indexer = indexer
        self.dp_rank = dp_rank
        self.active_dp = active_dp
        self.k = k
        self.byte_limit = byte_limit
        self._buf: Deque[BufferedBatch] = collections.deque()
        self.preload_bytes_total = 0

    # ---- naming resolution: TID -> buffered batch (paper's get_item) ---- #
    def get(self, iteration: int) -> np.ndarray:
        while self._buf and self._buf[0].iteration < iteration:
            self._buf.popleft()                      # evict consumed
        if not self._buf or self._buf[0].iteration != iteration:
            self._load(iteration)                    # demand miss (recovery)
        batch = self._buf.popleft()
        assert batch.iteration == iteration
        return batch.tokens

    def _load(self, iteration: int) -> None:
        idx = self.indexer.indices(iteration, self.dp_rank, self.active_dp)
        self._buf.appendleft(BufferedBatch(iteration, self.source.fetch(idx)))
        self.preload_bytes_total += len(idx) * self.source.sample_bytes

    @property
    def buffered_bytes(self) -> int:
        return sum(b.tokens.nbytes for b in self._buf)

    def can_preload(self) -> bool:
        if len(self._buf) >= self.k:
            return False
        if self.byte_limit is not None and \
                self.buffered_bytes >= self.byte_limit:
            return False
        return True

    def preload_next(self, next_needed: int) -> Optional[int]:
        """Preload the next un-buffered iteration >= next_needed; returns the
        bytes transferred (for the STATE queue) or None if throttled."""
        if not self.can_preload():
            return None
        it = (self._buf[-1].iteration + 1) if self._buf else next_needed
        idx = self.indexer.indices(it, self.dp_rank, self.active_dp)
        self._buf.append(BufferedBatch(it, self.source.fetch(idx)))
        nbytes = len(idx) * self.source.sample_bytes
        self.preload_bytes_total += nbytes
        return nbytes

    def repartition(self, active_dp: int, dp_rank: Optional[int] = None
                    ) -> None:
        """Elastic rescale: drop buffered batches (indices changed)."""
        self.active_dp = active_dp
        if dp_rank is not None:
            self.dp_rank = dp_rank
        self._buf.clear()
