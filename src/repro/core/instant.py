"""Instant checkpointing: neighboring redundancy (paper §4.2, Fig. 3 (B)).

Each iteration, every device streams its *unique* state shard to the next
worker in the DP ring via ``lax.ppermute`` (TPU collective-permute — the
ICI-native point-to-point the paper's RDMA write maps onto). The permute is
fused into the compiled train step so XLA overlaps it with backward/update
compute: this is the "use idle links during compute" mechanism, and the FCR
condition (core/fcr.py) says when it hides completely.

The permuted shards come back as a step *output*; the host runtime
(repro.runtime) keeps them in host RAM as the neighbor's live checkpoint.
"""
from __future__ import annotations

import functools
from typing import Any, Optional

import jax
from jax.sharding import Mesh
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map_compat

PyTree = Any


def ring_perm(n: int, shift: int = 1):
    return [(i, (i + shift) % n) for i in range(n)]


def neighbor_backup(tree: PyTree, pspecs: PyTree, mesh: Mesh,
                    *, axis: str = "data", shift: int = 1) -> PyTree:
    """Permute every leaf one step along the DP ring. Call inside jit.

    tree/pspecs may contain None leaves (razor-redundant): they pass through
    untouched and cost no ICI traffic.
    """
    n = mesh.shape[axis]
    if n <= 1:
        return tree
    perm = ring_perm(n, shift)

    is_p = lambda x: isinstance(x, P) or x is None
    flat_specs, treedef = jax.tree_util.tree_flatten(pspecs, is_leaf=is_p)
    flat_vals = treedef.flatten_up_to(tree)

    present = [(i, v, s) for i, (v, s) in enumerate(zip(flat_vals, flat_specs))
               if v is not None]
    if not present:
        return tree
    idxs, vals, specs = zip(*present)

    def permute_all(*xs):
        return tuple(jax.lax.ppermute(x, axis, perm) for x in xs)

    out = shard_map_compat(
        permute_all, mesh,
        in_specs=tuple(specs), out_specs=tuple(specs),
    )(*vals)

    new_flat = list(flat_vals)
    for i, o in zip(idxs, out):
        new_flat[i] = o
    return jax.tree_util.tree_unflatten(treedef, new_flat)
