"""Paper analytic models: MFU-loss decomposition (§3.1), checkpoint-time
formulas (§2/§4.2), failure probabilities (Table 2) and recovery probability
Eqs. (3)-(5) (§6.2)."""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict

import numpy as np

HOUR = 3600.0
GPU_MTBF_HOURS = 80_000.0  # per-GPU MTBF (paper §3.1)


# --------------------------------------------------------------------------- #
# Checkpoint timing (paper §2, §4.2)
# --------------------------------------------------------------------------- #
def compute_time(s: float, b: float, phi: float, c: float) -> float:
    """T_c = 6 s b phi / C : fwd+bwd compute seconds for phi params/device."""
    return 6.0 * s * b * phi / c


def ckpt_time_full(phi: float, v: float, i: float) -> float:
    """Traditional engine: persist weights+optimizer over network (V) and disk
    (I): T_ckpt = 16 phi (V + I) / (V I)."""
    return 16.0 * phi * (v + i) / (v * i)


def ckpt_time_razor(phi: float, v: float) -> float:
    """FFTrainer: unique Adam state only, to a neighbor over the training
    network: T'_ckpt = 12 phi / V."""
    return 12.0 * phi / v


# --------------------------------------------------------------------------- #
# MFU loss (paper §3.1): L = L_ckpt + L_recover + L_rollback
# --------------------------------------------------------------------------- #
@dataclass(frozen=True)
class MfuLoss:
    ckpt: float
    recover: float
    rollback: float

    @property
    def total(self) -> float:
        return self.ckpt + self.recover + self.rollback


def mfu_loss(t_ckpt: float, t_interval: float, mttr: float,
             mtbf: float) -> MfuLoss:
    """All times in seconds; t_interval is the CKPT interval. Each component
    is capped at 1 (the paper's formulas are small-ratio approximations that
    exceed 1 when e.g. the CKPT interval exceeds the MTBF)."""
    l_ckpt = min(t_ckpt / (t_interval + t_ckpt), 1.0) if t_ckpt else 0.0
    l_recover = min(mttr / (mtbf + mttr), 1.0)
    l_rollback = min((t_interval / 2.0) / (mtbf + mttr), 1.0)
    return MfuLoss(l_ckpt, l_recover, l_rollback)


def cluster_failure_probability(n_gpus: int, horizon_hours: float,
                                gpu_mtbf_hours: float = GPU_MTBF_HOURS) -> float:
    """P that a cluster of n GPUs sees >=1 failure within the horizon
    (Table 2's P_x columns)."""
    return 1.0 - math.exp(-n_gpus * horizon_hours / gpu_mtbf_hours)


def cluster_mtbf_hours(n_gpus: int,
                       gpu_mtbf_hours: float = GPU_MTBF_HOURS) -> float:
    return gpu_mtbf_hours / max(n_gpus, 1)


# --------------------------------------------------------------------------- #
# Recovery probability, Eqs. (3)-(5)
# --------------------------------------------------------------------------- #
def _log_comb(n: int, k: int) -> float:
    if k < 0 or k > n:
        return -math.inf
    return (math.lgamma(n + 1) - math.lgamma(k + 1) - math.lgamma(n - k + 1))


def recovery_prob_given_k(n: int, k: int) -> float:
    """Eq. (3): P that no failed machine's DP-ring neighbor also failed —
    the count of k non-adjacent picks on an N-cycle over C(N,k)."""
    if k <= 1:
        return 1.0
    if 2 * k > n:
        return 0.0
    num = (math.exp(_log_comb(n - k, k) - _log_comb(n, k))
           + math.exp(_log_comb(n - k - 1, k - 1) - _log_comb(n, k)))
    return float(min(num, 1.0))


def k_failure_prob(n: int, k: int, hours: float,
                   gpu_mtbf_hours: float = GPU_MTBF_HOURS,
                   gpus_per_host: int = 8) -> float:
    """Eq. (4): P(exactly k of N hosts fail within `hours`)."""
    mu = gpus_per_host / gpu_mtbf_hours
    p = 1.0 - math.exp(-mu * hours)
    if p <= 0.0:
        return 1.0 if k == 0 else 0.0
    logp = (_log_comb(n, k) + k * math.log(p) + (n - k) * math.log1p(-p))
    return math.exp(logp)


def recovery_probability(n: int, hours: float,
                         gpu_mtbf_hours: float = GPU_MTBF_HOURS,
                         gpus_per_host: int = 8, k_max: int = None) -> float:
    """Eq. (5): P(N,H) = sum_k P_r(N,k) P_f(N,k,H)."""
    if k_max is None:
        # adaptive: sum until the tail is negligible
        mu = gpus_per_host / gpu_mtbf_hours
        p = 1.0 - math.exp(-mu * hours)
        k_max = min(n, max(16, int(4 * n * p + 16)))
    total = 0.0
    for k in range(0, k_max + 1):
        total += recovery_prob_given_k(n, k) * k_failure_prob(
            n, k, hours, gpu_mtbf_hours, gpus_per_host)
    return min(total, 1.0)


def gemini_recovery_probability(n: int, hours: float, m: int = 2,
                                gpu_mtbf_hours: float = GPU_MTBF_HOURS,
                                gpus_per_host: int = 8,
                                samples: int = 200_000,
                                seed: int = 0) -> float:
    """Gemini-style m-replica placement (checkpoint kept on self + next m-1
    machines): recovery fails iff some machine AND all its replica holders
    fail. Monte-Carlo (documented; exact closed form exists only for m=2)."""
    rng = np.random.default_rng(seed)
    mu = gpus_per_host / gpu_mtbf_hours
    p = 1.0 - math.exp(-mu * hours)
    fail = rng.random((samples, n)) < p
    ok = np.ones(samples, dtype=bool)
    lost = fail.copy()
    for j in range(1, m):
        lost &= np.roll(fail, -j, axis=1)
    ok = ~lost.any(axis=1)
    return float(ok.mean())
