"""State controller (paper §3.3, §4.3): a single control-plane process per job.

Responsibilities (all lightweight; scalability measured in fig10 benchmark):
  * liveness: lock-free heartbeat slots, one per reporting worker (local
    rank 0 per host => <= N/8 connections), failure detection within ~1 s;
  * role management: role<->rank decoupling via lccl.RoleTable; on failure it
    rebinds the failed role to the replacement so model loading can start
    before connections are up;
  * data indexing: computes the TID=(role, iter) -> data-index mapping each
    iteration and sends it only to each model-parallel group's rank 0;
  * consistency: tracks per-DP-group checkpoint versions and picks the
    earliest globally-available iteration for recovery (§4.2).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.lccl import LockFreeAddressArray, Role, RoleTable


class HeartbeatTable:
    """Lock-free array of last-seen timestamps; O(workers) vectorized scan."""

    def __init__(self, n_workers: int):
        self.last_seen = np.full(n_workers, -np.inf)

    def beat(self, worker: int, now: float) -> None:
        self.last_seen[worker] = now

    def beat_many(self, workers: np.ndarray, now: float) -> None:
        self.last_seen[workers] = now

    def failed(self, now: float, timeout: float = 1.0) -> np.ndarray:
        return np.flatnonzero(self.last_seen < now - timeout)


@dataclass
class DataAssignment:
    iteration: int
    # per dp-rank index ranges into the (virtual) global dataset order
    ranges: Dict[int, Tuple[int, int]]


class StateController:
    def __init__(self, *, dp: int, pp: int, tp: int, global_batch: int,
                 heartbeat_timeout: float = 1.0, seed: int = 0):
        self.dp, self.pp, self.tp = dp, pp, tp
        self.n_workers = dp * pp * tp
        self.global_batch = global_batch
        self.roles = RoleTable(dp, pp, tp)
        self.addresses = LockFreeAddressArray(self.n_workers)
        self.heartbeats = HeartbeatTable(self.n_workers)
        self.timeout = heartbeat_timeout
        self.iteration = 0
        self._rng = np.random.default_rng(seed)
        self._perm_epoch = -1
        self._perm: Optional[np.ndarray] = None
        # per-DP-group newest checkpoint iteration (consistency, §4.2)
        self.ckpt_versions = np.zeros(dp, dtype=np.int64)
        self.active_dp = dp

    # ---------------- liveness ---------------- #
    # `now` is the SIM clock and is required: the old wall-clock fallback
    # (`time.monotonic()` when now was None) coupled detection latency to
    # host scheduling and broke replay bit-identity (simlint SIM001).
    def beat(self, worker: int, now: float) -> None:
        self.heartbeats.beat(worker, now)

    def detect_failures(self, now: float) -> List[int]:
        return list(self.heartbeats.failed(now, self.timeout))

    # ---------------- data indexing (TID -> indices) ---------------- #
    def assignment(self, iteration: int, dataset_size: int,
                   epoch_shuffle: bool = True) -> DataAssignment:
        """Exact-cover partition of the iteration's global batch across the
        ACTIVE dp ranks (elastic: shrinks/grows with active_dp)."""
        per = self.global_batch // self.active_dp
        start = (iteration * self.global_batch) % max(dataset_size, 1)
        ranges = {}
        for d in range(self.active_dp):
            ranges[d] = (start + d * per, start + (d + 1) * per)
        return DataAssignment(iteration, ranges)

    def indices_for(self, assign: DataAssignment, dp_rank: int,
                    dataset_size: int) -> np.ndarray:
        lo, hi = assign.ranges[dp_rank]
        epoch = (lo // max(dataset_size, 1))
        if epoch != self._perm_epoch:
            self._perm = self._rng.permutation(dataset_size)
            self._perm_epoch = epoch
        idx = np.arange(lo, hi) % dataset_size
        return self._perm[idx]

    def fanout_targets(self) -> List[int]:
        """Controller sends indices only to each TP group's rank 0 (§4.3)."""
        return [self.roles.role_to_rank[(d, p, 0)]
                for d in range(self.dp) for p in range(self.pp)]

    # ---------------- consistency (§4.2) ---------------- #
    def report_ckpt(self, dp_group: int, iteration: int) -> None:
        self.ckpt_versions[dp_group] = iteration

    def resolve_recovery_iteration(self) -> int:
        """Earliest globally-available checkpoint: min over DP groups."""
        return int(self.ckpt_versions[:self.active_dp].min())

    # ---------------- failover hooks ---------------- #
    def replace_worker(self, failed_rank: int, new_rank: int) -> Role:
        return self.roles.rebind(failed_rank, new_rank)

    def shrink_dp(self, lost_dp_groups: Sequence[int]) -> int:
        """Elastic degrade: drop lost DP groups; data indexing re-partitions
        on the next assignment() call."""
        self.active_dp = max(1, self.active_dp - len(set(lost_dp_groups)))
        return self.active_dp

    def restore_dp(self, dp: Optional[int] = None) -> int:
        self.active_dp = self.dp if dp is None else dp
        return self.active_dp
