"""Checkpoint consistency (paper §4.2, §6.2): two recent optimizer snapshots
per worker + earliest-globally-available version resolution.

Failures can stall collectives mid-iteration, leaving DP groups at versions n
and n+1. The controller picks min(versions); workers ahead roll back one step
using the older kept snapshot. Because the unique state is snapshotted
immediately after each update, resuming from that iteration loses no progress
(paper §6.2, last paragraph)."""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

PyTree = Any


@dataclass
class Snapshot:
    iteration: int
    state: PyTree            # host-side (numpy) unique state


class SnapshotKeeper:
    """Holds the last TWO snapshots (a few GB of CPU RAM in production —
    paper: 'FFTrainer keeps two recent snapshots of optimizer state')."""

    def __init__(self, depth: int = 2):
        self.depth = depth
        self._snaps: List[Snapshot] = []

    def push(self, iteration: int, state: PyTree) -> None:
        host = jax.tree.map(np.asarray, state)
        self._snaps.append(Snapshot(iteration, host))
        if len(self._snaps) > self.depth:
            self._snaps.pop(0)

    @property
    def iterations(self) -> List[int]:
        return [s.iteration for s in self._snaps]

    def get(self, iteration: int) -> Optional[Snapshot]:
        for s in reversed(self._snaps):
            if s.iteration == iteration:
                return s
        return None

    def latest(self) -> Optional[Snapshot]:
        return self._snaps[-1] if self._snaps else None


def resolve_global_iteration(versions: Dict[int, int]) -> int:
    """Earliest available checkpoint iteration across DP groups."""
    if not versions:
        raise ValueError("no checkpoint versions reported")
    return min(versions.values())


@dataclass(frozen=True)
class ReconcileAction:
    worker: int
    action: str              # "keep" | "rollback"
    target_iteration: int


def reconcile(worker_versions: Dict[int, int]) -> List[ReconcileAction]:
    """Per-worker action to converge on the globally consistent iteration.
    Raises if any worker is ahead by more than the snapshot depth (cannot
    happen with per-iteration snapshots + one-iteration skew, §4.2)."""
    target = resolve_global_iteration(worker_versions)
    out = []
    for w, v in sorted(worker_versions.items()):
        if v == target:
            out.append(ReconcileAction(w, "keep", target))
        elif v - target == 1:
            out.append(ReconcileAction(w, "rollback", target))
        elif v < target:
            raise AssertionError(f"worker {w} behind global target "
                                 f"({v} < {target}) — versions corrupt")
        else:
            raise AssertionError(
                f"worker {w} ahead by {v - target} > snapshot depth; "
                "multi-level insurance (full CKPT) required")
    return out
