"""Cross-layer failure detection (paper §6.1): interruptible blocking
collectives.

Instead of waiting out a 10-minute NCCL timeout, a blocked worker waits on
EITHER communication completion OR a controller breakdown notification. The
runtime simulator implements the rendezvous with threading primitives; the
same wake-on-either-signal semantics a TPU runtime gets from its coordination
service."""
from __future__ import annotations

import threading
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Set


class WorkerInterrupted(Exception):
    """Raised inside a blocked collective when the controller signals a
    breakdown — lets the main thread exit cleanly and run lazy backup."""

    def __init__(self, failed_workers: List[int]):
        super().__init__(f"breakdown: failed workers {failed_workers}")
        self.failed_workers = failed_workers


class InterruptibleBarrier:
    """All-worker rendezvous standing in for a blocking collective. Waiting
    releases the GIL (threading.Condition), so the agent thread can deliver a
    breakdown notification — the paper's two benefits of the hybrid signal."""

    def __init__(self, n_workers: int):
        self.n = n_workers
        self._cond = threading.Condition()
        self._arrived: Set[int] = set()
        self._generation = 0
        self._broken: Optional[List[int]] = None

    def wait(self, worker: int, timeout: Optional[float] = None) -> int:
        with self._cond:
            if self._broken is not None:
                raise WorkerInterrupted(self._broken)
            gen = self._generation
            self._arrived.add(worker)
            if len(self._arrived) == self.n:
                self._arrived.clear()
                self._generation += 1
                self._cond.notify_all()
                return gen
            while gen == self._generation:
                ok = self._cond.wait(timeout)
                if self._broken is not None:
                    raise WorkerInterrupted(self._broken)
                if not ok:
                    raise TimeoutError(
                        f"collective timeout (worker {worker}) — this is the "
                        "slow path FFTrainer avoids")
            return gen

    def interrupt(self, failed_workers: List[int]) -> None:
        """Controller-triggered breakdown notification (fast path)."""
        with self._cond:
            self._broken = list(failed_workers)
            self._cond.notify_all()

    def reset(self, n_workers: Optional[int] = None) -> None:
        with self._cond:
            if n_workers is not None:
                self.n = n_workers
            self._arrived.clear()
            self._broken = None
            self._generation += 1
            self._cond.notify_all()


@dataclass
class DetectionTimeline:
    """Accounting of detection latency for the failover benchmarks."""
    heartbeat_period: float = 1.0
    controller_scan_period: float = 1.0
    notify_latency: float = 0.05

    def detection_time(self) -> float:
        """Worst-case: miss one heartbeat + one scan + notification."""
        return (self.heartbeat_period + self.controller_scan_period
                + self.notify_latency)

    def nccl_timeout_baseline(self) -> float:
        return 600.0  # NCCL default timeout (paper §3.1)
