"""LCCL — lightweight collective communication layer (paper §5), control plane.

On TPU, the data plane (ring collectives) is compiler-scheduled, so what
transfers from the paper is:

  * role <-> rank decoupling (§5.2): a worker's logical role (r_d, r_p, r_t)
    is stable across restarts; its network rank is whatever slot it lands on.
    Model-partition loading keys off the ROLE and can start before
    connections finish — the overlap that cuts restart latency.
  * lock-free connection building (§5.1): a single address array, one slot per
    rank, written once and flagged; each rank reads only its ring targets —
    no barriers, O(1) work per worker, O(N) total.
  * group-free ring membership (§5.1): with static ring parallelism each
    worker has <=4 peers (prev/next in DP and PP rings); we materialize
    exactly those.
  * TRAIN/STATE two-queue link scheduling (§5.3): TRAIN preempts; STATE moves
    only when the link is idle.

These are real data structures measured by benchmarks (fig8/fig10) and driven
by the failover runtime.
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np


@dataclass(frozen=True)
class Role:
    """Logical position in the 3D-parallel job."""
    dp: int
    pp: int
    tp: int

    def as_tuple(self) -> Tuple[int, int, int]:
        return (self.dp, self.pp, self.tp)


class RoleTable:
    """Bidirectional role <-> rank mapping, stable roles across rank churn."""

    def __init__(self, dp: int, pp: int, tp: int):
        self.shape = (dp, pp, tp)
        self.role_to_rank: Dict[Tuple[int, int, int], int] = {}
        self.rank_to_role: Dict[int, Role] = {}
        rank = 0
        for d in range(dp):
            for p in range(pp):
                for t in range(tp):
                    self.bind(Role(d, p, t), rank)
                    rank += 1

    def bind(self, role: Role, rank: int) -> None:
        old = self.role_to_rank.get(role.as_tuple())
        if old is not None:
            self.rank_to_role.pop(old, None)
        self.role_to_rank[role.as_tuple()] = rank
        self.rank_to_role[rank] = role

    def rebind(self, failed_rank: int, new_rank: int) -> Role:
        """A replacement worker (new rank) takes over the failed worker's
        role. Returns the role so the newcomer knows WHICH partition to load
        — before any connection exists (the §5.2 overlap)."""
        role = self.rank_to_role.pop(failed_rank)
        self.bind(role, new_rank)
        return role

    def ring_peers(self, role: Role) -> Dict[str, Role]:
        """Group-free membership: the <=4 peers of ring 3D parallelism."""
        dp, pp, tp = self.shape
        return {
            "dp_next": Role((role.dp + 1) % dp, role.pp, role.tp),
            "dp_prev": Role((role.dp - 1) % dp, role.pp, role.tp),
            "pp_next": Role(role.dp, (role.pp + 1) % pp, role.tp),
            "pp_prev": Role(role.dp, (role.pp - 1) % pp, role.tp),
        }


class LockFreeAddressArray:
    """§5.1: one write-once slot per rank + a readiness flag; readers poll
    their targets only. NumPy slots stand in for the shared-memory array."""

    def __init__(self, n: int):
        self.addrs = np.zeros(n, dtype=np.int64)   # packed address stand-in
        self.ready = np.zeros(n, dtype=bool)

    def publish(self, rank: int, addr: int) -> None:
        self.addrs[rank] = addr
        self.ready[rank] = True        # flag write is the release

    def try_read(self, rank: int) -> Optional[int]:
        if self.ready[rank]:
            return int(self.addrs[rank])
        return None

    def connect_all(self, rank: int, targets: List[int]) -> List[int]:
        """Resolve this rank's ring targets (no barrier involved; spins until
        each target has published — bounded in tests/benchmarks)."""
        out = []
        for t in targets:
            a = self.try_read(t)
            while a is None:           # lock-free spin
                a = self.try_read(t)
            out.append(a)
        return out


# --------------------------------------------------------------------------- #
# TRAIN/STATE two-queue link scheduler (§5.3)
# --------------------------------------------------------------------------- #
@dataclass
class Transfer:
    kind: str        # "TRAIN" | "STATE"
    size: float      # bytes
    t_submit: float
    t_start: float = 0.0
    t_finish: float = 0.0
    finished: bool = False    # set by the scheduler (t_finish can be 0.0)


class LinkScheduler:
    """Event-driven single-link model: TRAIN monopolizes the link; STATE runs
    only when no TRAIN transfer is queued or in flight. STATE transfers are
    preemptible at `quantum` granularity (checkpoint/data chunks): a quantum
    interrupted by an arriving TRAIN transfer is aborted and retried once the
    link is idle again.

    The simulation clock (`now`) persists across `run(until=...)` calls, and a
    partially-transferred STATE item (`_rem`/`_rem_bytes`) is carried over, so
    a scheduler can be advanced incrementally — e.g. one training iteration at
    a time — and residual state resumes exactly where it left off."""

    def __init__(self, bandwidth: float, quantum: float = 1 << 20):
        self.bw = bandwidth
        self.quantum = quantum
        self.now = 0.0
        self.done: List[Transfer] = []
        self._train: List[Transfer] = []
        self._state: List[Transfer] = []
        self._rem: Optional[Transfer] = None   # STATE mid-flight across runs
        self._rem_bytes = 0.0
        self._last_finish = 0.0

    def submit(self, kind: str, size: float, t: float) -> Transfer:
        tr = Transfer(kind, size, t)
        (self._train if kind == "TRAIN" else self._state).append(tr)
        return tr

    def _finish(self, tr: Transfer) -> None:
        tr.finished = True
        self.done.append(tr)
        self._last_finish = max(self._last_finish, tr.t_finish)

    @property
    def idle(self) -> bool:
        return not (self._train or self._state or self._rem is not None)

    def pending_bytes(self, kind: Optional[str] = None) -> float:
        out = 0.0
        if kind in (None, "TRAIN"):
            out += sum(x.size for x in self._train)
        if kind in (None, "STATE"):
            out += sum(x.size for x in self._state) + self._rem_bytes
        return out

    def run(self, until: float) -> float:
        """Simulate from `now` to `until`; returns link-busy seconds. A
        transfer started before `until` runs to completion (TRAIN is never
        preempted; a STATE quantum is all-or-nothing), so `now` may end up
        slightly past `until`."""
        t = self.now
        busy = 0.0
        pend_t = sorted(self._train, key=lambda x: x.t_submit)
        pend_s = sorted(self._state, key=lambda x: x.t_submit)
        rem_s, rem_bytes = self._rem, self._rem_bytes
        while t < until and (pend_t or pend_s or rem_s is not None):
            ready_t = [x for x in pend_t if x.t_submit <= t]
            if ready_t:
                tr = ready_t[0]
                pend_t.remove(tr)
                tr.t_start = max(t, tr.t_submit)
                dt = tr.size / self.bw
                t = tr.t_start + dt
                busy += dt
                tr.t_finish = t
                self._finish(tr)
                continue
            # link idle for TRAIN: advance STATE by one quantum
            nxt_t = min((x.t_submit for x in pend_t), default=float("inf"))
            if rem_s is None and pend_s and pend_s[0].t_submit <= t:
                rem_s = pend_s.pop(0)
                rem_s.t_start = max(t, rem_s.t_submit)
                rem_bytes = rem_s.size
            if rem_s is not None:
                if rem_bytes <= 0:          # zero-byte transfer: instant
                    rem_s.t_finish = t
                    self._finish(rem_s)
                    rem_s = None
                    continue
                chunk = min(self.quantum, rem_bytes)
                dt = chunk / self.bw
                if t + dt > nxt_t:      # TRAIN arrives mid-quantum: yield
                    t = nxt_t           # (aborted quantum is retried later)
                    continue
                t += dt
                busy += dt
                rem_bytes -= chunk
                if rem_bytes <= 0:
                    rem_s.t_finish = t
                    self._finish(rem_s)
                    rem_s = None
                continue
            # nothing runnable: jump to next submission
            nxt_s = min((x.t_submit for x in pend_s), default=float("inf"))
            nxt = min(nxt_t, nxt_s)
            if nxt == float("inf"):
                break
            t = max(t, nxt)
        self._train = pend_t
        self._state = pend_s
        self._rem, self._rem_bytes = rem_s, rem_bytes
        self.now = max(t, until) if until != float("inf") else t
        return busy

    def drain(self, max_rounds: int = 64) -> float:
        """Run until every submitted transfer has finished; returns the final
        clock. Bounded retry loop: preemption-aborted quanta retransmit, so a
        single analytic horizon can undershoot."""
        t0 = self.now
        total = self.pending_bytes()
        for _ in range(max_rounds):
            if self.idle:
                # clamp the clock back to the true completion instant — the
                # run() horizon above carries slack that should not delay
                # transfers submitted afterwards
                self.now = min(self.now, max(self._last_finish, t0))
                return self.now
            last_submit = max(
                [x.t_submit for x in self._train + self._state] +
                ([self._rem.t_submit] if self._rem is not None else [0.0]))
            horizon = max(self.now, last_submit) + \
                self.pending_bytes() / self.bw + 2.0 * total / self.bw + 1.0
            self.run(until=horizon)
        raise RuntimeError("LinkScheduler.drain did not converge "
                           "(TRAIN arrivals denser than one STATE quantum?)")


def submit_chunked(sched: LinkScheduler, kind: str, nbytes: float, t: float,
                   quantum: Optional[float] = None) -> List[Transfer]:
    """Submit `nbytes` as quantum-sized transfers (last one short); the
    canonical way recovery/checkpoint volumes enter the scheduler."""
    q = sched.quantum if quantum is None else quantum
    n = max(1, int(np.ceil(nbytes / q))) if nbytes > 0 else 1
    out, left = [], nbytes
    for _ in range(n):
        sz = min(q, left)
        out.append(sched.submit(kind, max(sz, 0.0), t))
        left -= sz
    return out


def ring_allreduce_time(size_bytes: float, n: int, bandwidth: float,
                        latency: float = 15e-6, efficiency: float = 1.0
                        ) -> float:
    """Ring allreduce wall time: 2(n-1)/n * size / (BW*eff) + 2(n-1)*lat."""
    if n <= 1:
        return 0.0
    steps = 2 * (n - 1)
    return (steps / n) * size_bytes / (bandwidth * efficiency) \
        + steps * latency
