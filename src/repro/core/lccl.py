"""LCCL — lightweight collective communication layer (paper §5), control plane.

On TPU, the data plane (ring collectives) is compiler-scheduled, so what
transfers from the paper is:

  * role <-> rank decoupling (§5.2): a worker's logical role (r_d, r_p, r_t)
    is stable across restarts; its network rank is whatever slot it lands on.
    Model-partition loading keys off the ROLE and can start before
    connections finish — the overlap that cuts restart latency.
  * lock-free connection building (§5.1): a single address array, one slot per
    rank, written once and flagged; each rank reads only its ring targets —
    no barriers, O(1) work per worker, O(N) total.
  * group-free ring membership (§5.1): with static ring parallelism each
    worker has <=4 peers (prev/next in DP and PP rings); we materialize
    exactly those.
  * TRAIN/STATE two-queue link scheduling (§5.3): TRAIN preempts; STATE moves
    only when the link is idle.

These are real data structures measured by benchmarks (fig8/fig10) and driven
by the failover runtime.
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np


@dataclass(frozen=True)
class Role:
    """Logical position in the 3D-parallel job."""
    dp: int
    pp: int
    tp: int

    def as_tuple(self) -> Tuple[int, int, int]:
        return (self.dp, self.pp, self.tp)


class RoleTable:
    """Bidirectional role <-> rank mapping, stable roles across rank churn."""

    def __init__(self, dp: int, pp: int, tp: int):
        self.shape = (dp, pp, tp)
        self.role_to_rank: Dict[Tuple[int, int, int], int] = {}
        self.rank_to_role: Dict[int, Role] = {}
        rank = 0
        for d in range(dp):
            for p in range(pp):
                for t in range(tp):
                    self.bind(Role(d, p, t), rank)
                    rank += 1

    def bind(self, role: Role, rank: int) -> None:
        old = self.role_to_rank.get(role.as_tuple())
        if old is not None:
            self.rank_to_role.pop(old, None)
        self.role_to_rank[role.as_tuple()] = rank
        self.rank_to_role[rank] = role

    def rebind(self, failed_rank: int, new_rank: int) -> Role:
        """A replacement worker (new rank) takes over the failed worker's
        role. Returns the role so the newcomer knows WHICH partition to load
        — before any connection exists (the §5.2 overlap)."""
        role = self.rank_to_role.pop(failed_rank)
        self.bind(role, new_rank)
        return role

    def ring_peers(self, role: Role) -> Dict[str, Role]:
        """Group-free membership: the <=4 peers of ring 3D parallelism."""
        dp, pp, tp = self.shape
        return {
            "dp_next": Role((role.dp + 1) % dp, role.pp, role.tp),
            "dp_prev": Role((role.dp - 1) % dp, role.pp, role.tp),
            "pp_next": Role(role.dp, (role.pp + 1) % pp, role.tp),
            "pp_prev": Role(role.dp, (role.pp - 1) % pp, role.tp),
        }


class LockFreeAddressArray:
    """§5.1: one write-once slot per rank + a readiness flag; readers poll
    their targets only. NumPy slots stand in for the shared-memory array."""

    def __init__(self, n: int):
        self.addrs = np.zeros(n, dtype=np.int64)   # packed address stand-in
        self.ready = np.zeros(n, dtype=bool)

    def publish(self, rank: int, addr: int) -> None:
        self.addrs[rank] = addr
        self.ready[rank] = True        # flag write is the release

    def try_read(self, rank: int) -> Optional[int]:
        if self.ready[rank]:
            return int(self.addrs[rank])
        return None

    def connect_all(self, rank: int, targets: List[int]) -> List[int]:
        """Resolve this rank's ring targets (no barrier involved; spins until
        each target has published — bounded in tests/benchmarks)."""
        out = []
        for t in targets:
            a = self.try_read(t)
            while a is None:           # lock-free spin
                a = self.try_read(t)
            out.append(a)
        return out


# --------------------------------------------------------------------------- #
# TRAIN/STATE two-queue link scheduler (§5.3)
# --------------------------------------------------------------------------- #
@dataclass
class Transfer:
    kind: str        # "TRAIN" | "STATE"
    size: float      # bytes
    t_submit: float
    t_start: float = 0.0
    t_finish: float = 0.0
    finished: bool = False    # set by the scheduler (t_finish can be 0.0)


class LinkScheduler:
    """Event-driven single-link model: TRAIN monopolizes the link; STATE runs
    only when no TRAIN transfer is queued or in flight. STATE transfers are
    preemptible at `quantum` granularity (checkpoint/data chunks): a quantum
    interrupted by an arriving TRAIN transfer is aborted and retried once the
    link is idle again.

    The simulation clock (`now`) persists across `run(until=...)` calls, and a
    partially-transferred STATE item (`_rem`/`_rem_bytes`) is carried over, so
    a scheduler can be advanced incrementally — e.g. one training iteration at
    a time — and residual state resumes exactly where it left off."""

    def __init__(self, bandwidth: float, quantum: float = 1 << 20):
        self.bw = bandwidth
        self.quantum = quantum
        self.now = 0.0
        self.done: List[Transfer] = []
        self.n_finished = 0            # survives done-list pruning
        self._train: List[Transfer] = []
        self._state: List[Transfer] = []
        self._rem: Optional[Transfer] = None   # STATE mid-flight across runs
        self._rem_bytes = 0.0
        self._last_finish = 0.0

    def submit(self, kind: str, size: float, t: float) -> Transfer:
        tr = Transfer(kind, size, t)
        (self._train if kind == "TRAIN" else self._state).append(tr)
        return tr

    def _finish(self, tr: Transfer) -> None:
        tr.finished = True
        self.done.append(tr)
        self.n_finished += 1
        self._last_finish = max(self._last_finish, tr.t_finish)

    @property
    def idle(self) -> bool:
        return not (self._train or self._state or self._rem is not None)

    def pending_bytes(self, kind: Optional[str] = None) -> float:
        out = 0.0
        if kind in (None, "TRAIN"):
            out += sum(x.size for x in self._train)
        if kind in (None, "STATE"):
            out += sum(x.size for x in self._state) + self._rem_bytes
        return out

    def run(self, until: float) -> float:
        """Simulate from `now` to `until`; returns link-busy seconds. A
        transfer started before `until` runs to completion (TRAIN is never
        preempted; a STATE quantum is all-or-nothing), so `now` may end up
        slightly past `until`."""
        t = self.now
        busy = 0.0
        pend_t = sorted(self._train, key=lambda x: x.t_submit)
        pend_s = sorted(self._state, key=lambda x: x.t_submit)
        rem_s, rem_bytes = self._rem, self._rem_bytes
        while t < until and (pend_t or pend_s or rem_s is not None):
            ready_t = [x for x in pend_t if x.t_submit <= t]
            if ready_t:
                tr = ready_t[0]
                pend_t.remove(tr)
                tr.t_start = max(t, tr.t_submit)
                dt = tr.size / self.bw
                t = tr.t_start + dt
                busy += dt
                tr.t_finish = t
                self._finish(tr)
                continue
            # link idle for TRAIN: advance STATE by one quantum
            nxt_t = min((x.t_submit for x in pend_t), default=float("inf"))
            if rem_s is None and pend_s and pend_s[0].t_submit <= t:
                rem_s = pend_s.pop(0)
                rem_s.t_start = max(t, rem_s.t_submit)
                rem_bytes = rem_s.size
            if rem_s is not None:
                if rem_bytes <= 0:          # zero-byte transfer: instant
                    rem_s.t_finish = t
                    self._finish(rem_s)
                    rem_s = None
                    continue
                chunk = min(self.quantum, rem_bytes)
                dt = chunk / self.bw
                if t + dt > nxt_t:      # TRAIN arrives mid-quantum: yield
                    t = nxt_t           # (aborted quantum is retried later)
                    continue
                t += dt
                busy += dt
                rem_bytes -= chunk
                if rem_bytes <= 0:
                    rem_s.t_finish = t
                    self._finish(rem_s)
                    rem_s = None
                continue
            # nothing runnable: jump to next submission
            nxt_s = min((x.t_submit for x in pend_s), default=float("inf"))
            nxt = min(nxt_t, nxt_s)
            if nxt == float("inf"):
                break
            t = max(t, nxt)
        self._train = pend_t
        self._state = pend_s
        self._rem, self._rem_bytes = rem_s, rem_bytes
        self.now = max(t, until) if until != float("inf") else t
        return busy

    def drain(self, max_rounds: int = 64) -> float:
        """Run until every submitted transfer has finished; returns the final
        clock. Bounded retry loop: preemption-aborted quanta retransmit, so a
        single analytic horizon can undershoot."""
        t0 = self.now
        total = self.pending_bytes()
        for _ in range(max_rounds):
            if self.idle:
                # clamp the clock back to the true completion instant — the
                # run() horizon above carries slack that should not delay
                # transfers submitted afterwards
                self.now = min(self.now, max(self._last_finish, t0))
                return self.now
            last_submit = max(
                [x.t_submit for x in self._train + self._state] +
                ([self._rem.t_submit] if self._rem is not None else [0.0]))
            horizon = max(self.now, last_submit) + \
                self.pending_bytes() / self.bw + 2.0 * total / self.bw + 1.0
            self.run(until=horizon)
        raise RuntimeError("LinkScheduler.drain did not converge "
                           "(TRAIN arrivals denser than one STATE quantum?)")


# --------------------------------------------------------------------------- #
# Per-link topology: one LinkScheduler per edge (ISSUE 2 tentpole)
# --------------------------------------------------------------------------- #
Edge = Tuple[int, int]


def edge_key(u: int, v: int) -> Edge:
    """Canonical (undirected) edge identity."""
    return (u, v) if u <= v else (v, u)


@dataclass
class PathTransfer:
    """One item moving hop-by-hop (store-and-forward) along an edge path.

    Duck-types the `Transfer` surface that `StreamTicket` consumes
    (`finished`, `t_finish`, `t_submit`), so transport tickets work unchanged
    whether a chunk crossed one edge or rode a multi-hop recovery path."""
    kind: str
    size: float
    t_submit: float
    path: Tuple[Edge, ...]
    hop: int = 0                       # index of the edge currently in flight
    transfer: Optional[Transfer] = None
    finished: bool = False
    t_finish: float = 0.0

    @property
    def edge(self) -> Optional[Edge]:
        return self.path[self.hop] if self.hop < len(self.path) else None


class LinkTopology:
    """A graph of per-edge `LinkScheduler`s replacing the PR-1 global link.

    * ``kind="ring"``: edge (i, i+1 mod n) for every i — the DP-ring fabric
      the paper's neighbor shards and allreduce actually use.
    * ``kind="full"``: every pair — an idealized fully-connected fabric.

    Each edge is an independent TRAIN/STATE two-queue scheduler, so
    contention is per-edge instead of uniformly smeared: a saturated hotspot
    edge delays only the streams routed across it. A failed node's incident
    edges go dark (``fail_node``) and ``path`` routes around them; individual
    edges can also be failed (``fail_edge``) to force multi-hop detours.

    Multi-hop items move store-and-forward: a chunk fully crosses one edge,
    then is submitted on the next at its arrival time (``_pump``). Within a
    single ``run(until=...)`` window a chunk advances at most one hop (each
    edge clock is already clamped to ``until``); ``drain()`` loops rounds
    with growing horizons, so drained timings are exact."""

    def __init__(self, n: int, bandwidth: float, quantum: float = 1 << 20,
                 kind: str = "ring",
                 edge_bw: Optional[Dict[Edge, float]] = None):
        assert kind in ("ring", "full"), kind
        assert n >= 1
        self.n = n
        self.kind = kind
        self.default_bw = bandwidth
        self.quantum = quantum
        if kind == "ring":
            edges = {edge_key(i, (i + 1) % n) for i in range(n)} if n > 1 \
                else set()
        else:
            edges = {(i, j) for i in range(n) for j in range(i + 1, n)}
        bw = dict(edge_bw or {})
        self.links: Dict[Edge, LinkScheduler] = {
            e: LinkScheduler(bw.get(e, bandwidth), quantum=quantum)
            for e in sorted(edges)}
        self.dark_nodes: set = set()
        self.dark_edges: set = set()
        self._forwarding: List[PathTransfer] = []

    # ------------------------- graph queries ------------------------- #
    def edges(self) -> List[Edge]:
        return list(self.links)

    def edge(self, u: int, v: int) -> LinkScheduler:
        return self.links[edge_key(u, v)]

    def set_bandwidth(self, u: int, v: int, bandwidth: float) -> None:
        self.links[edge_key(u, v)].bw = bandwidth

    def edge_up(self, u: int, v: int) -> bool:
        e = edge_key(u, v)
        return (e in self.links and e not in self.dark_edges
                and u not in self.dark_nodes and v not in self.dark_nodes)

    def live_edges(self) -> List[Edge]:
        return [e for e in self.links if self.edge_up(*e)]

    def neighbors(self, u: int) -> List[int]:
        out = []
        for a, b in self.links:
            if a == u and self.edge_up(a, b):
                out.append(b)
            elif b == u and self.edge_up(a, b):
                out.append(a)
        return sorted(out)

    # ------------------------- failure state ------------------------- #
    def fail_node(self, wid: int) -> None:
        self.dark_nodes.add(wid)

    def restore_node(self, wid: int) -> None:
        self.dark_nodes.discard(wid)

    def fail_edge(self, u: int, v: int) -> None:
        self.dark_edges.add(edge_key(u, v))

    def restore_edge(self, u: int, v: int) -> None:
        self.dark_edges.discard(edge_key(u, v))

    # ------------------------- routing ------------------------- #
    def path(self, src: int, dst: int) -> List[Edge]:
        """Shortest live path src -> dst (BFS), as a list of edges. The
        endpoints are assumed up (a recovering node's pod is created before
        its state streams); intermediate dark nodes/edges are routed around."""
        if src == dst:
            return []
        prev: Dict[int, int] = {src: src}
        frontier = [src]
        while frontier and dst not in prev:
            nxt = []
            for u in frontier:
                for a, b in self.links:
                    if edge_key(a, b) in self.dark_edges:
                        continue
                    for x, y in ((a, b), (b, a)):
                        if x != u or y in prev:
                            continue
                        # intermediate nodes must be live; dst itself is
                        # allowed (its pod is up by the time state moves)
                        if y != dst and y in self.dark_nodes:
                            continue
                        if u != src and u in self.dark_nodes:
                            continue
                        prev[y] = u
                        nxt.append(y)
            frontier = nxt
        if dst not in prev:
            raise RuntimeError(
                f"no live path {src} -> {dst} "
                f"(dark nodes {sorted(self.dark_nodes)}, "
                f"dark edges {sorted(self.dark_edges)})")
        hops = []
        node = dst
        while node != src:
            hops.append(edge_key(prev[node], node))
            node = prev[node]
        return hops[::-1]

    def least_loaded_edge(self, kind: Optional[str] = None) -> Edge:
        """The live edge with the least queued bytes — where full/lazy
        checkpoint streams go so they stay off busy training edges."""
        live = self.live_edges()
        if not live:
            raise RuntimeError("no live edges in the topology")
        return min(live, key=lambda e: (self.links[e].pending_bytes(kind), e))

    # ------------------------- submission ------------------------- #
    def submit_path(self, kind: str, size: float, t: float,
                    path: Sequence[Edge]) -> PathTransfer:
        """Put one item on an edge path. Empty path = local delivery."""
        pt = PathTransfer(kind, size, t, tuple(edge_key(*e) for e in path))
        if not pt.path:
            pt.finished = True
            pt.t_finish = t
            return pt
        pt.transfer = self.links[pt.path[0]].submit(kind, size, t)
        self._forwarding.append(pt)
        return pt

    def submit_train_edge(self, u: int, v: int, nbytes: float, t: float
                          ) -> Transfer:
        return self.edge(u, v).submit("TRAIN", nbytes, t)

    def submit_train_ring(self, nbytes_per_edge: float, t: float
                          ) -> List[Transfer]:
        """One step's ring-allreduce volume, edge by edge: every live edge
        carries 2(n-1)/n of the gradient bytes (`step_traffic`), so TRAIN
        preemption is per-edge instead of smeared over a global link."""
        return [sch.submit("TRAIN", nbytes_per_edge, t)
                for e, sch in self.links.items() if self.edge_up(*e)]

    # ------------------------- simulation ------------------------- #
    def _pump(self) -> int:
        """Advance store-and-forward: items whose current leg landed are
        submitted on their next edge at the arrival time (or delivered)."""
        progressed = 0
        still = []
        for pt in self._forwarding:
            if pt.transfer is not None and pt.transfer.finished:
                progressed += 1
                pt.hop += 1
                if pt.hop < len(pt.path):
                    pt.transfer = self.links[pt.path[pt.hop]].submit(
                        pt.kind, pt.size, pt.transfer.t_finish)
                    still.append(pt)
                else:
                    pt.finished = True
                    pt.t_finish = pt.transfer.t_finish
            else:
                still.append(pt)
        self._forwarding = still
        return progressed

    @property
    def idle(self) -> bool:
        return not self._forwarding and \
            all(sch.idle for sch in self.links.values())

    def pending_bytes(self, kind: Optional[str] = None) -> float:
        return sum(sch.pending_bytes(kind) for sch in self.links.values())

    @property
    def clock(self) -> float:
        return max((sch.now for sch in self.links.values()), default=0.0)

    def run(self, until: float) -> float:
        busy = sum(sch.run(until) for sch in self.links.values())
        self._pump()
        return busy

    def drain(self, max_rounds: int = 64) -> float:
        """Run every edge until all transfers (and forwarded hops) land."""
        for _ in range(max_rounds):
            for sch in self.links.values():
                if not sch.idle:
                    sch.drain()
            self._pump()
            if self.idle:
                return self.clock
        raise RuntimeError("LinkTopology.drain did not converge")


def submit_chunked_path(topo: LinkTopology, kind: str, nbytes: float,
                        t: float, path: Sequence[Edge],
                        quantum: Optional[float] = None) -> List[PathTransfer]:
    """Submit `nbytes` as quantum-sized items along an edge path — the
    per-link analogue of `submit_chunked` (recovery fetches, modeled
    checkpoint volumes)."""
    q = topo.quantum if quantum is None else quantum
    n = max(1, int(np.ceil(nbytes / q))) if nbytes > 0 else 1
    out, left = [], nbytes
    for _ in range(n):
        sz = min(q, left)
        out.append(topo.submit_path(kind, max(sz, 0.0), t, path))
        left -= sz
    return out


def submit_chunked(sched: LinkScheduler, kind: str, nbytes: float, t: float,
                   quantum: Optional[float] = None) -> List[Transfer]:
    """Submit `nbytes` as quantum-sized transfers (last one short); the
    canonical way recovery/checkpoint volumes enter the scheduler."""
    q = sched.quantum if quantum is None else quantum
    n = max(1, int(np.ceil(nbytes / q))) if nbytes > 0 else 1
    out, left = [], nbytes
    for _ in range(n):
        sz = min(q, left)
        out.append(sched.submit(kind, max(sz, 0.0), t))
        left -= sz
    return out


def ring_allreduce_time(size_bytes: float, n: int, bandwidth: float,
                        latency: float = 15e-6, efficiency: float = 1.0
                        ) -> float:
    """Ring allreduce wall time: 2(n-1)/n * size / (BW*eff) + 2(n-1)*lat."""
    if n <= 1:
        return 0.0
    steps = 2 * (n - 1)
    return (steps / n) * size_bytes / (bandwidth * efficiency) \
        + steps * latency
