"""LCCL — lightweight collective communication layer (paper §5), control plane.

On TPU, the data plane (ring collectives) is compiler-scheduled, so what
transfers from the paper is:

  * role <-> rank decoupling (§5.2): a worker's logical role (r_d, r_p, r_t)
    is stable across restarts; its network rank is whatever slot it lands on.
    Model-partition loading keys off the ROLE and can start before
    connections finish — the overlap that cuts restart latency.
  * lock-free connection building (§5.1): a single address array, one slot per
    rank, written once and flagged; each rank reads only its ring targets —
    no barriers, O(1) work per worker, O(N) total.
  * group-free ring membership (§5.1): with static ring parallelism each
    worker has <=4 peers (prev/next in DP and PP rings); we materialize
    exactly those.
  * TRAIN/STATE two-queue link scheduling (§5.3): TRAIN preempts; STATE moves
    only when the link is idle.

The link model grows in layers, matching real cluster fabrics:

  * `LinkScheduler`  — one link: two queues, TRAIN preempts STATE, optional
    per-transfer delivery latency.
  * `LinkTopology`   — a graph of per-edge schedulers (flat ring or full
    mesh): per-edge contention, dark nodes/edges, BFS live-path routing,
    store-and-forward multi-hop items, and bidirectional (edge-disjoint)
    path splitting by residual bandwidth.
  * `PodFabric`      — the hierarchical tier: nodes grouped into pods, each
    pod an ICI ring at full link bandwidth, pods joined by lower-bandwidth /
    higher-latency DCN gateway edges. Failure *storms* (`inject_storm`)
    darken correlated pods/edges from a seed, so recovery has to race around
    a darkened pod over DCN.

Units, everywhere in this module: bandwidths are **bytes/second**, sizes are
**bytes**, times and latencies are **seconds** on the simulation clock.

These are real data structures measured by benchmarks (fig8/fig10) and driven
by the failover runtime.
"""
from __future__ import annotations

import bisect
import threading
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np


@dataclass(frozen=True)
class Role:
    """Logical position in the 3D-parallel job."""
    dp: int
    pp: int
    tp: int

    def as_tuple(self) -> Tuple[int, int, int]:
        return (self.dp, self.pp, self.tp)


class RoleTable:
    """Bidirectional role <-> rank mapping, stable roles across rank churn."""

    def __init__(self, dp: int, pp: int, tp: int):
        self.shape = (dp, pp, tp)
        self.role_to_rank: Dict[Tuple[int, int, int], int] = {}
        self.rank_to_role: Dict[int, Role] = {}
        rank = 0
        for d in range(dp):
            for p in range(pp):
                for t in range(tp):
                    self.bind(Role(d, p, t), rank)
                    rank += 1

    def bind(self, role: Role, rank: int) -> None:
        old = self.role_to_rank.get(role.as_tuple())
        if old is not None:
            self.rank_to_role.pop(old, None)
        self.role_to_rank[role.as_tuple()] = rank
        self.rank_to_role[rank] = role

    def rebind(self, failed_rank: int, new_rank: int) -> Role:
        """A replacement worker (new rank) takes over the failed worker's
        role. Returns the role so the newcomer knows WHICH partition to load
        — before any connection exists (the §5.2 overlap)."""
        role = self.rank_to_role.pop(failed_rank)
        self.bind(role, new_rank)
        return role

    def ring_peers(self, role: Role) -> Dict[str, Role]:
        """Group-free membership: the <=4 peers of ring 3D parallelism."""
        dp, pp, tp = self.shape
        return {
            "dp_next": Role((role.dp + 1) % dp, role.pp, role.tp),
            "dp_prev": Role((role.dp - 1) % dp, role.pp, role.tp),
            "pp_next": Role(role.dp, (role.pp + 1) % pp, role.tp),
            "pp_prev": Role(role.dp, (role.pp - 1) % pp, role.tp),
        }


class LockFreeAddressArray:
    """§5.1: one write-once slot per rank + a readiness flag; readers poll
    their targets only. NumPy slots stand in for the shared-memory array."""

    def __init__(self, n: int):
        self.addrs = np.zeros(n, dtype=np.int64)   # packed address stand-in
        self.ready = np.zeros(n, dtype=bool)

    def publish(self, rank: int, addr: int) -> None:
        self.addrs[rank] = addr
        self.ready[rank] = True        # flag write is the release

    def try_read(self, rank: int) -> Optional[int]:
        if self.ready[rank]:
            return int(self.addrs[rank])
        return None

    def connect_all(self, rank: int, targets: List[int]) -> List[int]:
        """Resolve this rank's ring targets (no barrier involved; spins until
        each target has published — bounded in tests/benchmarks)."""
        out = []
        for t in targets:
            a = self.try_read(t)
            while a is None:           # lock-free spin
                a = self.try_read(t)
            out.append(a)
        return out


# --------------------------------------------------------------------------- #
# TRAIN/STATE two-queue link scheduler (§5.3)
# --------------------------------------------------------------------------- #
@dataclass
class Transfer:
    kind: str        # "TRAIN" | "STATE"
    size: float      # bytes
    t_submit: float
    t_start: float = 0.0
    t_finish: float = 0.0
    finished: bool = False    # set by the scheduler (t_finish can be 0.0)


class LinkScheduler:
    """Event-driven single-link model: TRAIN monopolizes the link; STATE runs
    only when no TRAIN transfer is queued or in flight. STATE transfers are
    preemptible at `quantum` granularity (checkpoint/data chunks): a quantum
    interrupted by an arriving TRAIN transfer is aborted and retried once the
    link is idle again.

    `bandwidth` is bytes/second; `quantum` is the STATE preemption grain in
    bytes; `latency` (seconds) is the per-transfer delivery delay: a transfer
    occupies the link for ``size / bandwidth`` seconds and its receiver sees
    it ``latency`` seconds after transmission ends (`t_finish` includes the
    latency; link occupancy does not). Chunks of one stream pipeline on a
    link, so a chunked artifact pays the latency once per *hop*, not once
    per chunk.

    The simulation clock (`now`) persists across `run(until=...)` calls, and a
    partially-transferred STATE item (`_rem`/`_rem_bytes`) is carried over, so
    a scheduler can be advanced incrementally — e.g. one training iteration at
    a time — and residual state resumes exactly where it left off.

    Two event-clock primitives let `LinkTopology` advance a whole fabric of
    these schedulers in cross-edge event order: `peek_next_finish(until)`
    reports (without mutating anything) WHEN this link's next transfer would
    complete, and ``run(until, stop_after_finish=True)`` advances exactly to
    that completion, leaving the clock at the event instant instead of the
    window horizon."""

    def __init__(self, bandwidth: float, quantum: float = 1 << 20,
                 latency: float = 0.0):
        self.bw = bandwidth
        self.quantum = quantum
        self.latency = latency
        self.now = 0.0
        self.done: List[Transfer] = []
        self.n_finished = 0            # survives done-list pruning
        # observed-throughput accounting (gray-failure detection): delivered
        # TRAIN payload and the transmit seconds it actually took at the
        # CURRENT bw — a silently degraded link shows up as delivered bytes
        # per transmit second falling below the provisioned rate
        self.train_bytes_done = 0.0
        self.train_tx_seconds = 0.0
        self._train: List[Transfer] = []
        self._state: List[Transfer] = []
        self._rem: Optional[Transfer] = None   # STATE mid-flight across runs
        self._rem_bytes = 0.0

    def submit(self, kind: str, size: float, t: float) -> Transfer:
        tr = Transfer(kind, size, t)
        # queues stay sorted by t_submit at all times (insort_right keeps
        # same-instant submissions in submission order), so run/peek walk
        # from the head with cursors instead of re-sorting per call; run
        # prunes its consumed prefix in one slice. Submissions in
        # non-decreasing time order (the overwhelmingly common case) insert
        # at the tail, so insort costs no element shifts there
        q = self._train if kind == "TRAIN" else self._state
        bisect.insort_right(q, tr, key=lambda x: x.t_submit)
        return tr

    def cancel(self, tr: Transfer) -> bool:
        """Withdraw a queued transfer that has NOT started moving bytes.

        Returns True when `tr` was still sitting in its queue (removed by
        identity — equal-valued transfers of one chunked stream must not
        alias); False when it already finished or is the mid-flight STATE
        item (`_rem`), whose transmitted quanta cannot be un-sent. This is
        the substrate for mid-transfer re-balancing: only never-started
        chunks are re-routable, so delivered bytes are never re-sent."""
        if tr.finished or tr is self._rem:
            return False
        q = self._train if tr.kind == "TRAIN" else self._state
        for i, queued in enumerate(q):
            if queued is tr:
                del q[i]
                return True
        return False

    def _finish(self, tr: Transfer, tx_end: float) -> None:
        """Mark `tr` delivered: transmission ended at `tx_end`; the receiver
        sees it `latency` seconds later (`t_finish`). The link itself is free
        again at `tx_end`, so only transmission time gates later transfers."""
        tr.t_finish = tx_end + self.latency
        tr.finished = True
        self.done.append(tr)
        self.n_finished += 1
        if tr.kind == "TRAIN":
            self.train_bytes_done += tr.size
            self.train_tx_seconds += tr.size / self.bw

    @property
    def idle(self) -> bool:
        return not (self._train or self._state or self._rem is not None)

    def pending_bytes(self, kind: Optional[str] = None) -> float:
        out = 0.0
        if kind in (None, "TRAIN"):
            out += sum(x.size for x in self._train)
        if kind in (None, "STATE"):
            out += sum(x.size for x in self._state) + self._rem_bytes
        return out

    def run(self, until: float, *, stop_after_finish: bool = False) -> float:
        """Simulate from `now` to `until`; returns link-busy seconds. A
        transfer started before `until` runs to completion (TRAIN is never
        preempted; a STATE quantum is all-or-nothing), so `now` may end up
        slightly past `until`.

        With ``stop_after_finish=True`` (the event-clock stepping mode used
        by `LinkTopology.run`) the simulation stops right after the FIRST
        transfer completion and `now` is left at that completion's
        transmission-end instant — not clamped to `until` — so forwarded
        submissions landing at that instant are still in this link's
        future."""
        t = self.now
        busy = 0.0
        finished = False
        pend_t = self._train           # sorted by t_submit (see submit)
        pend_s = self._state
        it = is_ = 0                   # consumed-prefix cursors
        rem_s, rem_bytes = self._rem, self._rem_bytes
        while not finished and t < until and \
                (it < len(pend_t) or is_ < len(pend_s) or rem_s is not None):
            if it < len(pend_t) and pend_t[it].t_submit <= t:
                tr = pend_t[it]        # earliest-submitted ready TRAIN
                it += 1
                tr.t_start = max(t, tr.t_submit)
                dt = tr.size / self.bw
                t = tr.t_start + dt
                busy += dt
                self._finish(tr, tx_end=t)
                finished = stop_after_finish
                continue
            # link idle for TRAIN: advance STATE by one quantum
            nxt_t = pend_t[it].t_submit if it < len(pend_t) else float("inf")
            if rem_s is None and is_ < len(pend_s) and \
                    pend_s[is_].t_submit <= t:
                rem_s = pend_s[is_]
                is_ += 1
                rem_s.t_start = max(t, rem_s.t_submit)
                rem_bytes = rem_s.size
            if rem_s is not None:
                if rem_bytes <= 0:          # zero-byte transfer: instant
                    self._finish(rem_s, tx_end=t)
                    rem_s = None
                    finished = stop_after_finish
                    continue
                chunk = min(self.quantum, rem_bytes)
                dt = chunk / self.bw
                if t + dt > nxt_t:      # TRAIN arrives mid-quantum: yield
                    t = nxt_t           # (aborted quantum is retried later)
                    continue
                t += dt
                busy += dt
                rem_bytes -= chunk
                if rem_bytes <= 0:
                    self._finish(rem_s, tx_end=t)
                    rem_s = None
                    finished = stop_after_finish
                continue
            # nothing runnable: jump to the next submission — but never past
            # the window horizon: a submission at t >= until belongs to a
            # later window, and overshooting the clock to it would delay
            # transfers forwarded onto this link in between (breaking
            # windowed == drained)
            nxt_s = pend_s[is_].t_submit if is_ < len(pend_s) \
                else float("inf")
            nxt = min(nxt_t, nxt_s)
            if nxt >= until:
                break
            t = max(t, nxt)
        del pend_t[:it]                # prune consumed prefixes in one move
        del pend_s[:is_]
        self._rem, self._rem_bytes = rem_s, rem_bytes
        if stop_after_finish or until == float("inf"):
            self.now = t
        else:
            self.now = max(t, until)
        return busy

    def peek_next_finish(self, until: float = float("inf")
                         ) -> Optional[float]:
        """Transmission-end time of the FIRST transfer `run(until)` would
        complete from the current state, or None when no queued transfer
        finishes in the window. Pure dry-run — nothing mutates — mirroring
        `run`'s scheduling decisions exactly, including the stable
        submission-order tie-break the sorted queues encode
        (`tests/test_event_clock.py` asserts the two agree on randomized
        workloads with same-instant submissions). Cursors walk the sorted
        queues in place, so a peek costs only the quanta up to the first
        completion — no copies, no sorting."""
        t = self.now
        pend_t, pend_s = self._train, self._state
        it = is_ = 0                   # heads of the unconsumed queues
        rem = self._rem_bytes if self._rem is not None else None
        while t < until and (it < len(pend_t) or is_ < len(pend_s)
                             or rem is not None):
            if it < len(pend_t) and pend_t[it].t_submit <= t:
                tr = pend_t[it]
                return max(t, tr.t_submit) + tr.size / self.bw
            nxt_t = pend_t[it].t_submit if it < len(pend_t) else float("inf")
            if rem is None and is_ < len(pend_s) and \
                    pend_s[is_].t_submit <= t:
                rem = pend_s[is_].size
                is_ += 1
            if rem is not None:
                if rem <= 0:                # zero-byte transfer: instant
                    return t
                chunk = min(self.quantum, rem)
                dt = chunk / self.bw
                if t + dt > nxt_t:      # TRAIN arrives mid-quantum: yield
                    t = nxt_t
                    continue
                t += dt
                rem -= chunk
                if rem <= 0:
                    return t
                continue
            nxt_s = pend_s[is_].t_submit if is_ < len(pend_s) \
                else float("inf")
            nxt = min(nxt_t, nxt_s)
            if nxt == float("inf"):
                break
            t = max(t, nxt)
        return None

    def drain(self) -> float:
        """Run until every submitted transfer has finished; returns the final
        clock. A single pass: ``run(until=inf)`` processes arrivals in event
        order (aborted quanta retried in place), so the clock lands exactly
        on the last transmission end — no horizon slack to clamp away, and
        nothing to retry, however dense the TRAIN arrivals."""
        self.run(until=float("inf"))
        return self.now


# --------------------------------------------------------------------------- #
# Per-link topology: one LinkScheduler per edge (ISSUE 2 tentpole), grown
# into a hierarchical pod fabric with edge tiers + latency (ISSUE 3)
# --------------------------------------------------------------------------- #
Edge = Tuple[int, int]

# edge tiers: ICI = intra-pod ring link, DCN = inter-pod gateway hop
TIER_ICI = "ici"
TIER_DCN = "dcn"


class RoutingError(RuntimeError):
    """No usable route through the fabric.

    Raised by `LinkTopology.path` / `disjoint_paths` consumers,
    `split_bytes` (no candidate paths) and `least_loaded_edge` (no live
    edges). Subclasses `RuntimeError` so existing probe sites (the
    reliability controller's partition probe, `estimate_stream_seconds`'s
    unreachable guard) keep working, but carries the routing context the
    bare message used to bury in a string:

    * ``src`` / ``dst`` — the requested endpoints (None when the failure
      is not endpoint-specific, e.g. an empty live-edge set),
    * ``dark_nodes`` / ``dark_edges`` — the dark sets at raise time,
      sorted tuples, so handlers can report or react without re-querying
      a topology that may have changed since."""

    def __init__(self, message: str, *, src: Optional[int] = None,
                 dst: Optional[int] = None,
                 dark_nodes: Sequence[int] = (),
                 dark_edges: Sequence[Edge] = ()):
        super().__init__(message)
        self.src = src
        self.dst = dst
        self.dark_nodes: Tuple[int, ...] = tuple(sorted(dark_nodes))
        self.dark_edges: Tuple[Edge, ...] = tuple(sorted(dark_edges))


def edge_key(u: int, v: int) -> Edge:
    """Canonical (undirected) edge identity."""
    return (u, v) if u <= v else (v, u)


@dataclass
class PathTransfer:
    """One item moving hop-by-hop (store-and-forward) along an edge path.

    Duck-types the `Transfer` surface that `StreamTicket` consumes
    (`finished`, `t_finish`, `t_submit`), so transport tickets work unchanged
    whether a chunk crossed one edge or rode a multi-hop recovery path."""
    kind: str
    size: float
    t_submit: float
    path: Tuple[Edge, ...]
    hop: int = 0                       # index of the edge currently in flight
    transfer: Optional[Transfer] = None
    finished: bool = False
    t_finish: float = 0.0

    @property
    def edge(self) -> Optional[Edge]:
        return self.path[self.hop] if self.hop < len(self.path) else None

    @property
    def delivery_edge(self) -> Optional[Edge]:
        """The fabric edge whose far end hands the item to its consumer —
        the LAST hop of the routed path (None for local delivery). This is
        the edge per-edge accounting (e.g. the cluster's instant
        hidden/exposed books) should attribute the delivery to."""
        return self.path[-1] if self.path else None


class LinkTopology:
    """A graph of per-edge `LinkScheduler`s — the cluster fabric.

    * ``kind="ring"``: edge (i, i+1 mod n) for every i — the DP-ring fabric
      the paper's neighbor shards and allreduce actually use.
    * ``kind="full"``: every pair — an idealized fully-connected fabric.
    * `PodFabric` (subclass) builds the hierarchical tier: per-pod ICI rings
      joined by DCN gateway edges.

    Each edge is an independent TRAIN/STATE two-queue scheduler with its own
    bandwidth (bytes/s) and delivery latency (seconds), so contention is
    per-edge instead of uniformly smeared: a saturated hotspot edge delays
    only the streams routed across it. Every edge carries a *tier* tag
    (``TIER_ICI`` / ``TIER_DCN``); a flat topology is all-ICI. A failed
    node's incident edges go dark (``fail_node``) and ``path`` routes around
    them; individual edges can also be failed (``fail_edge``) to force
    multi-hop detours.

    Multi-hop items move store-and-forward: a chunk fully crosses one edge,
    then is submitted on the next at its arrival time (``_pump``). Edges
    advance in cross-edge EVENT ORDER (``run`` processes the globally
    earliest completion first and forwards its next hop at the true arrival
    instant), so a chunk crosses as many hops inside one ``run(until=...)``
    window as its exact schedule allows — windowed timings equal ``drain()``
    timings to float precision."""

    def __init__(self, n: int, bandwidth: float, quantum: float = 1 << 20,
                 kind: str = "ring",
                 edge_bw: Optional[Dict[Edge, float]] = None,
                 latency: float = 0.0,
                 edge_latency: Optional[Dict[Edge, float]] = None):
        assert kind in ("ring", "full"), kind
        assert n >= 1
        self.kind = kind
        if kind == "ring":
            edges = {edge_key(i, (i + 1) % n) for i in range(n)} if n > 1 \
                else set()
        else:
            edges = {(i, j) for i in range(n) for j in range(i + 1, n)}
        self._init_fabric(n, edges, {e: TIER_ICI for e in edges}, bandwidth,
                          quantum, edge_bw, latency, edge_latency)

    def _init_fabric(self, n: int, edges, tiers: Dict[Edge, str],
                     default_bw: float, quantum: float,
                     edge_bw: Optional[Dict[Edge, float]],
                     default_latency: float,
                     edge_latency: Optional[Dict[Edge, float]]) -> None:
        """Shared constructor core: one `LinkScheduler` per edge, with
        per-edge bandwidth (bytes/s), latency (s), and tier tag."""
        self.n = n
        self.default_bw = default_bw
        self.quantum = quantum
        bw = dict(edge_bw or {})
        lat = dict(edge_latency or {})
        self.edge_tier: Dict[Edge, str] = dict(tiers)
        self.links: Dict[Edge, LinkScheduler] = {
            e: LinkScheduler(bw.get(e, default_bw), quantum=quantum,
                             latency=lat.get(e, default_latency))
            for e in sorted(edges)}
        self.dark_nodes: set = set()
        self.dark_edges: set = set()
        # plan compilation (core/plan.py): `compile_plan` switches `run` to
        # the decoupled fast path (exact, skips the global peek/min event
        # loop for edges no pending multi-hop item couples); `_epoch` counts
        # topology-changing events (dark nodes/edges, bandwidth edits) so
        # compiled traffic plans and the BFS routing cache know when their
        # precomputed state went stale
        self.compile_plan = False
        self._epoch = 0
        self._path_cache: Dict[Tuple[int, int], Tuple[Edge, ...]] = {}
        # in-flight multi-hop items, keyed by the identity of the Transfer
        # currently carrying them: the event loop in `run` knows exactly
        # which transfer just finished, so forwarding is an O(1) dict pop
        # instead of a scan over every item in the fabric (keys stay valid:
        # a mapped Transfer is referenced by its PathTransfer, so its id
        # cannot be recycled while mapped)
        self._inflight: Dict[int, PathTransfer] = {}

    # ------------------------- graph queries ------------------------- #
    def edges(self) -> List[Edge]:
        return list(self.links)

    def tier(self, u: int, v: int) -> str:
        """Tier tag of edge (u, v): TIER_ICI or TIER_DCN."""
        return self.edge_tier[edge_key(u, v)]

    def tier_edges(self, tier: str) -> List[Edge]:
        return [e for e, t in self.edge_tier.items() if t == tier]

    def tiers(self) -> List[str]:
        return sorted(set(self.edge_tier.values()))

    def edge(self, u: int, v: int) -> LinkScheduler:
        return self.links[edge_key(u, v)]

    def set_bandwidth(self, u: int, v: int, bandwidth: float) -> None:
        self.links[edge_key(u, v)].bw = bandwidth
        self._bump_epoch()

    def edge_up(self, u: int, v: int) -> bool:
        e = edge_key(u, v)
        return (e in self.links and e not in self.dark_edges
                and u not in self.dark_nodes and v not in self.dark_nodes)

    def live_edges(self) -> List[Edge]:
        return [e for e in self.links if self.edge_up(*e)]

    def neighbors(self, u: int) -> List[int]:
        out = []
        for a, b in self.links:
            if a == u and self.edge_up(a, b):
                out.append(b)
            elif b == u and self.edge_up(a, b):
                out.append(a)
        return sorted(out)

    # ------------------------- failure state ------------------------- #
    @property
    def epoch(self) -> int:
        """Monotone topology-change counter: bumped whenever dark state or
        bandwidth changes. A compiled `TrafficPlan` (core/plan.py) snapshots
        it at compile time and refuses to replay once it diverges; the BFS
        routing cache is dropped on every bump."""
        return self._epoch

    def _bump_epoch(self) -> None:
        self._epoch += 1
        self._path_cache.clear()

    def fail_node(self, wid: int) -> None:
        self.dark_nodes.add(wid)
        self._bump_epoch()

    def restore_node(self, wid: int) -> None:
        self.dark_nodes.discard(wid)
        self._bump_epoch()

    def fail_edge(self, u: int, v: int) -> None:
        self.dark_edges.add(edge_key(u, v))
        self._bump_epoch()

    def restore_edge(self, u: int, v: int) -> None:
        self.dark_edges.discard(edge_key(u, v))
        self._bump_epoch()

    # ------------------------- routing ------------------------- #
    def path(self, src: int, dst: int,
             blocked: Optional[set] = None) -> List[Edge]:
        """Shortest live path src -> dst (BFS), as a list of edges. The
        endpoints are assumed up (a recovering node's pod is created before
        its state streams); intermediate dark nodes/edges are routed around.
        `blocked` adds extra edges to avoid (used for edge-disjoint
        alternate paths).

        Unblocked lookups hit a routing cache keyed (src, dst) that lives
        until the next topology change (`_bump_epoch` clears it), so the
        per-step routes of a steady fabric cost one BFS per epoch instead
        of one per submission."""
        if not blocked:
            hit = self._path_cache.get((src, dst))
            if hit is not None:
                return list(hit)
        p = self._bfs(src, dst, blocked or set())
        if p is None:
            raise RoutingError(
                f"no live path {src} -> {dst} "
                f"(dark nodes {sorted(self.dark_nodes)}, "
                f"dark edges {sorted(self.dark_edges)})",
                src=src, dst=dst, dark_nodes=self.dark_nodes,
                dark_edges=self.dark_edges)
        if not blocked:
            self._path_cache[(src, dst)] = tuple(p)
        return p

    def _bfs(self, src: int, dst: int, blocked: set
             ) -> Optional[List[Edge]]:
        if src == dst:
            return []
        prev: Dict[int, int] = {src: src}
        frontier = [src]
        while frontier and dst not in prev:
            nxt = []
            for u in frontier:
                for a, b in self.links:
                    e = edge_key(a, b)
                    if e in self.dark_edges or e in blocked:
                        continue
                    for x, y in ((a, b), (b, a)):
                        if x != u or y in prev:
                            continue
                        # intermediate nodes must be live; dst itself is
                        # allowed (its pod is up by the time state moves)
                        if y != dst and y in self.dark_nodes:
                            continue
                        if u != src and u in self.dark_nodes:
                            continue
                        prev[y] = u
                        nxt.append(y)
            frontier = nxt
        if dst not in prev:
            return None
        hops = []
        node = dst
        while node != src:
            hops.append(edge_key(prev[node], node))
            node = prev[node]
        return hops[::-1]

    def disjoint_paths(self, src: int, dst: int, k: int = 2
                       ) -> List[List[Edge]]:
        """Up to `k` edge-disjoint live paths src -> dst, shortest first.

        On a ring these are exactly the two directions around it; on a
        `PodFabric` the second path detours the pod-level gateway ring the
        other way, and with `dcn_uplinks > 1` further paths climb the
        slack uplink rings (each pod exposes extra DCN-attached nodes, so
        k=4 cross-pod routing is ICI-fanned across two independent gateway
        rings × two ring directions). Greedy shortest-first with
        accumulated edge blocking; the k-path routing policy splits a
        stream's bytes across the result by residual bandwidth
        (`split_bytes`)."""
        paths: List[List[Edge]] = []
        blocked: set = set()
        for _ in range(max(k, 1)):
            p = self._bfs(src, dst, blocked)
            if p is None:
                break
            paths.append(p)
            if not p:                   # src == dst: nothing to disjoin
                break
            blocked |= set(p)
        return paths

    def split_bytes(self, paths: Sequence[Sequence[Edge]], nbytes: float
                    ) -> List[float]:
        """Divide `nbytes` across `paths` so all directions finish together.

        Each path is modeled as a pipe of rate ``r`` (its bottleneck edge's
        bandwidth, bytes/s) that only starts delivering after an offset ``c``
        (seconds): the worst per-edge queued backlog on the path plus the
        path's summed delivery latency. Water-filling solves
        ``sum_i r_i * max(0, T - c_i) = nbytes`` for the common finish time
        T; the returned byte shares are ``r_i * max(0, T - c_i)``. On an
        idle symmetric ring the two directions get exactly half each — the
        bidirectional split that halves recovery time; over k idle
        equal-rate paths each gets ``nbytes / k``."""
        if not paths:
            raise RoutingError("split_bytes needs at least one path",
                               dark_nodes=self.dark_nodes,
                               dark_edges=self.dark_edges)
        infos = []
        for p in paths:
            if not p:                   # local delivery: infinite rate
                return [nbytes] + [0.0] * (len(paths) - 1)
            r = min(self.links[e].bw for e in p)
            backlog = max(self.links[e].pending_bytes() / self.links[e].bw
                          for e in p)
            lat = sum(self.links[e].latency for e in p)
            infos.append((r, backlog + lat))
        order = sorted(range(len(infos)), key=lambda i: infos[i][1])
        finish = None
        active = 0
        for m in range(1, len(order) + 1):
            rs = sum(infos[i][0] for i in order[:m])
            cs = sum(infos[i][0] * infos[i][1] for i in order[:m])
            t = (nbytes + cs) / rs
            nxt = infos[order[m]][1] if m < len(order) else float("inf")
            if t <= nxt:
                finish, active = t, m
                break
        assert finish is not None
        shares = [0.0] * len(paths)
        for i in order[:active]:
            r, c = infos[i]
            shares[i] = r * max(0.0, finish - c)
        # rounding guard: shares must sum to exactly nbytes
        drift = nbytes - sum(shares)
        shares[order[0]] += drift
        return shares

    def least_loaded_edge(self, kind: Optional[str] = None) -> Edge:
        """The live edge with the least queued *drain seconds*
        (queued bytes / bandwidth; faster edge wins ties) — where full
        checkpoint streams go so they stay off busy training edges. On a
        `PodFabric` this is tier-aware placement: an idle ICI edge beats an
        idle DCN edge, but once the ICI ring is saturated with TRAIN backlog
        the slack DCN tier wins."""
        live = self.live_edges()
        if not live:
            raise RoutingError("no live edges in the topology",
                               dark_nodes=self.dark_nodes,
                               dark_edges=self.dark_edges)
        return min(live, key=lambda e: (
            self.links[e].pending_bytes(kind) / self.links[e].bw,
            1.0 / self.links[e].bw, e))

    # ------------------------- submission ------------------------- #
    def submit_path(self, kind: str, size: float, t: float,
                    path: Sequence[Edge]) -> PathTransfer:
        """Put one `size`-byte item on an edge path at simulation time `t`
        (seconds). Empty path = local delivery."""
        pt = PathTransfer(kind, size, t, tuple(edge_key(*e) for e in path))
        if not pt.path:
            pt.finished = True
            pt.t_finish = t
            return pt
        pt.transfer = self.links[pt.path[0]].submit(kind, size, t)
        self._inflight[id(pt.transfer)] = pt
        return pt

    def cancel_path(self, pt: PathTransfer) -> bool:
        """Withdraw a multi-hop item that has not moved a single byte yet.

        Only valid while the item is still queued (not started) on its
        FIRST hop: once any edge transmitted part of it, those bytes are on
        the wire and the item must run to delivery. Returns True when the
        item was withdrawn (its first-hop transfer dequeued and the
        `_inflight` mapping dropped); False when it is too late. Withdrawal
        is pure queue surgery — no dark/bandwidth state changes — so it
        deliberately does NOT bump the topology epoch and compiled
        `TrafficPlan`s stay valid across a re-balance."""
        if pt.finished or pt.transfer is None or pt.hop != 0:
            return False
        if not self.links[pt.path[0]].cancel(pt.transfer):
            return False
        del self._inflight[id(pt.transfer)]
        pt.transfer = None
        return True

    def submit_train_edge(self, u: int, v: int, nbytes: float, t: float
                          ) -> Transfer:
        return self.edge(u, v).submit("TRAIN", nbytes, t)

    def submit_train_ring(self, nbytes_per_edge: float, t: float
                          ) -> List[Transfer]:
        """One step's ring-allreduce volume, edge by edge: every live edge
        carries 2(n-1)/n of the gradient bytes (`step_traffic`), so TRAIN
        preemption is per-edge instead of smeared over a global link."""
        # simlint: disable=SIM006 -- self.links is built by insertion from
        # sorted(edges) in _init_fabric and never rekeyed, so its iteration
        # order is deterministic; this is the per-step hot path and a
        # sorted() here costs O(E log E) every iteration for nothing.
        return [sch.submit("TRAIN", nbytes_per_edge, t)
                for e, sch in self.links.items() if self.edge_up(*e)]

    def submit_train_tiers(self, tier_bytes: Dict[str, float], t: float
                           ) -> List[Transfer]:
        """One step's hierarchical-allreduce volume: each live edge carries
        its TIER's per-edge wire bytes (`tier_bytes[TIER_ICI]` for the
        intra-pod reduce-scatter + allgather, `tier_bytes[TIER_DCN]` for the
        inter-pod shard allreduce over the gateway ring). Tiers absent from
        `tier_bytes`, or mapped to 0 bytes, submit nothing."""
        out = []
        # simlint: disable=SIM006 -- same deterministic insertion order as
        # submit_train_ring (links built from sorted(edges)); per-step hot
        # path, gated by the fleet-bench wall_s trend.
        for e, sch in self.links.items():
            if not self.edge_up(*e):
                continue
            nbytes = tier_bytes.get(self.edge_tier[e], 0.0)
            if nbytes > 0:
                out.append(sch.submit("TRAIN", nbytes, t))
        return out

    # ------------------------- simulation ------------------------- #
    def _advance(self, pt: PathTransfer) -> Optional[Edge]:
        """One store-and-forward step for an item whose current leg landed:
        submit it on its next edge at the arrival instant (returning that
        edge) or deliver it (returning None). The caller has already
        removed the finished leg's mapping from `_inflight`."""
        pt.hop += 1
        if pt.hop < len(pt.path):
            nxt = pt.path[pt.hop]
            pt.transfer = self.links[nxt].submit(
                pt.kind, pt.size, pt.transfer.t_finish)
            self._inflight[id(pt.transfer)] = pt
            return nxt
        pt.finished = True
        pt.t_finish = pt.transfer.t_finish
        return None

    def _pump(self) -> set:
        """Full-scan fallback of `_advance`: forward every in-flight item
        whose current leg landed (the event loop in `run` forwards each
        completion as it happens; this catches transfers finished by any
        out-of-band `LinkScheduler.run`). Returns the edges that received
        forwarded submissions."""
        touched: set = set()
        for key, pt in list(self._inflight.items()):
            if pt.transfer.finished:
                del self._inflight[key]
                nxt = self._advance(pt)
                if nxt is not None:
                    touched.add(nxt)
        return touched

    @property
    def idle(self) -> bool:
        return not self._inflight and \
            all(sch.idle for sch in self.links.values())

    def pending_bytes(self, kind: Optional[str] = None) -> float:
        return sum(sch.pending_bytes(kind) for sch in self.links.values())

    @property
    def clock(self) -> float:
        return max((sch.now for sch in self.links.values()), default=0.0)

    def run(self, until: float) -> float:
        """Advance the fabric to `until` in cross-edge EVENT ORDER.

        Completions are processed globally earliest-first: the edge whose
        next transfer finishes soonest advances exactly to that completion
        (``stop_after_finish``), the completion's forwarded hop (if any) is
        submitted on its next edge at the true arrival instant, and only
        then is the next-earliest completion considered. Every other edge's
        clock still trails the event frontier at that moment, so a
        forwarded submission is never clamped to a window boundary — a
        multi-hop stream crosses as many hops inside one window as its
        exact store-and-forward schedule allows, and windowed timings equal
        drained timings. Finally each edge coasts to `until` (residual
        STATE quanta, clock advance). Returns total link-busy seconds.

        With `compile_plan` set (FabricConfig(compile_plan=True)) the same
        window runs on the decoupled fast path: only the edges a pending
        multi-hop item still couples go through the global event loop;
        every other edge advances independently in one `LinkScheduler.run`
        call. Cross-edge ordering matters solely for forwarding decisions,
        so the timings are identical (property-tested in
        tests/test_traffic_plan.py) while the O(edges^2) peek/min scan
        drops to O(coupled edges^2 + edges)."""
        if self.compile_plan:
            return self._run_decoupled(until)
        busy = self._run_events(until)
        self._pump()
        return busy

    def _run_decoupled(self, until: float) -> float:
        """Exact window advance without the global event loop: edges in the
        remaining path of some in-flight multi-hop item must still advance
        in cross-edge event order (their completions forward submissions),
        but that closure is usually tiny; the rest of the fabric advances
        edge-by-edge, independently."""
        coupled: set = set()
        for pt in self._inflight.values():
            if pt.hop < len(pt.path) - 1:
                coupled.update(pt.path[pt.hop:])
        busy = 0.0
        if coupled:
            busy += self._run_events(until, coupled)
        for e, sch in self.links.items():
            if e not in coupled:
                busy += sch.run(until)
        self._pump()
        return busy

    def _run_events(self, until: float,
                    edges: Optional[set] = None) -> float:
        """The cross-edge event loop over `edges` (default: every edge):
        process completions globally earliest-first, forwarding each
        finished hop at its true arrival instant, then coast each edge to
        `until`. Forwarded submissions always land inside `edges` — the
        caller passes a closure over the remaining hops of every pending
        multi-hop item (or all edges)."""
        links = self.links if edges is None else \
            {e: self.links[e] for e in edges}
        busy = 0.0
        peek: Dict[Edge, Optional[float]] = {
            e: sch.peek_next_finish(until) for e, sch in links.items()}
        while True:
            nxt = [(t, e) for e, t in peek.items() if t is not None]
            if not nxt:
                break
            _, e = min(nxt)
            sch = links[e]
            before = sch.n_finished
            busy += sch.run(until, stop_after_finish=True)
            if sch.n_finished == before:   # peek promised a completion
                raise RuntimeError(f"event clock stalled on edge {e}")
            peek[e] = sch.peek_next_finish(until)
            # forward the item the completed transfer was carrying (if any)
            # at its exact arrival instant — O(1), no fabric scan
            pt = self._inflight.pop(id(sch.done[-1]), None)
            if pt is not None:
                f = self._advance(pt)
                if f is not None:          # new submission: refresh its peek
                    peek[f] = links[f].peek_next_finish(until)
        for sch in links.values():
            busy += sch.run(until)
        return busy

    def drain(self) -> float:
        """Run until all transfers (and every forwarded hop) land: a single
        event-ordered pass over the queue — `run` with an infinite horizon
        forwards each hop at its exact completion instant, so whole
        multi-hop chains complete in one call and the returned clock is the
        true last-delivery transmission end (no horizon slack, no retry
        rounds)."""
        self.run(until=float("inf"))
        return self.clock


# --------------------------------------------------------------------------- #
# Hierarchical pod fabric: ICI rings × DCN gateway hops (ISSUE 3 tentpole)
# --------------------------------------------------------------------------- #
class PodFabric(LinkTopology):
    """Hierarchical, heterogeneous fabric: `n_pods` pods of `pod_size` nodes.

    Node ``p * pod_size + i`` is node `i` of pod `p`. Inside each pod the
    nodes form an ICI ring at `ici_bw` bytes/s (the fast tier); node 0 of
    each pod is its *gateway*, and the gateways form a pod-level ring of DCN
    edges at `dcn_bw` bytes/s (the slow tier) with per-edge delivery latency
    `dcn_latency` seconds. Cross-pod traffic therefore rides
    ICI -> gateway -> DCN -> gateway -> ICI, store-and-forward, and a
    darkened pod forces DCN detours the other way around the gateway ring.

    ``dcn_uplinks`` provisions extra pod-level rings: uplink ``j`` of pod
    ``p`` is node ``p * pod_size + j * pod_size // dcn_uplinks`` (uplink 0
    is the gateway), and the j-th uplinks of all pods form their own DCN
    ring. The default (1) reproduces the classic single-gateway fabric
    edge-for-edge; with 2 uplink rings a cross-pod stream has up to four
    edge-disjoint paths (two ring directions × two uplink rings), which is
    what k=4 recovery striping rides.

    ``edge_bw`` / ``edge_latency`` override individual edges (hotspots);
    `fail_pod` darkens every node of a pod at once (`inject_storm` drives
    correlated failures from a seed)."""

    def __init__(self, n_pods: int, pod_size: int, ici_bw: float,
                 dcn_bw: float, *, quantum: float = 1 << 20,
                 ici_latency: float = 0.0, dcn_latency: float = 0.0,
                 edge_bw: Optional[Dict[Edge, float]] = None,
                 edge_latency: Optional[Dict[Edge, float]] = None,
                 dcn_uplinks: int = 1):
        assert n_pods >= 1 and pod_size >= 1
        assert dcn_uplinks >= 1
        self.kind = "pods"
        self.n_pods = n_pods
        self.pod_size = pod_size
        self.ici_bw = ici_bw
        self.dcn_bw = dcn_bw
        self.ici_latency = ici_latency
        self.dcn_latency = dcn_latency
        # distinct uplink offsets cap at pod_size (offsets collide beyond)
        self.dcn_uplinks = min(dcn_uplinks, pod_size)
        tiers: Dict[Edge, str] = {}
        for p in range(n_pods):
            base = p * pod_size
            if pod_size > 1:
                for i in range(pod_size if pod_size > 2 else 1):
                    e = edge_key(base + i, base + (i + 1) % pod_size)
                    tiers[e] = TIER_ICI
        if n_pods > 1:
            for j in range(self.dcn_uplinks):
                for p in range(n_pods if n_pods > 2 else 1):
                    e = edge_key(self.uplink(p, j),
                                 self.uplink((p + 1) % n_pods, j))
                    tiers[e] = TIER_DCN
        bw = {e: (ici_bw if t == TIER_ICI else dcn_bw)
              for e, t in tiers.items()}
        bw.update(edge_bw or {})
        lat = {e: (ici_latency if t == TIER_ICI else dcn_latency)
               for e, t in tiers.items()}
        lat.update(edge_latency or {})
        self._init_fabric(n_pods * pod_size, set(tiers), tiers, ici_bw,
                          quantum, bw, 0.0, lat)

    # ------------------------- pod queries ------------------------- #
    def pod_of(self, node: int) -> int:
        return node // self.pod_size

    def pod_nodes(self, pod: int) -> List[int]:
        base = pod * self.pod_size
        return list(range(base, base + self.pod_size))

    def gateway(self, pod: int) -> int:
        """The pod's primary DCN-attached node (node 0 of the pod)."""
        return pod * self.pod_size

    def uplink(self, pod: int, j: int = 0) -> int:
        """The pod's j-th DCN-attached node (uplink 0 is the gateway);
        uplinks are spread evenly around the pod's ICI ring so their DCN
        rings stay edge-disjoint from each other AND from the intra-pod
        hops between them."""
        return pod * self.pod_size + (j * self.pod_size) // self.dcn_uplinks

    # ------------------------- failure state ------------------------- #
    def fail_pod(self, pod: int) -> None:
        """Darken the whole pod: every node (and so every incident ICI and
        DCN edge) goes dark — the correlated failure domain the ByteDance
        robustness report stresses."""
        for node in self.pod_nodes(pod):
            self.fail_node(node)

    def restore_pod(self, pod: int) -> None:
        for node in self.pod_nodes(pod):
            self.restore_node(node)

    def dark_pods(self) -> List[int]:
        """Pods with every node dark."""
        return [p for p in range(self.n_pods)
                if all(n in self.dark_nodes for n in self.pod_nodes(p))]


@dataclass(frozen=True)
class StormReport:
    """What a seeded failure storm darkened."""
    seed: int
    pods: Tuple[int, ...]              # fully-darkened pods
    nodes: Tuple[int, ...]             # every darkened node
    edges: Tuple[Edge, ...]            # extra correlated edge failures


def inject_storm(fabric: LinkTopology, seed: int, *, pods: int = 1,
                 edge_failures: int = 0) -> StormReport:
    """Correlated failure storm, reproducible from `seed`.

    Picks `pods` distinct victim pods (uniformly, without replacement) and
    darkens each whole pod; then fails `edge_failures` extra live edges,
    preferring edges *incident to the victim pods' gateway neighbors* — the
    blast radius of a ToR/fabric event is spatially clustered, so recovery
    traffic must race around the darkened region over the surviving DCN
    hops. On a flat `LinkTopology` (no pods), `pods` is ignored and the
    storm is `edge_failures` clustered edge failures around a random seed
    edge."""
    rng = np.random.default_rng(seed)
    dark_before = set(fabric.dark_nodes)
    hit_pods: List[int] = []
    if isinstance(fabric, PodFabric) and pods > 0:
        avail = [p for p in range(fabric.n_pods)
                 if p not in fabric.dark_pods()]
        take = min(pods, len(avail))
        hit_pods = sorted(int(p) for p in
                          rng.choice(avail, size=take, replace=False))
        for p in hit_pods:
            fabric.fail_pod(p)
    hit_nodes = sorted(set(fabric.dark_nodes) - dark_before)
    # correlated extra edge failures: rank live edges by graph distance to
    # the storm center and knock out the nearest ones
    hit_edges: List[Edge] = []
    live = fabric.live_edges()
    if edge_failures > 0 and live:
        if hit_pods and isinstance(fabric, PodFabric):
            center = {fabric.gateway((p + d) % fabric.n_pods)
                      for p in hit_pods for d in (-1, 1)}
        else:
            seed_edge = live[int(rng.integers(len(live)))]
            center = set(seed_edge)
        def dist(e: Edge) -> Tuple[int, Edge]:
            # modular node distance, so ring-wraparound edges count as
            # close to a blast at the seam
            d = min(min(abs(x - c), fabric.n - abs(x - c))
                    for x in e for c in center) if center else 0
            return (d, e)
        for e in sorted(live, key=dist)[:edge_failures]:
            fabric.fail_edge(*e)
            hit_edges.append(e)
    return StormReport(seed, tuple(hit_pods), tuple(hit_nodes),
                       tuple(hit_edges))


def submit_chunked_path(topo: LinkTopology, kind: str, nbytes: float,
                        t: float, path: Sequence[Edge],
                        quantum: Optional[float] = None) -> List[PathTransfer]:
    """Submit `nbytes` as quantum-sized items along an edge path — the
    per-link analogue of `submit_chunked` (recovery fetches, modeled
    checkpoint volumes)."""
    q = topo.quantum if quantum is None else quantum
    n = max(1, int(np.ceil(nbytes / q))) if nbytes > 0 else 1
    out, left = [], nbytes
    for _ in range(n):
        sz = min(q, left)
        out.append(topo.submit_path(kind, max(sz, 0.0), t, path))
        left -= sz
    return out


def submit_chunked(sched: LinkScheduler, kind: str, nbytes: float, t: float,
                   quantum: Optional[float] = None) -> List[Transfer]:
    """Submit `nbytes` as quantum-sized transfers (last one short); the
    canonical way recovery/checkpoint volumes enter the scheduler."""
    q = sched.quantum if quantum is None else quantum
    n = max(1, int(np.ceil(nbytes / q))) if nbytes > 0 else 1
    out, left = [], nbytes
    for _ in range(n):
        sz = min(q, left)
        out.append(sched.submit(kind, max(sz, 0.0), t))
        left -= sz
    return out


def ring_allreduce_time(size_bytes: float, n: int, bandwidth: float,
                        latency: float = 15e-6, efficiency: float = 1.0
                        ) -> float:
    """Ring allreduce wall time (seconds): `size_bytes` bytes over an
    n-node ring at `bandwidth` bytes/s with per-message `latency` seconds:
    2(n-1)/n * size / (BW*eff) + 2(n-1)*lat."""
    if n <= 1:
        return 0.0
    steps = 2 * (n - 1)
    return (steps / n) * size_bytes / (bandwidth * efficiency) \
        + steps * latency
