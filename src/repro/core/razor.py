"""Checkpoint razor (paper §4.2): classify training state into *unique* and
*redundant* leaves given the parallelism configuration.

Rules (paper's two + our EP/TP generalization, DESIGN.md §6):
  1. dp > 1  =>  bf16 params are redundant (re-castable from the fp32 master,
     and replicated across the DP group anyway).
  2. ZeRO-sharded optimizer leaves (spec mentions the "data" axis) are unique
     per device — they MUST be backed up (12·φ/d bytes for Adam).
  3. TP/EP-sharded-only leaves are unique *per model-parallel rank* but
     replicated across DP — one DP peer suffices, so they're redundant for
     per-iteration backup and persisted lazily at recovery (lazy backup).
  4. dp == 1  =>  everything is unique.

The plan's ``backup_tree``/``backup_specs`` drive the instant (per-iteration)
neighbor backup; ``lazy_tree`` is what DP-rank-0 persists at recovery time.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Tuple

import jax
import numpy as np
from jax.sharding import Mesh
from jax.sharding import PartitionSpec as P

PyTree = Any


def _mentions(spec: P, axis: str) -> bool:
    for part in spec:
        if part == axis:
            return True
        if isinstance(part, (tuple, list)) and axis in part:
            return True
    return False


def _nbytes(leaf) -> int:
    return int(np.prod(leaf.shape)) * jax.dtypes.canonicalize_dtype(leaf.dtype).itemsize


@dataclass
class RazorPlan:
    """Result of razor classification over the full train-state pytree."""
    unique_mask: PyTree          # bool per leaf of opt state: back up per-iter
    dp: int
    unique_bytes: int            # global bytes of unique state (sum of shards)
    redundant_bytes: int         # global bytes of razor-eliminated state
    full_bytes: int              # what a traditional full CKPT would save

    @property
    def unique_bytes_per_device_ring(self) -> int:
        """Bytes each device sends to its DP neighbor per iteration."""
        return self.unique_bytes // max(self.dp, 1)

    @property
    def reduction(self) -> float:
        return 1.0 - self.unique_bytes / max(self.full_bytes, 1)


def razor_plan(opt_specs: PyTree, opt_pspecs: PyTree, param_specs: PyTree,
               mesh: Mesh, *, zero_axis: str = "data") -> RazorPlan:
    dp = mesh.shape[zero_axis] if zero_axis in mesh.axis_names else 1

    def classify(spec_leaf, pspec):
        if dp <= 1:
            return True
        return _mentions(pspec, zero_axis)

    unique_mask = jax.tree.map(classify, opt_specs, opt_pspecs)

    opt_leaves = jax.tree.leaves(opt_specs)
    mask_leaves = jax.tree.leaves(unique_mask)
    unique_bytes = sum(_nbytes(l) for l, m in zip(opt_leaves, mask_leaves) if m)
    redundant_opt = sum(_nbytes(l) for l, m in zip(opt_leaves, mask_leaves)
                        if not m)
    param_bytes = sum(_nbytes(l) for l in jax.tree.leaves(param_specs))
    # A traditional engine persists weights + full optimizer state from EVERY
    # DP replica (the paper's 16 phi per device); the razor keeps exactly one
    # ZeRO-sharded copy of the optimizer state.
    full_bytes = dp * (param_bytes + unique_bytes + redundant_opt)
    return RazorPlan(
        unique_mask=unique_mask,
        dp=dp,
        unique_bytes=unique_bytes,
        redundant_bytes=full_bytes - unique_bytes,
        full_bytes=full_bytes,
    )


def select_unique(tree: PyTree, mask: PyTree) -> PyTree:
    """Subtree of leaves marked unique (others replaced by None and pruned)."""
    pruned = jax.tree.map(lambda x, m: x if m else None, tree, mask)
    return pruned


def razor_bytes_formula(phi: int, dp: int) -> int:
    """Paper's Adam arithmetic: unique bytes per DP group = 12*phi/d per device
    (fp32 master + m + v, each 4 bytes, ZeRO-sharded d ways)."""
    return 12 * phi // max(dp, 1)
