"""Compiled traffic plans — fleet-scale fabric simulation (ROADMAP item).

The event-driven clock in `core/lccl.py` is exact but pays one Python frame
per transfer event; at fleet scale (thousands of edges, multi-day traces)
that is the wall-clock bottleneck. This module compiles a *periodic*
submitted traffic pattern — the per-edge TRAIN allreduce plus STATE stream
chunks one training step puts on every edge (`train/step.py`,
`ckpt/stream.py`) — into a static **TrafficPlan**, the way an op compiler
lowers a graph through scheduling stages:

1. **route**: the pattern is per-edge (routing already resolved via the
   epoch-cached `LinkTopology.path` tables), so the plan only needs the live
   edges and their schedulers.
2. **schedule**: edges are grouped into *classes* by (bandwidth, latency,
   link quantum, submission list). One real `LinkScheduler` simulates a
   single period per class — the template. The template must drain within
   the period (link idle again before the next step's traffic arrives);
   otherwise the pattern is not steady-state and compilation refuses
   (`PlanUnsupported`) so the caller falls back to the exact per-event path.
3. **lower**: N steady-state steps replay as vectorized numpy algebra —
   completion i of step s finishes at ``t0 + s*period + template[i]`` — and
   `apply` advances the schedulers' clocks/counters in O(edges) total,
   batching all same-edge completions instead of walking them one event at
   a time.

Replayed timings match the interpreted event loop to float precision
(`np.testing.assert_allclose(..., rtol=1e-12)`, the same discipline as
`tests/test_event_clock.py`): the only divergence is summation order inside
one period (template sums at base 0, the interpreter accumulates from
``s*period``), a few ulp.

Cache invalidation: a plan snapshots `LinkTopology.epoch` at compile time.
Any topology-changing event (dark node/edge, bandwidth edit — failures,
storms, elastic shrink) bumps the epoch, the plan turns `stale`, and
`apply` refuses to run it. Cross the event on the exact path, then
recompile.

Units follow `core/lccl.py`: bytes, bytes/second, seconds.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.lccl import (TIER_DCN, TIER_ICI, Edge, LinkScheduler,
                             LinkTopology, edge_key)

__all__ = ["PlanUnsupported", "Submission", "TrafficPlan", "PlanReplay",
           "compile_traffic_plan", "steady_state_pattern"]

# one per-period submission on an edge: (kind, nbytes, offset seconds into
# the period). Offsets must lie in [0, period).
Submission = Tuple[str, float, float]


class PlanUnsupported(RuntimeError):
    """The pattern/topology cannot replay as a compiled plan (overcommitted
    period, dark edge in the pattern, stale epoch, mid-flight scheduler
    state). Callers fall back to the exact per-event path."""


@dataclass
class PlanClass:
    """One edge class's compiled single-period template."""
    bw: float
    latency: float
    quantum: float
    subs: Tuple[Submission, ...]
    edges: Tuple[Edge, ...]
    rel_finish: np.ndarray             # delivery times of one period, base 0
    rel_clock: float                   # scheduler clock at period drain
    busy: float                        # link-busy seconds per period
    kinds: Tuple[str, ...]             # completion kinds, template order
    train_bytes: float = 0.0           # TRAIN payload per period
    train_tx: float = 0.0              # TRAIN transmit seconds per period


@dataclass(frozen=True)
class PlanReplay:
    """What one `TrafficPlan.apply` advanced, in aggregate."""
    n_steps: int
    events: int                        # interpreter completions batched away
    busy: float                        # total link-busy seconds
    t_end: float                       # every replayed edge's clock after


class TrafficPlan:
    """A compiled steady-state traffic pattern over a `LinkTopology`.

    Built by `compile_traffic_plan`; valid while `topology.epoch` equals the
    snapshot taken at compile time (`stale` otherwise). `finish_times` gives
    any edge's exact per-completion delivery times over N steps without
    touching the schedulers; `apply` advances the fabric's schedulers by N
    steps in O(edges) — clocks and completion counters move, but the
    individual `Transfer` records are batched away (the `done` lists do not
    materialize; that is the point)."""

    def __init__(self, topology: LinkTopology, period: float,
                 classes: List[PlanClass]):
        self.topology = topology
        self.period = period
        self.classes = classes
        self.epoch = topology.epoch
        self.n_edges = sum(len(c.edges) for c in classes)
        self.events_per_step = sum(
            len(c.rel_finish) * len(c.edges) for c in classes)
        self._class_of: Dict[Edge, PlanClass] = {
            e: c for c in classes for e in c.edges}

    @property
    def stale(self) -> bool:
        """True once the topology changed since compilation (failure, storm,
        restore, bandwidth edit) — the plan must be recompiled."""
        return self.epoch != self.topology.epoch

    def finish_times(self, u: int, v: int, n_steps: int,
                     t0: float = 0.0) -> np.ndarray:
        """Delivery times of every completion on edge (u, v) over `n_steps`
        periods starting at `t0`, in completion order — vectorized:
        ``(t0 + s*period) + template``."""
        c = self._class_of[edge_key(u, v)]
        if len(c.rel_finish) == 0:
            return np.empty((n_steps, 0))
        starts = t0 + self.period * np.arange(n_steps)
        return (starts[:, None] + c.rel_finish[None, :]).reshape(-1)

    def apply(self, n_steps: int, t0: float = 0.0) -> PlanReplay:
        """Advance every planned edge's scheduler by `n_steps` steady-state
        periods starting at `t0`, without per-event work.

        Preconditions (PlanUnsupported otherwise): the plan is not stale,
        and every planned edge's scheduler is idle with its clock at or
        before `t0` — exactly the state the interpreter leaves a
        steady-state edge in at a period boundary. Afterward each scheduler
        sits at ``t0 + n_steps*period`` with `n_finished` advanced by its
        per-period completion count, which is where the exact event loop
        would leave it (the batched `Transfer` records themselves are not
        materialized)."""
        if n_steps <= 0:
            return PlanReplay(0, 0, 0.0, t0)
        if self.stale:
            raise PlanUnsupported(
                f"stale plan: compiled at topology epoch {self.epoch}, "
                f"now {self.topology.epoch} — recompile after the "
                "topology change")
        links = self.topology.links
        for c in self.classes:
            for e in c.edges:
                sch = links[e]
                if not sch.idle or sch.now > t0:
                    raise PlanUnsupported(
                        f"edge {e} is not at a steady-state boundary "
                        f"(idle={sch.idle}, now={sch.now}, t0={t0}); "
                        "drain the fabric on the exact path first")
        t_end = t0 + n_steps * self.period
        busy = 0.0
        events = 0
        for c in self.classes:
            k = len(c.rel_finish)
            for e in c.edges:
                sch = links[e]
                sch.now = t_end
                sch.n_finished += n_steps * k
                sch.train_bytes_done += n_steps * c.train_bytes
                sch.train_tx_seconds += n_steps * c.train_tx
            busy += n_steps * c.busy * len(c.edges)
            events += n_steps * k * len(c.edges)
        return PlanReplay(n_steps, events, busy, t_end)


def compile_traffic_plan(topology: LinkTopology,
                         pattern: Dict[Edge, Sequence[Submission]],
                         period: float) -> TrafficPlan:
    """Compile one step's per-edge traffic into a `TrafficPlan`.

    `pattern` maps each edge to its per-period submissions
    ``(kind, nbytes, offset)``; `period` is the steady-state step length in
    seconds. Edges with identical (bandwidth, latency, quantum, submissions)
    share one simulated template, so a homogeneous 4096-node fabric compiles
    in a handful of `LinkScheduler` runs. Raises `PlanUnsupported` when an
    edge is dark or one period's traffic does not drain within the period
    (the pattern is not steady-state — fall back to the exact path)."""
    if period <= 0:
        raise PlanUnsupported(f"period must be positive, got {period}")
    groups: Dict[Tuple, List[Edge]] = {}
    for e, subs in pattern.items():
        e = edge_key(*e)
        if not topology.edge_up(*e):
            raise PlanUnsupported(f"pattern covers dark edge {e}")
        sch = topology.links[e]
        norm = tuple((str(kind), float(size), float(off))
                     for kind, size, off in subs)
        for kind, size, off in norm:
            if not 0.0 <= off < period:
                raise PlanUnsupported(
                    f"submission offset {off} outside [0, {period}) "
                    f"on edge {e}")
        key = (sch.bw, sch.latency, sch.quantum, norm)
        groups.setdefault(key, []).append(e)
    classes: List[PlanClass] = []
    for (bw, latency, quantum, subs), edges in sorted(groups.items()):
        ref = LinkScheduler(bw, quantum=quantum, latency=latency)
        for kind, size, off in subs:
            ref.submit(kind, size, off)
        busy = ref.run(until=float("inf"))
        if ref.now > period:
            raise PlanUnsupported(
                f"period overcommitted: one period's traffic on edges "
                f"{edges[:3]}{'...' if len(edges) > 3 else ''} drains at "
                f"{ref.now:.6g}s > period {period:.6g}s")
        classes.append(PlanClass(
            bw=bw, latency=latency, quantum=quantum, subs=subs,
            edges=tuple(sorted(edges)),
            rel_finish=np.array([tr.t_finish for tr in ref.done]),
            rel_clock=ref.now, busy=busy,
            kinds=tuple(tr.kind for tr in ref.done),
            train_bytes=ref.train_bytes_done,
            train_tx=ref.train_tx_seconds))
    return TrafficPlan(topology, period, classes)


def steady_state_pattern(fabric: LinkTopology, profile,
                         state_quantum: Optional[float] = None
                         ) -> Dict[Edge, Tuple[Submission, ...]]:
    """The per-edge periodic pattern one training step submits on `fabric`.

    `profile` is a `train/step.py:TrafficProfile` (duck-typed:
    `train_bytes`, `state_bytes`, `dcn_bytes`): every live ICI edge carries
    the intra-pod allreduce volume as TRAIN plus the instant-checkpoint
    shard as quantum-chunked STATE (each worker permutes its shard one ring
    hop, so each ring edge carries exactly one shard per step); every live
    DCN edge carries the inter-pod shard-allreduce volume as TRAIN. All
    submissions land at offset 0, matching `SimCluster.step` /
    `submit_step_traffic`."""
    q = float(state_quantum if state_quantum is not None
              else getattr(fabric, "quantum", 1 << 20))
    pattern: Dict[Edge, Tuple[Submission, ...]] = {}
    for e in fabric.live_edges():
        tier = fabric.edge_tier.get(e, TIER_ICI)
        train = profile.dcn_bytes if tier == TIER_DCN else profile.train_bytes
        subs: List[Submission] = []
        if train > 0:
            subs.append(("TRAIN", float(train), 0.0))
        if tier == TIER_ICI and profile.state_bytes > 0:
            left = float(profile.state_bytes)
            while left > 0:
                subs.append(("STATE", min(q, left), 0.0))
                left -= q
        pattern[e] = tuple(subs)
    return pattern
