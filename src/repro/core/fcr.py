"""Free Checkpointing Ratio (paper §4.2, Eq. 2):

    FCR = s * b * V / (2 * C)   —  free (fully-hidden) CKPT iff FCR >= 1

s: tokens/sequence, b: per-device batch, V: per-device backup-link bandwidth
(bytes/s), C: per-device FLOP/s. Derivation: T_c = 6 s b phi / C must cover
T'_ckpt = 12 phi / V.

On TPU the backup link is one ICI direction (~50 GB/s), vs the paper's
25 GB/s NIC share — the FCR condition is strictly easier to satisfy
(DESIGN.md §2)."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterable, List

from repro.roofline import hw


def fcr(s: float, b: float, v: float, c: float) -> float:
    return (s * b * v) / (2.0 * c)


def is_free(s: float, b: float, v: float, c: float) -> bool:
    return fcr(s, b, v, c) >= 1.0


def tpu_fcr(seq_len: int, global_batch: int, dp: int,
            link_bw: float = hw.ICI_LINK_BW,
            peak_flops: float = hw.PEAK_FLOPS) -> float:
    """FCR for our production mesh: per-device batch = global_batch / dp."""
    return fcr(seq_len, global_batch / dp, link_bw, peak_flops)


@dataclass(frozen=True)
class FcrSample:
    seq_len: int
    batch_per_device: int
    bandwidth: float
    flops: float

    @property
    def value(self) -> float:
        return fcr(self.seq_len, self.batch_per_device, self.bandwidth,
                   self.flops)

    @property
    def free(self) -> bool:
        return self.value >= 1.0


def sweep(seq_lens: Iterable[int], batches: Iterable[int],
          bandwidths: Iterable[float], flops: Iterable[float]
          ) -> List[FcrSample]:
    """Parameter sweep behind the paper's Fig. 9 parallel-coordinates plot."""
    out = []
    for s in seq_lens:
        for b in batches:
            for v in bandwidths:
                for c in flops:
                    out.append(FcrSample(s, b, v, c))
    return out


def _tier_worst_edge(fabric):
    """Yield (tier, bandwidth, latency) for each non-empty tier: the tier's
    minimum edge bandwidth and maximum edge latency — the conservative
    representative both the closed-form and emergent per-tier verdicts are
    judged at, so they can't diverge."""
    for t in fabric.tiers():
        edges = fabric.tier_edges(t)
        if edges:
            yield (t, min(fabric.edge(*e).bw for e in edges),
                   max(fabric.edge(*e).latency for e in edges))


def fcr_per_tier(fabric, s: float, b: float, c: float) -> dict:
    """Closed-form FCR (Eq. 2) per fabric *tier*: for each tier (ICI ring /
    DCN gateway hops of a `PodFabric`) evaluate `fcr` at the tier's worst
    (minimum-bandwidth) edge. A tier's checkpoint traffic is free iff its
    value >= 1, so on a hierarchical fabric the instant checkpoint can be
    free on the ICI tier while the same volume would be exposed on DCN —
    exactly why tier-aware stream placement keeps instant shards on ICI and
    spills only slack-tolerant artifacts to DCN. Eq. 2 has no latency
    term; `fcr_hidden_per_tier` (emergent) accounts for it."""
    return {t: fcr(s, b, v, c) for t, v, _ in _tier_worst_edge(fabric)}


def fcr_hidden_per_tier(fabric, s: float, b: float, c: float,
                        phi: float = 1e9, *, iters: int = 3,
                        quantum: float = 4 << 20,
                        train_traffic=()) -> dict:
    """Per-tier FCR hiding verdict, emergent from the transport: every tier
    is judged by its worst edge's `fcr_hidden_emergent` run, including the
    tier's delivery latency (a DCN chunk lands `latency` seconds after
    transmission ends, so a tier can be exposed even when Eq. 2 says
    free). On an idle zero-latency fabric this reduces exactly to
    ``fcr_per_tier(...) >= 1`` tier by tier (the closed form); with
    `train_traffic` sharing the links, hiding demands genuine surplus on
    that tier."""
    return {t: fcr_hidden_emergent(s, b, v, c, phi, iters=iters,
                                   quantum=quantum, latency=lat,
                                   train_traffic=train_traffic)
            for t, v, lat in _tier_worst_edge(fabric)}


def fcr_hidden_per_edge(topology, s: float, b: float, c: float,
                        phi: float = 1e9, *, iters: int = 3,
                        quantum: float = 4 << 20,
                        train_traffic=(),
                        edge_train_traffic=None) -> dict:
    """Per-edge FCR hiding over a `LinkTopology` ring: every edge carries its
    neighbor-shard STATE chunks at that edge's OWN bandwidth, plus the
    ring-allreduce TRAIN volume every edge sees (`train_traffic`, (t, bytes)
    pairs) and any `edge_train_traffic[{edge}]` extras. Returns
    {edge: hidden?}.

    On a dedicated ring (no TRAIN traffic) each edge's verdict reduces
    exactly to the closed form `is_free(s, b, v_edge, c)` — Eq. 2, but now a
    hotspot or asymmetric edge fails hiding on precisely that edge while the
    rest of the ring stays free."""
    extra = edge_train_traffic or {}
    out = {}
    for e in topology.edges():
        sched = topology.edge(*e)
        traffic = list(train_traffic) + list(extra.get(e, ()))
        out[e] = fcr_hidden_emergent(s, b, sched.bw, c, phi, iters=iters,
                                     quantum=quantum, latency=sched.latency,
                                     train_traffic=traffic)
    return out


def fcr_hidden_emergent(s: float, b: float, v: float, c: float,
                        phi: float = 1e9, *, iters: int = 3,
                        quantum: float = 4 << 20, latency: float = 0.0,
                        train_traffic=()) -> bool:
    """The FCR hiding condition, EMERGENT from the StateStream transport
    instead of Eq. 2: drive each iteration's razor checkpoint (12·φ bytes of
    chunked STATE traffic) through a TRAIN/STATE link scheduler between
    compute boundaries T_c = 6·s·b·φ/C apart, and report whether every
    iteration's chunks drained before the next boundary. `latency`
    (seconds) is the link's delivery latency: the last chunk lands that
    much after its transmission ends, so a high-latency link can be
    exposed even when Eq. 2 says free.

    On a dedicated zero-latency backup link this reduces exactly to
    `is_free` (FCR >= 1); with `train_traffic` sharing the link — (t,
    bytes) pairs — hiding demands genuine surplus capacity, which no
    closed form captures."""
    from repro.core.lccl import LinkScheduler, submit_chunked

    t_c = 6.0 * s * b * phi / c
    ckpt_bytes = 12.0 * phi
    sched = LinkScheduler(v, quantum=min(quantum, max(ckpt_bytes, 1.0)),
                          latency=latency)
    per_iter: List[List] = []
    for i in range(iters):
        per_iter.append(submit_chunked(sched, "STATE", ckpt_bytes, i * t_c))
    for t, nbytes in train_traffic:
        sched.submit("TRAIN", nbytes, t)
    # one exact pass: drain's event-ordered clock records every chunk's true
    # finish instant (windowed advancement would produce identical times),
    # so the per-iteration verdict below reads the exact schedule
    sched.drain()
    eps = 1e-9 * max(t_c, 1.0)
    return all(tr.t_finish <= (i + 1) * t_c + eps
               for i, trs in enumerate(per_iter) for tr in trs)
