"""StateStream — unified chunked checkpoint transport (paper §4.2 + §5.3).

Every checkpoint artifact — instant neighbor shards, full async fallbacks,
lazy backups, recovery fetches — is cut into fixed-size CRC'd quanta
(`StreamChunk`) and scheduled as STATE traffic on the modeled fabric, while
the train loop submits its gradient-allreduce volume as TRAIN traffic.
Preemption, overlap, and the FCR hiding condition then *emerge* from the one
transport model instead of living in three hand-tuned formulas.

Units: chunk/stream sizes are bytes, `quantum` is bytes, all transport
timestamps (`t`, finish times) are seconds on the simulation clock, and
bandwidths inherited from the fabric are bytes/second.

Layers:

  * `ChunkedStream`   — producer: pytree/array -> ordered chunks, per-chunk
                        CRC32, plus the metadata needed to rebuild the pytree.
  * `StreamAssembler` — consumer: accepts chunks in any order, verifies CRCs,
                        dedupes, and reports what is still `missing()` — the
                        basis of resumable partial transfers.
  * `StreamTransport` — binds streams to one shared `LinkScheduler` (the
                        PR-1 single-link model, kept for analytic baselines):
                        each chunk becomes one STATE transfer; finished
                        transfers are pumped into their assemblers; TRAIN
                        traffic submitted through the same object preempts
                        every stream.
  * `TopologyTransport` — the fabric variant: routes each stream onto
                        `LinkTopology` / `PodFabric` edge paths. Neighbor
                        shards ride the adjacent ring edge; recovery fetches
                        split across both ring directions by residual
                        bandwidth (bidirectional routing); lazy backups fan
                        out over the source's incident edges onto whichever
                        tier has slack; full artifacts pick the least-loaded
                        live edge. Contention is per-edge, per-tier — never
                        smeared.

Both transports heal corruption with NACK-driven retransmission: a chunk the
assembler rejects on CRC is re-submitted immediately (alone), instead of
waiting for a full `missing()` resend pass.
"""
from __future__ import annotations

import dataclasses
import math
import zlib
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.lccl import (Edge, LinkScheduler, LinkTopology, PathTransfer,
                             RoutingError, Transfer, edge_key)

PyTree = Any
DEFAULT_QUANTUM = 1 << 20          # 1 MiB — the paper's chunk granularity
_SEP = "|"


# --------------------------------------------------------------------------- #
# Chunk format
# --------------------------------------------------------------------------- #
@dataclass(frozen=True)
class StreamChunk:
    """One transport quantum of a checkpoint artifact."""
    stream_id: str
    seq: int                       # chunk index within the stream
    n_chunks: int
    offset: int                    # byte offset of payload in the artifact
    payload: bytes
    crc: int                       # CRC32 of payload
    total_bytes: int               # artifact size

    @property
    def nbytes(self) -> int:
        return len(self.payload)

    def verify(self) -> bool:
        return zlib.crc32(self.payload) == self.crc

    def manifest_entry(self) -> Dict[str, int]:
        return {"seq": self.seq, "offset": self.offset,
                "nbytes": self.nbytes, "crc": self.crc}


def _leaf_records(tree: PyTree) -> List[Tuple[str, np.ndarray]]:
    import jax
    out = []
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in path)
        out.append((key, np.ascontiguousarray(np.asarray(leaf))))
    return out


class ChunkedStream:
    """A checkpoint artifact cut into CRC'd fixed-size quanta.

    `quantum` is the chunk size in bytes (the last chunk may be short);
    `data` is the serialized artifact. `meta` carries enough layout
    information (leaf key, dtype, shape, byte offset) to rebuild the
    original pytree from the reassembled byte blob.
    """

    def __init__(self, stream_id: str, data: bytes,
                 meta: Optional[List[Tuple[str, str, Tuple[int, ...], int]]]
                 = None, quantum: int = DEFAULT_QUANTUM):
        assert quantum > 0
        self.stream_id = stream_id
        self.meta = meta
        self.quantum = quantum
        self.total_bytes = len(data)
        n = max(1, math.ceil(len(data) / quantum))
        self.chunks: List[StreamChunk] = []
        for i in range(n):
            payload = data[i * quantum:(i + 1) * quantum]
            self.chunks.append(StreamChunk(
                stream_id, i, n, i * quantum, payload,
                zlib.crc32(payload), self.total_bytes))

    @property
    def n_chunks(self) -> int:
        return len(self.chunks)

    def manifest(self) -> Dict[str, Any]:
        return {"stream_id": self.stream_id, "n_chunks": self.n_chunks,
                "total_bytes": self.total_bytes, "quantum": self.quantum,
                "chunks": [c.manifest_entry() for c in self.chunks]}

    # ------------------------- constructors ------------------------- #
    @classmethod
    def from_array(cls, stream_id: str, arr: np.ndarray,
                   quantum: int = DEFAULT_QUANTUM) -> "ChunkedStream":
        arr = np.ascontiguousarray(arr)
        meta = [("", arr.dtype.str, tuple(arr.shape), 0)]
        return cls(stream_id, arr.tobytes(), meta, quantum)

    @classmethod
    def from_pytree(cls, stream_id: str, tree: PyTree,
                    quantum: int = DEFAULT_QUANTUM) -> "ChunkedStream":
        parts, meta, off = [], [], 0
        for key, arr in _leaf_records(tree):
            raw = arr.tobytes()
            meta.append((key, arr.dtype.str, tuple(arr.shape), off))
            parts.append(raw)
            off += len(raw)
        return cls(stream_id, b"".join(parts), meta, quantum)


class StreamAssembler:
    """Receives chunks (any order, possibly across multiple recovery
    attempts), verifies per-chunk CRCs, and rebuilds the artifact. Chunks
    already accepted survive an interrupted transfer — `missing()` is exactly
    what a resumed transfer still has to move."""

    def __init__(self, stream_id: str, n_chunks: int, total_bytes: int,
                 meta=None):
        self.stream_id = stream_id
        self.n_chunks = n_chunks
        self.total_bytes = total_bytes
        self.meta = meta
        self._parts: Dict[int, StreamChunk] = {}
        self.rejected = 0              # CRC failures

    @classmethod
    def for_stream(cls, stream: ChunkedStream) -> "StreamAssembler":
        return cls(stream.stream_id, stream.n_chunks, stream.total_bytes,
                   stream.meta)

    def offer(self, chunk: StreamChunk) -> bool:
        """Accept a chunk; returns True when it was new and CRC-valid."""
        if chunk.stream_id != self.stream_id:
            return False
        if not chunk.verify():
            self.rejected += 1
            return False
        if chunk.seq in self._parts:
            return False               # duplicate (retransmit): drop
        self._parts[chunk.seq] = chunk
        return True

    @property
    def received(self) -> int:
        return len(self._parts)

    @property
    def received_bytes(self) -> int:
        return sum(c.nbytes for c in self._parts.values())

    def missing(self) -> List[int]:
        return [i for i in range(self.n_chunks) if i not in self._parts]

    @property
    def complete(self) -> bool:
        return not self.missing()

    # ------------------------- reassembly ------------------------- #
    def data(self) -> bytes:
        assert self.complete, \
            f"stream {self.stream_id}: {len(self.missing())} chunks missing"
        return b"".join(self._parts[i].payload for i in range(self.n_chunks))

    def to_array(self) -> np.ndarray:
        assert self.meta and len(self.meta) == 1
        _, dt, shape, _ = self.meta[0]
        return np.frombuffer(self.data(), dtype=np.dtype(dt)).reshape(shape)

    def to_flat_dict(self) -> Dict[str, np.ndarray]:
        assert self.meta is not None, "stream carries no pytree metadata"
        blob = self.data()
        out = {}
        for key, dt, shape, off in self.meta:
            dtype = np.dtype(dt)
            n = int(np.prod(shape)) if shape else 1
            arr = np.frombuffer(blob, dtype=dtype, count=n, offset=off)
            out[key] = arr.reshape(shape)
        return out

    def to_pytree(self, like: PyTree) -> PyTree:
        """Rebuild into the structure of `like` (arrays or structs)."""
        import jax
        flat = self.to_flat_dict()
        _, treedef = jax.tree_util.tree_flatten(like)
        keys = [
            _SEP.join(str(getattr(k, "key", getattr(k, "idx", k)))
                      for k in p)
            for p, _ in jax.tree_util.tree_flatten_with_path(like)[0]]
        return jax.tree_util.tree_unflatten(treedef,
                                            [flat[k] for k in keys])


# --------------------------------------------------------------------------- #
# Transport
# --------------------------------------------------------------------------- #
@dataclass
class StreamTicket:
    """Handle for one (possibly partial) stream submission."""
    stream_id: str
    transfers: List[Transfer]
    chunks: List[StreamChunk]
    assembler: Optional[StreamAssembler] = None
    submitted_at: float = 0.0

    @property
    def complete(self) -> bool:
        return all(tr.finished for tr in self.transfers)

    @property
    def finish_time(self) -> Optional[float]:
        """Link-time instant the last chunk landed (None while in flight).
        Exact per hop: the fabric's event-ordered clock forwards and
        finishes each chunk at its true store-and-forward instant, whether
        the window it rode in was ``run(until=...)`` or ``drain()``."""
        if not self.transfers:
            return self.submitted_at
        if not self.complete:
            return None
        return max(tr.t_finish for tr in self.transfers)

    @property
    def delivery_edge(self):
        """The fabric edge that hands this stream to its consumer — the
        last hop of its routed path (`PathTransfer.delivery_edge`). None on
        a single-link transport or for local delivery. Single-path policies
        ("shortest", e.g. instant neighbor shards) put every chunk on the
        same path, so the first routed transfer is authoritative."""
        for tr in self.transfers:
            edge = getattr(tr, "delivery_edge", None)
            if edge is not None:
                return edge
        return None

    @property
    def bytes_moved(self) -> int:
        return sum(c.nbytes for c in self.chunks)


@dataclass
class _PendingChunk:
    """A chunk in flight: its transfer (or multi-hop PathTransfer), payload,
    destination assembler, the ticket it belongs to, and retransmit count."""
    transfer: Any                       # Transfer | PathTransfer
    chunk: StreamChunk
    assembler: Optional[StreamAssembler]
    ticket: Optional[StreamTicket] = None
    attempts: int = 0


@dataclass
class _StripeState:
    """Routing context of one striped (multi-path) stream in flight.

    Kept by `TopologyTransport` for every src+dst split send so the
    transport can re-run the split when the fabric changes under the
    stream: `epoch` is the topology epoch the current chunk allocation was
    computed at — when it trails `topology.epoch`, a `rebalance()` cancels
    the stream's never-started chunks and re-stripes them over the
    surviving paths' residual capacity. `paths` tracks the CURRENT route
    set (refreshed on every re-balance), which is also what NACK
    retransmits pick their least-loaded live path from."""
    ticket: StreamTicket
    src: int
    dst: int
    policy: str
    k: int
    epoch: int
    paths: List[List[Edge]]


class _NackingTransport:
    """Shared delivery + NACK machinery for both transport flavors.

    On delivery, a chunk the assembler rejects on CRC triggers an immediate
    per-chunk retransmit of the pristine payload (`nacks_sent`), bounded by
    `max_retransmits` — chunk-level healing without waiting for a full
    `missing()` resend pass. Byte-flips can be injected for tests via
    `corrupt_once` (the next delivery of that chunk arrives corrupted)."""

    max_retransmits = 8

    def _init_counters(self) -> None:
        self._pending: List[_PendingChunk] = []
        self.streams_sent = 0
        self.train_bytes_submitted = 0.0
        self.state_bytes_submitted = 0.0
        self.chunks_delivered = 0
        self.nacks_sent = 0
        self._corrupt_once: Dict[Tuple[str, int], int] = {}

    def accounting(self) -> Dict[str, float]:
        """Plan-level byte accounting snapshot: what this transport has put
        on the wire so far, by traffic class. Recovery policies diff two
        snapshots around an `execute()` to bill a plan for exactly the
        STATE bytes it streamed (a `ComputeRecovery` bill is zero)."""
        return {
            "train_bytes": float(self.train_bytes_submitted),
            "state_bytes": float(self.state_bytes_submitted),
            "chunks_delivered": float(self.chunks_delivered),
            "nacks_sent": float(self.nacks_sent),
            "streams_sent": float(self.streams_sent),
        }

    def corrupt_once(self, stream_id: str, seq: int, times: int = 1) -> None:
        """Arrange for the next `times` deliveries of (stream_id, seq) to
        arrive with a flipped byte — exercises the CRC-reject -> NACK path
        (and, past `max_retransmits`, the give-up path)."""
        key = (stream_id, seq)
        self._corrupt_once[key] = self._corrupt_once.get(key, 0) + times

    def instant_route(self, wid: int) -> Tuple[Optional[int], Optional[int]]:
        """(src, dst) for worker `wid`'s instant neighbor shard; the plain
        single-link transport has no notion of placement."""
        return None, None

    def _resend(self, pend: "_PendingChunk", t: float) -> None:
        raise NotImplementedError

    def _open_ticket(self, stream: ChunkedStream, t: float,
                     assembler: Optional[StreamAssembler],
                     seqs: Optional[Sequence[int]]
                     ) -> Tuple[List[StreamChunk], StreamTicket]:
        """Resolve the chunk subset (default: what the assembler is still
        missing) and open its ticket. The ticket is retained only while its
        chunks are in flight — holding every ticket (and its payloads) for
        the life of the transport would pin gigabytes over a long run."""
        if seqs is None:
            seqs = (assembler.missing() if assembler is not None
                    else range(stream.n_chunks))
        chunks = [stream.chunks[i] for i in seqs]
        return chunks, StreamTicket(stream.stream_id, [], chunks, assembler,
                                    submitted_at=t)

    def _drain_links(self) -> float:
        raise NotImplementedError

    def _links_idle(self) -> bool:
        raise NotImplementedError

    def drain(self, max_rounds: int = 16) -> float:
        """Run the link(s) until every stream — NACK retransmits and
        multi-hop forwards included — has landed; returns the clock. The
        fabric itself drains in a single event-ordered pass (multi-hop
        chains complete inside one `_drain_links` call); the loop here only
        re-runs for chunks the delivery step re-submitted (CRC-rejected
        NACK resends), so it is bounded by `max_retransmits`."""
        for _ in range(max_rounds):
            t = self._drain_links()
            if self.pump() == 0 and self._links_idle():
                return t
        raise RuntimeError(f"{type(self).__name__}.drain did not converge "
                           "(unbounded retransmission?)")

    def _deliver(self, pend: "_PendingChunk", t: float) -> None:
        """Offer a landed chunk to its assembler; NACK-retransmit on CRC
        rejection."""
        asm = pend.assembler
        if asm is None:
            return
        chunk = pend.chunk
        key = (chunk.stream_id, chunk.seq)
        wire_chunk = chunk
        if self._corrupt_once.get(key, 0) > 0 and chunk.payload:
            self._corrupt_once[key] -= 1
            if self._corrupt_once[key] <= 0:
                del self._corrupt_once[key]
            flipped = bytes([chunk.payload[0] ^ 0xFF]) + chunk.payload[1:]
            wire_chunk = dataclasses.replace(chunk, payload=flipped)
        rejected_before = asm.rejected
        accepted = asm.offer(wire_chunk)
        if accepted or asm.rejected == rejected_before:
            return                      # landed, or duplicate: nothing owed
        if pend.attempts < self.max_retransmits:
            self.nacks_sent += 1
            self._resend(pend, t)

    def pump(self) -> int:
        """Deliver every finished chunk to its assembler (NACK-resending CRC
        rejects)."""
        delivered = 0
        still = []
        for pend in self._pending:
            if pend.transfer.finished:
                self._deliver(pend, pend.transfer.t_finish)
                delivered += 1
            else:
                still.append(pend)
        self._pending = still
        self.chunks_delivered += delivered
        return delivered


class StreamTransport(_NackingTransport):
    """Shared single-link transport. One `LinkScheduler` carries BOTH the
    train loop's allreduce volume (TRAIN, preempting) and every checkpoint
    stream (STATE, chunk-granular). Finished STATE transfers are pumped into
    their stream's assembler, so data delivery and link timing come from the
    same simulation."""

    def __init__(self, scheduler: LinkScheduler):
        self.scheduler = scheduler
        self._init_counters()

    # ------------------------- submission ------------------------- #
    def submit_train(self, nbytes: float, t: float) -> Transfer:
        self.train_bytes_submitted += nbytes
        return self.scheduler.submit("TRAIN", nbytes, t)

    def send(self, stream: ChunkedStream, t: float,
             assembler: Optional[StreamAssembler] = None,
             seqs: Optional[Sequence[int]] = None,
             src: Optional[int] = None, dst: Optional[int] = None,
             policy: str = "split", k: Optional[int] = None) -> StreamTicket:
        """Submit a stream's chunks as STATE traffic at link-time `t`
        (seconds; chunk sizes in bytes).

        `seqs` restricts to a subset of chunk indices — used to resume a
        partial transfer (send only `assembler.missing()`) or to model a
        transfer interrupted after N chunks. `src`/`dst`/`policy`/`k` are
        accepted for interface parity with `TopologyTransport` and ignored
        (one link has no routing)."""
        chunks, ticket = self._open_ticket(stream, t, assembler, seqs)
        for c in chunks:
            tr = self.scheduler.submit("STATE", float(c.nbytes), t)
            ticket.transfers.append(tr)
            self._pending.append(_PendingChunk(tr, c, assembler, ticket))
            self.state_bytes_submitted += c.nbytes
        self.streams_sent += 1
        return ticket

    def _resend(self, pend: _PendingChunk, t: float) -> None:
        tr = self.scheduler.submit("STATE", float(pend.chunk.nbytes), t)
        if pend.ticket is not None:
            pend.ticket.transfers.append(tr)
        self._pending.append(_PendingChunk(tr, pend.chunk, pend.assembler,
                                           pend.ticket, pend.attempts + 1))
        self.state_bytes_submitted += pend.chunk.nbytes

    # ------------------------- progress ------------------------- #
    def pump(self) -> int:
        delivered = super().pump()
        if delivered:
            # prune the scheduler's done-list (a long run finishes millions
            # of chunk transfers; nothing needs them once delivered)
            self.scheduler.done.clear()
        return delivered

    def run(self, until: float) -> float:
        busy = self.scheduler.run(until)
        self.pump()
        return busy

    def _drain_links(self) -> float:
        return self.scheduler.drain()

    def _links_idle(self) -> bool:
        return self.scheduler.idle


class TopologyTransport(_NackingTransport):
    """Per-link transport: streams are routed onto `LinkTopology` /
    `PodFabric` edge paths.

    Routing rules (ISSUE 2, tiered + bidirectional since ISSUE 3, k-path
    striped since ISSUE 10):
      * instant neighbor shards — the adjacent ring edge (`instant_route`,
        ``policy="shortest"``: one hop, nothing to split);
      * recovery fetches (src AND dst given) — split across up to `k`
        edge-disjoint live paths (default ``route_k=2``: both ring
        directions; on a `PodFabric` both ways around the gateway ring, and
        with `dcn_uplinks > 1` up to k=4 over the slack uplink rings) with
        bytes divided by residual bandwidth (`LinkTopology.split_bytes`),
        chunks striped path-by-path in share order;
      * lazy backups (src given, dst None) — split across the source's
        incident live edges by residual bandwidth: the state drains onto
        whichever tier (ICI ring direction or DCN uplink) has slack;
      * full artifacts (no src/dst) — the least-loaded live edge by queued
        drain seconds, tier-aware (a TRAIN-saturated ICI ring loses to an
        idle DCN hop).

    Striped streams additionally RE-BALANCE mid-transfer: every src+dst
    split send records its route set + the topology epoch it was computed
    at (`_StripeState`), and when the fabric changes under an in-flight
    stream — a `set_bandwidth` (gray-link degrade), a reliability-
    controller quarantine (`fail_edge`), any dark-state change — the next
    `run`/`drain` notices the epoch mismatch and `rebalance()` cancels the
    stream's never-started chunks (`LinkTopology.cancel_path`), re-runs
    the split over the surviving paths' residual capacity, and re-submits
    them. Bytes already delivered or on the wire are never re-sent, ticket
    accounting stays exact, and the re-balance itself bumps no epoch, so
    compiled `TrafficPlan`s stay valid.

    TRAIN volume is submitted edge-by-edge (`submit_train` loads every live
    ring edge with the per-edge allreduce bytes; `submit_train_tiers` loads
    each tier with its own hierarchical-allreduce volume), so a hotspot edge
    delays exactly the streams crossing it."""

    def __init__(self, topology: LinkTopology, route_k: int = 2,
                 auto_rebalance: bool = True):
        self.topology = topology
        self.route_k = route_k          # default split width for send/routes
        self.auto_rebalance = auto_rebalance
        self.rebalances = 0             # re-balance passes that moved chunks
        self.chunks_rebalanced = 0      # chunks reassigned across all passes
        self._stripes: List[_StripeState] = []
        self._init_counters()

    # ------------------------- submission ------------------------- #
    def submit_train(self, nbytes_per_edge: float, t: float) -> List[Transfer]:
        trs = self.topology.submit_train_ring(nbytes_per_edge, t)
        self.train_bytes_submitted += nbytes_per_edge * len(trs)
        return trs

    def submit_train_tiers(self, tier_bytes, t: float) -> List[Transfer]:
        """Hierarchical allreduce: per-edge TRAIN bytes by tier
        ({TIER_ICI: ..., TIER_DCN: ...}, bytes per edge)."""
        trs = self.topology.submit_train_tiers(tier_bytes, t)
        self.train_bytes_submitted += sum(tr.size for tr in trs)
        return trs

    def submit_train_edge(self, u: int, v: int, nbytes: float, t: float
                          ) -> Transfer:
        self.train_bytes_submitted += nbytes
        return self.topology.submit_train_edge(u, v, nbytes, t)

    def instant_route(self, wid: int) -> Tuple[int, int]:
        """Worker `wid`'s instant shard arrives from its DP-ring predecessor
        over the adjacent edge."""
        return (wid - 1) % self.topology.n, wid

    def routes(self, src: Optional[int], dst: Optional[int], nbytes: float,
               policy: str = "split", k: Optional[int] = None
               ) -> List[Tuple[List[Edge], float]]:
        """Resolve the edge paths a `nbytes` stream rides and the byte share
        each carries. Returns [(path, share_bytes), ...]; an empty path is
        local delivery. `k` is the routing budget for the split policy —
        the maximum number of edge-disjoint paths to stripe across
        (defaults to the transport's `route_k`); fewer may exist."""
        topo = self.topology
        if k is None:
            k = self.route_k
        if src is not None and dst is not None:
            if src == dst:
                return [([], nbytes)]
            if policy == "shortest":
                return [(topo.path(src, dst), nbytes)]
            paths = topo.disjoint_paths(src, dst, k=k)
            if not paths:
                raise RoutingError(
                    f"no live path {src} -> {dst} "
                    f"(dark nodes {sorted(topo.dark_nodes)}, "
                    f"dark edges {sorted(topo.dark_edges)})",
                    src=src, dst=dst, dark_nodes=topo.dark_nodes,
                    dark_edges=topo.dark_edges)
            shares = topo.split_bytes(paths, nbytes)
            return [(p, s) for p, s in zip(paths, shares) if s > 0] \
                or [(paths[0], nbytes)]
        if src is not None:
            # lazy backup: fan out over the source's incident live edges by
            # residual bandwidth — both ring directions, and on a PodFabric
            # a gateway's DCN uplinks too (tier slack, not topology habit)
            fans = [[edge_key(src, nb)] for nb in topo.neighbors(src)]
            if not fans:
                return [([], nbytes)]   # isolated node: local delivery
            shares = topo.split_bytes(fans, nbytes)
            return [(p, s) for p, s in zip(fans, shares) if s > 0] \
                or [(fans[0], nbytes)]
        if not topo.live_edges():
            return [([], nbytes)]       # single-node fabric: local delivery
        # full artifacts: least queued drain-seconds (TRAIN included), so
        # they stay off busy training edges and off slow tiers
        return [([topo.least_loaded_edge()], nbytes)]

    def send(self, stream: ChunkedStream, t: float,
             assembler: Optional[StreamAssembler] = None,
             seqs: Optional[Sequence[int]] = None,
             src: Optional[int] = None, dst: Optional[int] = None,
             policy: str = "split", k: Optional[int] = None) -> StreamTicket:
        """Submit a stream's chunks as STATE traffic along routed edge paths
        at link-time `t` (seconds).

        With `src`/`dst` the chunks ride up to `k` edge-disjoint live paths
        between the two nodes (store-and-forward per hop; `k` defaults to
        the transport's `route_k`), bytes split by residual bandwidth and
        chunks striped path-by-path; ``policy="shortest"`` forces the
        single BFS path. With only `src`, chunks fan out over its incident
        edges (lazy placement). `seqs` resumes a partial transfer, as in
        `StreamTransport.send`. Striped sends register for mid-transfer
        re-balancing (see class docstring)."""
        chunks, ticket = self._open_ticket(stream, t, assembler, seqs)
        nbytes = float(sum(c.nbytes for c in chunks))
        routed = self.routes(src, dst, nbytes, policy, k)
        self._stripe(chunks, routed, t, assembler, ticket, count_bytes=True)
        if src is not None and dst is not None and src != dst \
                and policy == "split":
            self._stripes.append(_StripeState(
                ticket, src, dst, policy,
                self.route_k if k is None else k, self.topology.epoch,
                [p for p, _ in routed]))
        self.streams_sent += 1
        return ticket

    def _stripe(self, chunks: Sequence[StreamChunk],
                routed: Sequence[Tuple[List[Edge], float]], t: float,
                assembler: Optional[StreamAssembler],
                ticket: StreamTicket, *, count_bytes: bool,
                attempts_by_seq: Optional[Dict[int, int]] = None) -> None:
        """Hand chunks to paths in order, each path taking its byte share.
        `count_bytes=False` replays chunks a re-balance withdrew before
        they moved — already billed at their original submission, so
        re-striping them must not double-count `state_bytes_submitted`
        (`attempts_by_seq` likewise carries their retransmit counts over)."""
        quota = [share for _, share in routed]
        which = 0
        for c in chunks:
            while which < len(routed) - 1 and quota[which] < c.nbytes / 2:
                which += 1
            quota[which] -= c.nbytes
            path = routed[which][0]
            pt = self.topology.submit_path("STATE", float(c.nbytes), t, path)
            ticket.transfers.append(pt)
            if count_bytes:
                self.state_bytes_submitted += c.nbytes
            pend = _PendingChunk(
                pt, c, assembler, ticket,
                attempts_by_seq.get(c.seq, 0) if attempts_by_seq else 0)
            if pt.finished:             # empty path: local, lands instantly
                self._deliver(pend, t)
                self.chunks_delivered += 1
            else:
                self._pending.append(pend)

    # ------------------------- re-balancing ------------------------- #
    def _stripe_of(self, ticket: Optional[StreamTicket]
                   ) -> Optional[_StripeState]:
        for st in self._stripes:
            if st.ticket is ticket:
                return st
        return None

    def _path_load(self, path: Sequence[Edge]) -> float:
        """A path's start offset in split_bytes' model: worst per-edge
        queued drain seconds plus summed delivery latency."""
        topo = self.topology
        return max(topo.links[e].pending_bytes() / topo.links[e].bw
                   for e in path) \
            + sum(topo.links[e].latency for e in path)

    def _maybe_rebalance(self) -> None:
        """Re-balance when the fabric changed under an in-flight striped
        stream — the topology epoch moved past the epoch a stripe's chunk
        allocation was computed at (degrades, quarantines, dark-state
        changes all bump it)."""
        if not (self.auto_rebalance and self._stripes):
            return
        epoch = self.topology.epoch
        if any(st.epoch != epoch for st in self._stripes):
            self.rebalance()

    def rebalance(self, t: Optional[float] = None) -> int:
        """Re-run the k-path split for every striped in-flight stream over
        the CURRENT topology and reassign the chunks that have not started
        moving (withdrawable via `LinkTopology.cancel_path`) — delivered or
        on-the-wire bytes are never re-sent. Re-submission happens at `t`
        (default: the fabric clock, i.e. the instant the change was
        noticed), never before a chunk's original submit time. Returns the
        number of chunks reassigned; cancel/resubmit is pure queue surgery,
        so no topology epoch is bumped and compiled plans stay valid."""
        t_now = self.topology.clock if t is None else t
        moved = 0
        for st in self._stripes:
            moved += self._rebalance_stripe(st, t_now)
        if moved:
            self.rebalances += 1
            self.chunks_rebalanced += moved
        return moved

    def _rebalance_stripe(self, st: _StripeState, t: float) -> int:
        st.epoch = self.topology.epoch
        withdrawn: List[Tuple[_PendingChunk, PathTransfer]] = []
        for pend in self._pending:
            if pend.ticket is st.ticket and \
                    isinstance(pend.transfer, PathTransfer):
                old = pend.transfer
                if self.topology.cancel_path(old):
                    withdrawn.append((pend, old))
        if not withdrawn:
            return 0
        gone_pend = {id(p) for p, _ in withdrawn}
        self._pending = [p for p in self._pending
                         if id(p) not in gone_pend]
        gone_tr = {id(old) for _, old in withdrawn}
        st.ticket.transfers = [tr for tr in st.ticket.transfers
                               if id(tr) not in gone_tr]
        chunks = [p.chunk for p, _ in withdrawn]
        attempts = {p.chunk.seq: p.attempts for p, _ in withdrawn}
        assembler = withdrawn[0][0].assembler
        nbytes = float(sum(c.nbytes for c in chunks))
        # never submit before the chunks' original submit time
        t_sub = max(t, max(old.t_submit for _, old in withdrawn))
        try:
            routed = self.routes(st.src, st.dst, nbytes, st.policy, st.k)
        except RoutingError:
            # destination cut off: put the chunks back on their old paths
            # (they will NACK/stall exactly as the static allocation would)
            for pend, old in withdrawn:
                pt = self.topology.submit_path(
                    "STATE", float(pend.chunk.nbytes),
                    max(t, old.t_submit), old.path)
                st.ticket.transfers.append(pt)
                self._pending.append(_PendingChunk(
                    pt, pend.chunk, pend.assembler, st.ticket,
                    pend.attempts))
            return 0
        st.paths = [p for p, _ in routed]
        self._stripe(chunks, routed, t_sub, assembler, st.ticket,
                     count_bytes=False, attempts_by_seq=attempts)
        return len(chunks)

    def _retransmit_path(self, st: _StripeState,
                         fallback: Tuple[Edge, ...]) -> Sequence[Edge]:
        """The current least-loaded LIVE path of a striped stream's route
        set — where its NACK retransmits go, so resends also benefit from
        re-balancing instead of pinning to the (possibly degraded or
        quarantined) original path."""
        live = [p for p in st.paths
                if p and all(self.topology.edge_up(*e) for e in p)]
        if not live:
            live = [p for p in
                    self.topology.disjoint_paths(st.src, st.dst, st.k) if p]
            if not live:
                return fallback
            st.paths = live
        return min(live, key=lambda p: (self._path_load(p), p))

    def _resend(self, pend: _PendingChunk, t: float) -> None:
        path: Sequence[Edge] = pend.transfer.path \
            if isinstance(pend.transfer, PathTransfer) else ()
        st = self._stripe_of(pend.ticket)
        if st is not None:
            path = self._retransmit_path(st, tuple(path))
        pt = self.topology.submit_path("STATE", float(pend.chunk.nbytes), t,
                                       path)
        if pend.ticket is not None:
            pend.ticket.transfers.append(pt)
        nxt = _PendingChunk(pt, pend.chunk, pend.assembler, pend.ticket,
                            pend.attempts + 1)
        self.state_bytes_submitted += pend.chunk.nbytes
        if pt.finished:
            self._deliver(nxt, t)
            self.chunks_delivered += 1
        else:
            self._pending.append(nxt)

    # ------------------------- progress ------------------------- #
    def pump(self) -> int:
        delivered = super().pump()
        if delivered:
            # prune every edge's done-list (counters survive; a long run
            # finishes millions of chunk transfers nothing needs afterwards)
            for sch in self.topology.links.values():
                sch.done.clear()
            # retire routing state of streams with nothing left in flight
            self._stripes = [st for st in self._stripes
                             if any(p.ticket is st.ticket
                                    for p in self._pending)]
        return delivered

    def run(self, until: float) -> float:
        self._maybe_rebalance()
        busy = self.topology.run(until)
        self.pump()
        return busy

    def _drain_links(self) -> float:
        self._maybe_rebalance()
        return self.topology.drain()

    def _links_idle(self) -> bool:
        return self.topology.idle


def stream_pytree(transport: StreamTransport, stream_id: str, tree: PyTree,
                  t: float, quantum: int = DEFAULT_QUANTUM
                  ) -> Tuple[StreamTicket, StreamAssembler]:
    """Chunk a pytree and put it on the wire; returns (ticket, assembler)."""
    stream = ChunkedStream.from_pytree(stream_id, tree, quantum)
    asm = StreamAssembler.for_stream(stream)
    ticket = transport.send(stream, t, assembler=asm)
    return ticket, asm
