"""StateStream — unified chunked checkpoint transport (paper §4.2 + §5.3).

Every checkpoint artifact — instant neighbor shards, full async fallbacks,
lazy backups, recovery fetches — is cut into fixed-size CRC'd quanta
(`StreamChunk`) and routed through one shared `LinkScheduler` as STATE
traffic, while the train loop submits its gradient-allreduce volume as TRAIN
traffic. Preemption, overlap, and the FCR hiding condition then *emerge* from
the single transport model instead of living in three hand-tuned formulas.

Layers:

  * `ChunkedStream`   — producer: pytree/array -> ordered chunks, per-chunk
                        CRC32, plus the metadata needed to rebuild the pytree.
  * `StreamAssembler` — consumer: accepts chunks in any order, verifies CRCs,
                        dedupes, and reports what is still `missing()` — the
                        basis of resumable partial transfers.
  * `StreamTransport` — binds streams to a shared `LinkScheduler`: each chunk
                        becomes one STATE transfer; finished transfers are
                        pumped into their assemblers; TRAIN traffic submitted
                        through the same object preempts every stream.
"""
from __future__ import annotations

import math
import zlib
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.lccl import LinkScheduler, Transfer

PyTree = Any
DEFAULT_QUANTUM = 1 << 20          # 1 MiB — the paper's chunk granularity
_SEP = "|"


# --------------------------------------------------------------------------- #
# Chunk format
# --------------------------------------------------------------------------- #
@dataclass(frozen=True)
class StreamChunk:
    """One transport quantum of a checkpoint artifact."""
    stream_id: str
    seq: int                       # chunk index within the stream
    n_chunks: int
    offset: int                    # byte offset of payload in the artifact
    payload: bytes
    crc: int                       # CRC32 of payload
    total_bytes: int               # artifact size

    @property
    def nbytes(self) -> int:
        return len(self.payload)

    def verify(self) -> bool:
        return zlib.crc32(self.payload) == self.crc

    def manifest_entry(self) -> Dict[str, int]:
        return {"seq": self.seq, "offset": self.offset,
                "nbytes": self.nbytes, "crc": self.crc}


def _leaf_records(tree: PyTree) -> List[Tuple[str, np.ndarray]]:
    import jax
    out = []
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in path)
        out.append((key, np.ascontiguousarray(np.asarray(leaf))))
    return out


class ChunkedStream:
    """A checkpoint artifact cut into CRC'd fixed-size quanta.

    `meta` carries enough layout information (leaf key, dtype, shape, byte
    offset) to rebuild the original pytree from the reassembled byte blob.
    """

    def __init__(self, stream_id: str, data: bytes,
                 meta: Optional[List[Tuple[str, str, Tuple[int, ...], int]]]
                 = None, quantum: int = DEFAULT_QUANTUM):
        assert quantum > 0
        self.stream_id = stream_id
        self.meta = meta
        self.quantum = quantum
        self.total_bytes = len(data)
        n = max(1, math.ceil(len(data) / quantum))
        self.chunks: List[StreamChunk] = []
        for i in range(n):
            payload = data[i * quantum:(i + 1) * quantum]
            self.chunks.append(StreamChunk(
                stream_id, i, n, i * quantum, payload,
                zlib.crc32(payload), self.total_bytes))

    @property
    def n_chunks(self) -> int:
        return len(self.chunks)

    def manifest(self) -> Dict[str, Any]:
        return {"stream_id": self.stream_id, "n_chunks": self.n_chunks,
                "total_bytes": self.total_bytes, "quantum": self.quantum,
                "chunks": [c.manifest_entry() for c in self.chunks]}

    # ------------------------- constructors ------------------------- #
    @classmethod
    def from_array(cls, stream_id: str, arr: np.ndarray,
                   quantum: int = DEFAULT_QUANTUM) -> "ChunkedStream":
        arr = np.ascontiguousarray(arr)
        meta = [("", arr.dtype.str, tuple(arr.shape), 0)]
        return cls(stream_id, arr.tobytes(), meta, quantum)

    @classmethod
    def from_pytree(cls, stream_id: str, tree: PyTree,
                    quantum: int = DEFAULT_QUANTUM) -> "ChunkedStream":
        parts, meta, off = [], [], 0
        for key, arr in _leaf_records(tree):
            raw = arr.tobytes()
            meta.append((key, arr.dtype.str, tuple(arr.shape), off))
            parts.append(raw)
            off += len(raw)
        return cls(stream_id, b"".join(parts), meta, quantum)


class StreamAssembler:
    """Receives chunks (any order, possibly across multiple recovery
    attempts), verifies per-chunk CRCs, and rebuilds the artifact. Chunks
    already accepted survive an interrupted transfer — `missing()` is exactly
    what a resumed transfer still has to move."""

    def __init__(self, stream_id: str, n_chunks: int, total_bytes: int,
                 meta=None):
        self.stream_id = stream_id
        self.n_chunks = n_chunks
        self.total_bytes = total_bytes
        self.meta = meta
        self._parts: Dict[int, StreamChunk] = {}
        self.rejected = 0              # CRC failures

    @classmethod
    def for_stream(cls, stream: ChunkedStream) -> "StreamAssembler":
        return cls(stream.stream_id, stream.n_chunks, stream.total_bytes,
                   stream.meta)

    def offer(self, chunk: StreamChunk) -> bool:
        """Accept a chunk; returns True when it was new and CRC-valid."""
        if chunk.stream_id != self.stream_id:
            return False
        if not chunk.verify():
            self.rejected += 1
            return False
        if chunk.seq in self._parts:
            return False               # duplicate (retransmit): drop
        self._parts[chunk.seq] = chunk
        return True

    @property
    def received(self) -> int:
        return len(self._parts)

    @property
    def received_bytes(self) -> int:
        return sum(c.nbytes for c in self._parts.values())

    def missing(self) -> List[int]:
        return [i for i in range(self.n_chunks) if i not in self._parts]

    @property
    def complete(self) -> bool:
        return not self.missing()

    # ------------------------- reassembly ------------------------- #
    def data(self) -> bytes:
        assert self.complete, \
            f"stream {self.stream_id}: {len(self.missing())} chunks missing"
        return b"".join(self._parts[i].payload for i in range(self.n_chunks))

    def to_array(self) -> np.ndarray:
        assert self.meta and len(self.meta) == 1
        _, dt, shape, _ = self.meta[0]
        return np.frombuffer(self.data(), dtype=np.dtype(dt)).reshape(shape)

    def to_flat_dict(self) -> Dict[str, np.ndarray]:
        assert self.meta is not None, "stream carries no pytree metadata"
        blob = self.data()
        out = {}
        for key, dt, shape, off in self.meta:
            dtype = np.dtype(dt)
            n = int(np.prod(shape)) if shape else 1
            arr = np.frombuffer(blob, dtype=dtype, count=n, offset=off)
            out[key] = arr.reshape(shape)
        return out

    def to_pytree(self, like: PyTree) -> PyTree:
        """Rebuild into the structure of `like` (arrays or structs)."""
        import jax
        flat = self.to_flat_dict()
        _, treedef = jax.tree_util.tree_flatten(like)
        keys = [
            _SEP.join(str(getattr(k, "key", getattr(k, "idx", k)))
                      for k in p)
            for p, _ in jax.tree_util.tree_flatten_with_path(like)[0]]
        return jax.tree_util.tree_unflatten(treedef,
                                            [flat[k] for k in keys])


# --------------------------------------------------------------------------- #
# Transport
# --------------------------------------------------------------------------- #
@dataclass
class StreamTicket:
    """Handle for one (possibly partial) stream submission."""
    stream_id: str
    transfers: List[Transfer]
    chunks: List[StreamChunk]
    assembler: Optional[StreamAssembler] = None
    submitted_at: float = 0.0

    @property
    def complete(self) -> bool:
        return all(tr.finished for tr in self.transfers)

    @property
    def finish_time(self) -> Optional[float]:
        """Link-time instant the last chunk landed (None while in flight)."""
        if not self.transfers:
            return self.submitted_at
        if not self.complete:
            return None
        return max(tr.t_finish for tr in self.transfers)

    @property
    def bytes_moved(self) -> int:
        return sum(c.nbytes for c in self.chunks)


class StreamTransport:
    """Shared single-link transport. One `LinkScheduler` carries BOTH the
    train loop's allreduce volume (TRAIN, preempting) and every checkpoint
    stream (STATE, chunk-granular). Finished STATE transfers are pumped into
    their stream's assembler, so data delivery and link timing come from the
    same simulation."""

    def __init__(self, scheduler: LinkScheduler):
        self.scheduler = scheduler
        self._pending: List[Tuple[Transfer, StreamChunk,
                                  Optional[StreamAssembler]]] = []
        self.streams_sent = 0
        self.train_bytes_submitted = 0.0
        self.state_bytes_submitted = 0.0
        self.chunks_delivered = 0

    # ------------------------- submission ------------------------- #
    def submit_train(self, nbytes: float, t: float) -> Transfer:
        self.train_bytes_submitted += nbytes
        return self.scheduler.submit("TRAIN", nbytes, t)

    def send(self, stream: ChunkedStream, t: float,
             assembler: Optional[StreamAssembler] = None,
             seqs: Optional[Sequence[int]] = None) -> StreamTicket:
        """Submit a stream's chunks as STATE traffic at link-time `t`.

        `seqs` restricts to a subset of chunk indices — used to resume a
        partial transfer (send only `assembler.missing()`) or to model a
        transfer interrupted after N chunks."""
        if seqs is None:
            seqs = (assembler.missing() if assembler is not None
                    else range(stream.n_chunks))
        chunks = [stream.chunks[i] for i in seqs]
        transfers = []
        for c in chunks:
            tr = self.scheduler.submit("STATE", float(c.nbytes), t)
            transfers.append(tr)
            self._pending.append((tr, c, assembler))
            self.state_bytes_submitted += c.nbytes
        # NOTE: the ticket is returned, not retained — holding every ticket
        # (and its chunk payloads) for the life of the transport would pin
        # gigabytes over a long training run
        self.streams_sent += 1
        return StreamTicket(stream.stream_id, transfers, chunks, assembler,
                            submitted_at=t)

    # ------------------------- progress ------------------------- #
    def pump(self) -> int:
        """Deliver every finished STATE transfer to its assembler, and prune
        the scheduler's done-list (a long run finishes millions of chunk
        transfers; nothing needs them once delivered)."""
        delivered = 0
        still = []
        for tr, chunk, asm in self._pending:
            if tr.finished:
                if asm is not None:
                    asm.offer(chunk)
                delivered += 1
            else:
                still.append((tr, chunk, asm))
        self._pending = still
        self.chunks_delivered += delivered
        if delivered:
            self.scheduler.done.clear()
        return delivered

    def run(self, until: float) -> float:
        busy = self.scheduler.run(until)
        self.pump()
        return busy

    def drain(self) -> float:
        """Run the link until everything has landed; returns the clock."""
        t = self.scheduler.drain()
        self.pump()
        return t


def stream_pytree(transport: StreamTransport, stream_id: str, tree: PyTree,
                  t: float, quantum: int = DEFAULT_QUANTUM
                  ) -> Tuple[StreamTicket, StreamAssembler]:
    """Chunk a pytree and put it on the wire; returns (ticket, assembler)."""
    stream = ChunkedStream.from_pytree(stream_id, tree, quantum)
    asm = StreamAssembler.for_stream(stream)
    ticket = transport.send(stream, t, assembler=asm)
    return ticket, asm
