"""Disk checkpoint shards: pytree <-> .npz with structure-preserving keys,
plus an async background writer (the paper's multi-level insurance persists
full state every ~500 iterations without blocking training)."""
from __future__ import annotations

import json
import queue
import threading
from pathlib import Path
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

PyTree = Any
_SEP = "|"


def _flatten(tree: PyTree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in path)
        flat[key] = np.asarray(leaf)
    return flat


def save_pytree(path: Path, tree: PyTree, meta: Optional[Dict] = None) -> None:
    path = Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    flat = _flatten(tree)
    tmp = path.with_suffix(".tmp.npz")
    np.savez(tmp, **flat)
    tmp.rename(path)                      # atomic-ish publish
    if meta is not None:
        path.with_suffix(".json").write_text(json.dumps(meta))


def load_pytree(path: Path, like: PyTree) -> PyTree:
    """Restore into the structure of `like` (ShapeDtypeStructs or arrays)."""
    data = np.load(Path(path))
    leaves_like, treedef = jax.tree_util.tree_flatten(like)
    flat_paths = [
        _SEP.join(str(getattr(k, "key", getattr(k, "idx", k))) for k in p)
        for p, _ in jax.tree_util.tree_flatten_with_path(like)[0]]
    leaves = [np.asarray(data[k]) for k in flat_paths]
    return jax.tree_util.tree_unflatten(treedef, leaves)


def load_meta(path: Path) -> Optional[Dict]:
    p = Path(path).with_suffix(".json")
    return json.loads(p.read_text()) if p.exists() else None


# ---------------- chunk manifests (StateStream integrity) ---------------- #
def manifest_path(path: Path) -> Path:
    return Path(path).with_suffix(".manifest.json")


def save_manifest(path: Path, manifest: Dict) -> None:
    """Persist a ChunkedStream manifest (per-chunk offsets + CRC32s) next to
    a checkpoint so a partially-fetched restore can verify and resume at
    chunk granularity."""
    p = manifest_path(path)
    p.parent.mkdir(parents=True, exist_ok=True)
    p.write_text(json.dumps(manifest))


def load_manifest(path: Path) -> Optional[Dict]:
    p = manifest_path(path)
    return json.loads(p.read_text()) if p.exists() else None


def verify_manifest(manifest: Dict, data: bytes) -> list:
    """Return the seqs of chunks whose CRC does not match `data` (empty list
    == artifact intact; non-empty == exactly what a resume must re-fetch)."""
    import zlib
    bad = []
    for entry in manifest["chunks"]:
        lo, hi = entry["offset"], entry["offset"] + entry["nbytes"]
        if zlib.crc32(data[lo:hi]) != entry["crc"]:
            bad.append(entry["seq"])
    return bad


class AsyncWriter:
    """Single background thread draining a save queue (bounded, coalescing:
    a newer snapshot for the same tag supersedes a queued older one)."""

    def __init__(self, max_queue: int = 2):
        self._q: "queue.Queue[Optional[Tuple[Path, PyTree, Dict]]]" = \
            queue.Queue(maxsize=max_queue)
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()
        self.saved = 0
        self.errors: list = []

    def submit(self, path: Path, tree: PyTree, meta: Optional[Dict] = None,
               block: bool = False) -> bool:
        item = (Path(path), jax.tree.map(np.asarray, tree), meta or {})
        try:
            self._q.put(item, block=block)
            return True
        except queue.Full:
            return False                   # skip: a save is already in flight

    def _run(self) -> None:
        while True:
            item = self._q.get()
            if item is None:
                self._q.task_done()
                return
            path, tree, meta = item
            try:
                save_pytree(path, tree, meta)
                self.saved += 1
            except Exception as e:         # pragma: no cover
                self.errors.append(e)
            finally:
                self._q.task_done()

    def drain(self) -> None:
        self._q.join()

    def close(self) -> None:
        self._q.put(None)
        self._q.join()
        self._thread.join(timeout=5)
