"""FFTrainer checkpoint engine (paper §4.2): instant neighbor checkpoints +
periodic full async fallback (multi-level insurance).

Host-side view of the in-step collective-permute: after each step the runtime
hands the engine the `backup` pytree (this worker's RAM now holds its DP
*predecessor's* unique shard). The engine keeps the last two versions for
consistency (§4.2) and owns the every-N full async disk checkpoint.

Transport: every artifact the engine produces — instant neighbor shards, full
async fallbacks, lazy backups — is additionally cut into CRC'd quanta and
routed through the `StateStream` transport as STATE traffic (§5.3) when one
is attached, so checkpoint movement competes with (and is preempted by) the
train loop's TRAIN traffic edge by edge on the modeled fabric: instant
shards ride the adjacent ICI ring edge, lazy backups fan out onto whichever
tier has slack, full fallbacks take the least-loaded live edge."""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from pathlib import Path
from typing import Any, Callable, Dict, Optional

import jax
import numpy as np

from repro.ckpt.storage import (AsyncWriter, load_meta, load_pytree,
                                save_manifest, save_pytree)
from repro.ckpt.stream import (DEFAULT_QUANTUM, ChunkedStream, StreamAssembler,
                               StreamTicket, StreamTransport)
from repro.core.consistency import SnapshotKeeper

PyTree = Any


@dataclass
class CkptEngineConfig:
    out_dir: Path = Path("checkpoints")
    full_every: int = 500          # multi-level insurance period
    snapshot_depth: int = 2
    quantum: int = DEFAULT_QUANTUM  # StateStream chunk size
    # routing budget for split-policy streams this engine submits: max
    # edge-disjoint paths to stripe across (None = the transport's route_k)
    route_k: Optional[int] = None


class CkptEngine:
    def __init__(self, cfg: CkptEngineConfig, worker_id: int = 0,
                 transport: Optional[StreamTransport] = None):
        self.cfg = cfg
        self.worker_id = worker_id
        # neighbor redundancy: predecessor's unique shard, two versions
        self.neighbor = SnapshotKeeper(cfg.snapshot_depth)
        # own unique shard (for lazy backup and version rollback)
        self.own = SnapshotKeeper(cfg.snapshot_depth)
        self.writer = AsyncWriter()
        self.transport = transport
        self.instant_count = 0
        self.full_count = 0
        self.streamed_chunks = 0
        self.streamed_bytes = 0
        self.last_instant_ticket: Optional[StreamTicket] = None

    # ---------------- chunk-stream plumbing ---------------- #
    def _stream(self, stream_id: str, tree: PyTree, t: float,
                stream: Optional[ChunkedStream] = None,
                route: str = "any") -> Optional[StreamTicket]:
        """Cut `tree` into CRC'd quanta (or take a prebuilt stream) and put
        it on the transport as STATE traffic at simulation time `t`
        (seconds). No-op (returns None) when no transport is attached.

        `route` picks the edge placement on a fabric transport: "instant"
        rides the adjacent DP-ring edge (predecessor -> this worker, single
        shortest path — one hop, nothing to split); "lazy" fans out over
        this worker's incident live edges by residual bandwidth (the slack
        tier absorbs it); "any" (full artifacts) takes the least-loaded live
        edge. A single-link transport ignores routing."""
        if self.transport is None:
            return None
        if stream is None:
            stream = ChunkedStream.from_pytree(stream_id, tree,
                                               quantum=self.cfg.quantum)
        asm = StreamAssembler.for_stream(stream)
        src = dst = None
        policy = "split"
        if route == "instant":
            src, dst = self.transport.instant_route(self.worker_id)
            policy = "shortest"
        elif route == "lazy":
            src = self.worker_id
        ticket = self.transport.send(stream, t, assembler=asm, src=src,
                                     dst=dst, policy=policy,
                                     k=self.cfg.route_k)
        self.streamed_chunks += stream.n_chunks
        self.streamed_bytes += stream.total_bytes
        return ticket

    def export_stream(self, iteration: int, which: str = "own"
                      ) -> ChunkedStream:
        """Produce the chunk stream for a held snapshot — the recovery-time
        producer side (a healthy holder re-chunks its neighbor copy so a
        newcomer can fetch it, resumably, through the scheduler)."""
        keeper = self.own if which == "own" else self.neighbor
        snap = keeper.get(iteration)
        assert snap is not None, \
            f"worker {self.worker_id}: no {which} snapshot at it {iteration}"
        sid = f"{which}/it{iteration:08d}/w{self.worker_id:05d}"
        return ChunkedStream.from_pytree(sid, snap.state,
                                         quantum=self.cfg.quantum)

    @staticmethod
    def import_stream(assembler: StreamAssembler, like: PyTree) -> PyTree:
        """Consumer side: rebuild a pytree from a (CRC-verified) assembler."""
        return assembler.to_pytree(like)

    # ---------------- instant (per-iteration) path ---------------- #
    def on_step(self, iteration: int, own_unique: PyTree,
                neighbor_backup: Optional[PyTree], *, t: float = 0.0) -> None:
        """Called each iteration with this worker's unique shard and the
        permuted shard received from the DP-ring predecessor."""
        self.own.push(iteration, own_unique)
        if neighbor_backup is None:
            # no instant stream this step: a stale ticket must not be
            # re-counted into the hidden/exposed books
            self.last_instant_ticket = None
        else:
            self.neighbor.push(iteration, neighbor_backup)
            self.instant_count += 1
            self.last_instant_ticket = self._stream(
                f"instant/it{iteration:08d}/w{self.worker_id:05d}",
                neighbor_backup, t, route="instant")

    def newest_version(self) -> int:
        return self.own.latest().iteration if self.own.latest() else -1

    # ---------------- full async fallback ---------------- #
    def maybe_full_checkpoint(self, iteration: int, full_state: PyTree,
                              *, force: bool = False, t: float = 0.0) -> bool:
        if not force and (iteration == 0 or
                          iteration % self.cfg.full_every != 0):
            return False
        path = self._full_path(iteration)
        ok = self.writer.submit(path, full_state,
                                {"iteration": iteration,
                                 "worker": self.worker_id})
        if ok:
            self.full_count += 1
            # the full fallback rides the same link as everything else; its
            # manifest lets a partial restore verify + resume per chunk
            sid = f"full/it{iteration:08d}/w{self.worker_id:05d}"
            stream = ChunkedStream.from_pytree(sid, full_state,
                                               quantum=self.cfg.quantum)
            save_manifest(path, stream.manifest())
            self._stream(sid, full_state, t, stream=stream)
        return ok

    def _full_path(self, iteration: int) -> Path:
        return (Path(self.cfg.out_dir) /
                f"full_it{iteration:08d}_w{self.worker_id:05d}.npz")

    def latest_full(self) -> Optional[int]:
        root = Path(self.cfg.out_dir)
        if not root.exists():
            return None
        its = sorted({int(p.name.split("_")[1][2:])
                      for p in root.glob(f"full_it*_w{self.worker_id:05d}.npz")})
        return its[-1] if its else None

    def restore_full(self, iteration: int, like: PyTree) -> PyTree:
        return load_pytree(self._full_path(iteration), like)

    # ---------------- lazy backup (paper §4.2) ---------------- #
    def lazy_backup(self, iteration: int, redundant_state: PyTree,
                    *, is_dp_rank0: bool, t: float = 0.0) -> Optional[Path]:
        """At recovery time only, DP rank 0 persists the razor-redundant
        state (params) so newcomers can fetch it; others skip (dedupe)."""
        if not is_dp_rank0:
            return None
        path = (Path(self.cfg.out_dir) /
                f"lazy_it{iteration:08d}_w{self.worker_id:05d}.npz")
        save_pytree(path, redundant_state, {"iteration": iteration})
        # the multi-GB redundant state fans out over this worker's incident
        # edges (both ring directions, plus a gateway's DCN uplink) by
        # residual bandwidth — it lands on whichever tier has slack
        self._stream(f"lazy/it{iteration:08d}/w{self.worker_id:05d}",
                     redundant_state, t, route="lazy")
        return path

    def close(self) -> None:
        self.writer.close()
