"""FFTrainer checkpoint engine (paper §4.2): instant neighbor checkpoints +
periodic full async fallback (multi-level insurance).

Host-side view of the in-step collective-permute: after each step the runtime
hands the engine the `backup` pytree (this worker's RAM now holds its DP
*predecessor's* unique shard). The engine keeps the last two versions for
consistency (§4.2) and owns the every-N full async disk checkpoint."""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from pathlib import Path
from typing import Any, Callable, Dict, Optional

import jax
import numpy as np

from repro.ckpt.storage import AsyncWriter, load_meta, load_pytree, save_pytree
from repro.core.consistency import SnapshotKeeper

PyTree = Any


@dataclass
class CkptEngineConfig:
    out_dir: Path = Path("checkpoints")
    full_every: int = 500          # multi-level insurance period
    snapshot_depth: int = 2


class CkptEngine:
    def __init__(self, cfg: CkptEngineConfig, worker_id: int = 0):
        self.cfg = cfg
        self.worker_id = worker_id
        # neighbor redundancy: predecessor's unique shard, two versions
        self.neighbor = SnapshotKeeper(cfg.snapshot_depth)
        # own unique shard (for lazy backup and version rollback)
        self.own = SnapshotKeeper(cfg.snapshot_depth)
        self.writer = AsyncWriter()
        self.instant_count = 0
        self.full_count = 0

    # ---------------- instant (per-iteration) path ---------------- #
    def on_step(self, iteration: int, own_unique: PyTree,
                neighbor_backup: Optional[PyTree]) -> None:
        """Called each iteration with this worker's unique shard and the
        permuted shard received from the DP-ring predecessor."""
        self.own.push(iteration, own_unique)
        if neighbor_backup is not None:
            self.neighbor.push(iteration, neighbor_backup)
            self.instant_count += 1

    def newest_version(self) -> int:
        return self.own.latest().iteration if self.own.latest() else -1

    # ---------------- full async fallback ---------------- #
    def maybe_full_checkpoint(self, iteration: int, full_state: PyTree,
                              *, force: bool = False) -> bool:
        if not force and (iteration == 0 or
                          iteration % self.cfg.full_every != 0):
            return False
        path = self._full_path(iteration)
        ok = self.writer.submit(path, full_state,
                                {"iteration": iteration,
                                 "worker": self.worker_id})
        if ok:
            self.full_count += 1
        return ok

    def _full_path(self, iteration: int) -> Path:
        return (Path(self.cfg.out_dir) /
                f"full_it{iteration:08d}_w{self.worker_id:05d}.npz")

    def latest_full(self) -> Optional[int]:
        root = Path(self.cfg.out_dir)
        if not root.exists():
            return None
        its = sorted({int(p.name.split("_")[1][2:])
                      for p in root.glob(f"full_it*_w{self.worker_id:05d}.npz")})
        return its[-1] if its else None

    def restore_full(self, iteration: int, like: PyTree) -> PyTree:
        return load_pytree(self._full_path(iteration), like)

    # ---------------- lazy backup (paper §4.2) ---------------- #
    def lazy_backup(self, iteration: int, redundant_state: PyTree,
                    *, is_dp_rank0: bool) -> Optional[Path]:
        """At recovery time only, DP rank 0 persists the razor-redundant
        state (params) so newcomers can fetch it; others skip (dedupe)."""
        if not is_dp_rank0:
            return None
        path = (Path(self.cfg.out_dir) /
                f"lazy_it{iteration:08d}_w{self.worker_id:05d}.npz")
        save_pytree(path, redundant_state, {"iteration": iteration})
        return path

    def close(self) -> None:
        self.writer.close()
