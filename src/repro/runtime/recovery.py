"""Pluggable recovery policies for the cluster simulator (paper §5 + the
"All is Not Lost" head-to-head from PAPERS.md).

`SimCluster.recover()` keeps the orchestration legs (detection, replacement
pods, lazy backup) and delegates the *state* leg to a `RecoveryPolicy`:

  plan(cluster, failed, faults)  -> RecoveryPlan     (what moves where, ETA)
  execute(plan)                  -> RecoveryReport   (state rebuilt, timeline)

Three policies ship:

  * `StreamRecovery` — FFTrainer's behavior, carved out of the old
    `SimCluster._recover_from_neighbors` / `_recover_from_full` bodies
    timing-identically: failed workers' shards stream from their DP-ring
    backup holders as chunked STATE traffic over the live fabric, falling
    back to the periodic full checkpoint (with rollback) when the neighbor
    copy died too.
  * `ComputeRecovery` — checkpoint-free: healthy neighbors replay redundant
    compute (train/step.py `ReplayCostModel`) to rebuild the lost shards.
    Costs worker compute-seconds, submits NO STATE traffic, and therefore
    stays viable when `inject_storm` has darkened the cross-pod edges.
  * `HybridRecovery` — per-failed-worker choice by estimated completion
    time: streamable shards race over the fabric while the rest recompute;
    the state leg is the max of the two racing legs.

The optimizer-vector flatten/shard helpers live here too (they are recovery
plumbing); `runtime/cluster.py` re-exports them for back-compat.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import (Any, ClassVar, Dict, List, Optional, Protocol, Sequence,
                    Tuple, Union, runtime_checkable)

import jax
import jax.numpy as jnp
import numpy as np

from repro.ckpt.stream import ChunkedStream, StreamAssembler
from repro.train.step import ReplayCost, ReplayCostModel, replay_compute_cost

PyTree = Any


# --------------------------------------------------------------------------- #
# Optimizer-vector plumbing (moved from runtime/cluster.py)
# --------------------------------------------------------------------------- #
def _flatten_opt(opt: PyTree) -> Tuple[np.ndarray, Any]:
    leaves, treedef = jax.tree_util.tree_flatten(opt)
    vec = np.concatenate([np.asarray(l, np.float32).ravel() for l in leaves])
    shapes = [(l.shape, l.dtype) for l in leaves]
    return vec, (treedef, shapes)


def _unflatten_opt(vec: np.ndarray, meta) -> PyTree:
    treedef, shapes = meta
    leaves, off = [], 0
    for shape, dtype in shapes:
        n = int(np.prod(shape))
        leaves.append(vec[off:off + n].reshape(shape).astype(dtype))
        off += n
    return jax.tree_util.tree_unflatten(treedef, leaves)


def shard_slices(n: int, dp: int) -> List[slice]:
    per = (n + dp - 1) // dp
    return [slice(i * per, min((i + 1) * per, n)) for i in range(dp)]


# --------------------------------------------------------------------------- #
# Fault scripting + typed errors
# --------------------------------------------------------------------------- #
class RecoveryError(RuntimeError):
    """A recovery request the chosen policy cannot honor (e.g. interrupting
    a chunk transfer that the policy never performs)."""


@dataclass(frozen=True)
class FaultScript:
    """What goes wrong DURING recovery (the consolidated form of the old
    `recover(hardware=, interrupt_after_chunks=, corrupt_chunks=)` kwargs).

    `hardware` — the failure lost host RAM too (slower pod creation).
    `interrupt_after_chunks` — a second failure strikes mid-transfer: the
    recovery stream stops after that many chunks; partial chunks are
    retained and the next `recover()` resumes from them.
    `corrupt_chunks` — flip a byte in that many recovery chunks on the wire
    (first missing chunks, stream by stream in worker order); the CRC
    rejects them and the NACK path retransmits.
    `mid_stream_degrade` — ``(u, v, factor)``: while the recovery streams
    are in flight, edge (u, v)'s bandwidth is multiplied by `factor` at
    `degrade_at_s` seconds after the state leg starts (a gray link browning
    out mid-transfer). The transport's k-path re-balancer then reassigns
    the not-yet-started chunks over the surviving paths' residual capacity
    (or the allocation stays static with re-balancing disabled)."""
    hardware: bool = False
    interrupt_after_chunks: Optional[int] = None
    corrupt_chunks: int = 0
    mid_stream_degrade: Optional[Tuple[int, int, float]] = None
    degrade_at_s: float = 0.0


def orchestration_timeline(cluster, faults: FaultScript) -> Dict[str, float]:
    """The recovery legs every policy shares: failure detection and
    replacement-pod creation (hardware pods re-image, §6.2), with
    dependency install pre-pulled away (Table 5).

    The detection leg prefers the cluster's MEASURED latency when its
    reliability loop detected the breakdown on the sim clock
    (`runtime/reliability.py`); the analytic `DetectionTimeline` worst case
    is the fallback for manually scripted inject-then-recover flows."""
    measured = getattr(cluster, "_measured_detection", None)
    detection = (float(measured) if measured is not None
                 else cluster.detection.detection_time())
    return {
        "detection": detection,
        "pod_creation": 7.0 if faults.hardware else 0.5,
        "dependency_install": 0.0,
    }


# --------------------------------------------------------------------------- #
# Reports + plans
# --------------------------------------------------------------------------- #
@dataclass
class RecoveryReport:
    kind: str                          # software | hardware | fallback | interrupted
    recovered_from: str                # neighbor | full_ckpt | neighbor_partial
                                       # | compute_replay | neighbor+compute
    resume_iteration: int
    rolled_back_iterations: int
    timeline: Dict[str, float]
    total_time: float
    elastic_dp: Optional[int] = None
    # StateStream chunk accounting for (partial, resumable) transfers
    chunks_total: int = 0              # chunks the recovery needs overall
    chunks_sent: int = 0               # chunks moved in THIS attempt
    chunks_reused: int = 0             # chunks surviving from a prior attempt
    # policy-level accounting (which resource this recovery spent)
    policy: str = "stream"             # name of the policy that executed
    state_bytes_streamed: float = 0.0  # STATE bytes this recovery put on wire
    compute_seconds: float = 0.0       # replay compute burned (checkpoint-free)
    # wall seconds the chunk streams themselves took on the fabric (the
    # scheduler's finish minus submit) — finer grained than the timeline's
    # network_and_state leg, which is floored by pod-allocation constants,
    # so k-path striping and mid-transfer re-balancing stay visible
    stream_seconds: float = 0.0


@dataclass(frozen=True)
class StreamLeg:
    """One failed worker whose shard streams from its backup holder."""
    wid: int
    holder: Optional[int]
    est_bytes: float
    est_seconds: float


@dataclass(frozen=True)
class ComputeLeg:
    """One failed worker whose shard is rebuilt by replaying compute."""
    wid: int
    replayers: Tuple[int, ...]
    cost: ReplayCost


@dataclass
class RecoveryPlan:
    """A policy's decision for one recovery: which failed worker recovers by
    which mechanism, plus the shared orchestration context. `execute`
    consumes exactly one plan."""
    policy: str                        # planning policy name
    mode: str                          # neighbor | full | compute | mixed
    cluster: Any
    failed: List[int]
    faults: FaultScript
    timeline: Dict[str, float]
    t_start: float
    legs: List[Union[StreamLeg, ComputeLeg]] = field(default_factory=list)
    # routing budget for the stream legs: max edge-disjoint paths each
    # recovery stream stripes across (None = the transport's route_k)
    route_k: Optional[int] = None

    @property
    def stream_legs(self) -> List[StreamLeg]:
        return [l for l in self.legs if isinstance(l, StreamLeg)]

    @property
    def compute_legs(self) -> List[ComputeLeg]:
        return [l for l in self.legs if isinstance(l, ComputeLeg)]

    @property
    def est_state_bytes(self) -> float:
        return float(sum(l.est_bytes for l in self.stream_legs))

    @property
    def est_compute_seconds(self) -> float:
        return float(sum(l.cost.compute_seconds for l in self.compute_legs))


@runtime_checkable
class RecoveryPolicy(Protocol):
    """The pluggable recovery interface: `plan` decides (cheap, no state
    moves), `execute` rebuilds the cluster's training state and returns the
    report. `SimCluster.recover()` calls both in sequence."""
    name: str

    def plan(self, cluster, failed: List[int],
             faults: FaultScript = FaultScript(), *,
             timeline: Optional[Dict[str, float]] = None,
             t_start: Optional[float] = None) -> RecoveryPlan: ...

    def execute(self, plan: RecoveryPlan) -> RecoveryReport: ...


def _plan_context(cluster, faults: FaultScript,
                  timeline: Optional[Dict[str, float]],
                  t_start: Optional[float]
                  ) -> Tuple[Dict[str, float], float]:
    """Default orchestration context for a standalone `plan()` call (recover()
    passes both in explicitly after running the lazy-backup leg)."""
    tl = dict(timeline) if timeline is not None \
        else orchestration_timeline(cluster, faults)
    t0 = t_start if t_start is not None else cluster.sim_time + sum(tl.values())
    return tl, t0


def estimate_stream_seconds(topology, src: Optional[int], dst: int,
                            nbytes: float, k: int = 2) -> float:
    """Idle-fabric ETA for streaming `nbytes` src -> dst over up to `k`
    edge-disjoint live paths (the transport's k-path striped routing):
    per-path bottleneck rates sum, the worst path latency is paid once.
    Used by `HybridRecovery` to race a stream leg against a compute leg
    and by table5 to validate the simulated k-path state leg; returns
    inf when no live path exists (the storm cut the holder off)."""
    if src is None:
        return float("inf")
    if src == dst:
        return 0.0
    try:
        paths = topology.disjoint_paths(src, dst, k=k)
    except Exception:  # noqa: BLE001 - no route == unstreamable
        return float("inf")
    paths = [p for p in paths if p]
    if not paths:
        return float("inf")
    rate, latency = 0.0, 0.0
    for p in paths:
        rate += min(topology.edge(*e).bw for e in p)
        latency = max(latency,
                      sum(topology.edge(*e).latency for e in p))
    return nbytes / max(rate, 1.0) + latency


def _replay_wall(legs: Sequence[ComputeLeg]) -> float:
    """Elapsed replay time for a set of compute legs: each replayer works
    its legs serially, legs with disjoint replayers run in parallel."""
    if not legs:
        return 0.0
    per_replayer: Dict[int, float] = {}
    wall = 0.0
    for leg in legs:
        if not leg.replayers:
            wall = max(wall, leg.cost.wall_seconds)
            continue
        for r in leg.replayers:
            per_replayer[r] = per_replayer.get(r, 0.0) + leg.cost.wall_seconds
    if per_replayer:
        wall = max(wall, max(per_replayer.values()))
    return wall


def _pick_replayers(cluster, wid: int, failed: List[int]) -> Tuple[int, ...]:
    """The healthy ring neighbors that replay for `wid` (paper-adjacent:
    the workers already holding overlapping activations/replicas). Falls
    back to any healthy worker when both neighbors are down."""
    dp = cluster.dp
    down = set(failed)
    nbrs = [(wid - 1) % dp, (wid + 1) % dp]
    picked = tuple(n for n in dict.fromkeys(nbrs)
                   if n != wid and n not in down and cluster.workers[n].alive)
    if picked:
        return picked
    return tuple(w.wid for w in cluster.workers
                 if w.alive and w.wid not in down)[:2]


# --------------------------------------------------------------------------- #
# StreamRecovery — today's behavior, timing-identical
# --------------------------------------------------------------------------- #
@dataclass
class StreamRecovery:
    """FFTrainer's stream-based recovery: chunked STATE traffic from the
    DP-ring backup holders, full-checkpoint fallback when the neighbor copy
    is gone. The execute path is the old `SimCluster._recover_from_*` code,
    moved — timings are bit-identical (pinned in
    tests/test_recovery_policy.py). `route_k` caps how many edge-disjoint
    paths each recovery stream stripes across (None = the transport's
    default, normally 2)."""
    route_k: Optional[int] = None
    name: ClassVar[str] = "stream"

    def _effective_k(self, cluster) -> int:
        return self.route_k if self.route_k is not None \
            else getattr(cluster.transport, "route_k", 2)

    def plan(self, cluster, failed: List[int],
             faults: FaultScript = FaultScript(), *,
             timeline: Optional[Dict[str, float]] = None,
             t_start: Optional[float] = None) -> RecoveryPlan:
        tl, t0 = _plan_context(cluster, faults, timeline, t_start)
        failed = sorted(failed)
        if cluster._recoverable_from_neighbors(failed):
            ldp, old_of, new_of = cluster._shard_layout()
            nbytes = cluster.shard_nbytes()
            k = self._effective_k(cluster)
            legs: List[Union[StreamLeg, ComputeLeg]] = []
            for wid in failed:
                holder = new_of[(old_of[wid] + 1) % ldp]
                legs.append(StreamLeg(
                    wid, holder, nbytes,
                    estimate_stream_seconds(cluster.topology, holder, wid,
                                            nbytes, k=k)))
            return RecoveryPlan(self.name, "neighbor", cluster, failed,
                                faults, tl, t0, legs, route_k=self.route_k)
        if faults.interrupt_after_chunks is not None:
            raise RecoveryError(
                "interrupt_after_chunks models a failure mid neighbor-"
                "stream; this recovery fell back to the full checkpoint "
                "(no resumable chunk transfer to interrupt)")
        return RecoveryPlan(self.name, "full", cluster, failed, faults,
                            tl, t0)

    def execute(self, plan: RecoveryPlan) -> RecoveryReport:
        if plan.mode == "full":
            return _execute_full(plan)
        return _execute_neighbor_streams(
            plan, stream_wids=[l.wid for l in plan.stream_legs])


# --------------------------------------------------------------------------- #
# ComputeRecovery — checkpoint-free, zero fabric bytes
# --------------------------------------------------------------------------- #
@dataclass
class ComputeRecovery:
    """Checkpoint-free recovery: healthy ring neighbors replay redundant
    compute to rebuild every failed worker's shard at the modeled
    `ReplayCostModel.recompute_rate`. Submits NO STATE traffic, so a
    storm-darkened DCN does not slow it down — the cost lands on the
    replayers' compute budget instead (`RecoveryReport.compute_seconds`).
    Rebuilds the CURRENT iteration's state (the replayers still hold it),
    so there is never a rollback — including the adjacent-double-hardware
    case where stream recovery must fall back to an old full checkpoint."""
    cost_model: ReplayCostModel = field(default_factory=ReplayCostModel)
    name: ClassVar[str] = "compute"

    def plan(self, cluster, failed: List[int],
             faults: FaultScript = FaultScript(), *,
             timeline: Optional[Dict[str, float]] = None,
             t_start: Optional[float] = None) -> RecoveryPlan:
        if faults.interrupt_after_chunks is not None:
            raise RecoveryError(
                "interrupt_after_chunks models a failure mid neighbor-"
                "stream; compute-based recovery replays compute and has no "
                "chunk transfer to interrupt")
        if faults.corrupt_chunks:
            raise RecoveryError(
                "corrupt_chunks corrupts recovery chunks on the wire; "
                "compute-based recovery streams no chunks")
        if faults.mid_stream_degrade is not None:
            raise RecoveryError(
                "mid_stream_degrade browns out an edge under an in-flight "
                "recovery stream; compute-based recovery streams no chunks")
        tl, t0 = _plan_context(cluster, faults, timeline, t_start)
        failed = sorted(failed)
        nbytes = cluster.shard_nbytes()
        legs: List[Union[StreamLeg, ComputeLeg]] = []
        for wid in failed:
            replayers = _pick_replayers(cluster, wid, failed)
            legs.append(ComputeLeg(wid, replayers, replay_compute_cost(
                nbytes, n_replayers=max(len(replayers), 1),
                model=self.cost_model)))
        return RecoveryPlan(self.name, "compute", cluster, failed, faults,
                            tl, t0, legs)

    def execute(self, plan: RecoveryPlan) -> RecoveryReport:
        cluster = plan.cluster
        wall = _replay_wall(plan.compute_legs)
        timeline = plan.timeline
        timeline["replay_compute"] = wall
        cluster.sim_time = max(cluster.sim_time, plan.t_start + wall)
        # the replayers reconstruct the shard the failed worker held at the
        # CURRENT iteration — the simulator's state tree is already the
        # global truth, so recovery is a no-op on data and a pure cost on
        # time: zero rollback, zero fabric bytes
        total = sum(timeline.values())
        return RecoveryReport(
            "hardware" if plan.faults.hardware else "software",
            "compute_replay", cluster.iteration, 0, timeline, total,
            policy=self.name, state_bytes_streamed=0.0,
            compute_seconds=plan.est_compute_seconds)


# --------------------------------------------------------------------------- #
# HybridRecovery — per-failed-worker race: fabric vs compute
# --------------------------------------------------------------------------- #
@dataclass
class HybridRecovery:
    """Per-failed-worker choice by estimated completion time: a shard whose
    backup holder is reachable over a fast live path streams; one whose
    stream ETA loses to the replay ETA (or whose backup died with it)
    recomputes. The state leg is the slower of the two racing legs — both
    run concurrently. `route_k` caps how many edge-disjoint paths each
    stream leg stripes across (None = the transport's default); the
    stream-vs-compute race uses the SAME k for its ETA, so a wider routing
    budget honestly tilts the race toward streaming."""
    cost_model: ReplayCostModel = field(default_factory=ReplayCostModel)
    route_k: Optional[int] = None
    name: ClassVar[str] = "hybrid"

    def _effective_k(self, cluster) -> int:
        return self.route_k if self.route_k is not None \
            else getattr(cluster.transport, "route_k", 2)

    def plan(self, cluster, failed: List[int],
             faults: FaultScript = FaultScript(), *,
             timeline: Optional[Dict[str, float]] = None,
             t_start: Optional[float] = None) -> RecoveryPlan:
        if faults.interrupt_after_chunks is not None:
            raise RecoveryError(
                "interrupt_after_chunks is only meaningful for the pure "
                "stream policy (hybrid legs race; use StreamRecovery to "
                "model a mid-transfer interruption)")
        tl, t0 = _plan_context(cluster, faults, timeline, t_start)
        failed = sorted(failed)
        ldp, old_of, new_of = cluster._shard_layout()
        nbytes = cluster.shard_nbytes()
        k = self._effective_k(cluster)
        legs: List[Union[StreamLeg, ComputeLeg]] = []
        for wid in failed:
            o = old_of[wid]
            kind, _src = cluster._slice_source(o, ldp, new_of)
            holder = new_of[(o + 1) % ldp] if kind != "none" else None
            est_stream = estimate_stream_seconds(cluster.topology, holder,
                                                 wid, nbytes, k=k)
            replayers = _pick_replayers(cluster, wid, failed)
            cost = replay_compute_cost(nbytes,
                                       n_replayers=max(len(replayers), 1),
                                       model=self.cost_model)
            if est_stream <= cost.wall_seconds:
                legs.append(StreamLeg(wid, holder, nbytes, est_stream))
            else:
                legs.append(ComputeLeg(wid, replayers, cost))
        return RecoveryPlan(self.name, "mixed", cluster, failed, faults,
                            tl, t0, legs, route_k=self.route_k)

    def execute(self, plan: RecoveryPlan) -> RecoveryReport:
        return _execute_neighbor_streams(
            plan, stream_wids=[l.wid for l in plan.stream_legs],
            compute_legs=plan.compute_legs)


_POLICIES = {
    "stream": StreamRecovery,
    "compute": ComputeRecovery,
    "hybrid": HybridRecovery,
}


def resolve_policy(spec: Union[str, RecoveryPolicy, None]) -> RecoveryPolicy:
    """Coerce a policy spec — None (default stream), a name, or an already-
    built policy instance — into a RecoveryPolicy."""
    if spec is None:
        return StreamRecovery()
    if isinstance(spec, str):
        try:
            return _POLICIES[spec]()
        except KeyError:
            raise ValueError(
                f"unknown recovery policy {spec!r}; "
                f"choose from {sorted(_POLICIES)}") from None
    if callable(getattr(spec, "plan", None)) and \
            callable(getattr(spec, "execute", None)):
        return spec
    raise TypeError(f"not a RecoveryPolicy: {spec!r}")


# --------------------------------------------------------------------------- #
# Execution machinery (the old SimCluster._recover_from_* bodies)
# --------------------------------------------------------------------------- #
def _execute_neighbor_streams(plan: RecoveryPlan, stream_wids: List[int],
                              compute_legs: Sequence[ComputeLeg] = ()
                              ) -> RecoveryReport:
    """Move `stream_wids`' shards as chunked STATE traffic from their
    backup holders (verbatim from the old `_recover_from_neighbors`), with
    optional concurrent `compute_legs` racing the streams (hybrid). With no
    compute legs the timings are bit-identical to the pre-refactor path."""
    cluster = plan.cluster
    timeline = plan.timeline
    faults = plan.faults
    acct0 = cluster.transport.accounting()["state_bytes"]
    compute_wids = {l.wid for l in compute_legs}
    ldp, old_of, new_of = cluster._shard_layout()
    # consistency: earliest globally-available version (§4.2), over the
    # snapshot layout's shard slices. Slices that a compute leg rebuilds
    # need no surviving snapshot — replay reconstructs the CURRENT state.
    versions = {}
    for o in range(ldp):
        kind, src_wid = cluster._slice_source(o, ldp, new_of)
        if kind == "none":
            assert new_of.get(o) in compute_wids, \
                f"layout slice {o} has no source and no compute leg"
            continue
        keeper = (cluster.workers[src_wid].engine.own if kind == "own"
                  else cluster.workers[src_wid].engine.neighbor)
        versions[o] = keeper.latest().iteration
    target = min(versions.values()) if versions else cluster.iteration
    if compute_wids:
        # replay rebuilds current-iteration state; mixing it with a
        # rolled-back stream target would splice two iterations
        assert target == cluster.iteration, \
            "hybrid compute legs need the stream target at the current " \
            "iteration (no snapshot rollback to splice against)"
    rolled = cluster.iteration - target
    # drop partial transfers aimed at a version we no longer want
    cluster._pending_recovery = {k: v for k, v in
                                 cluster._pending_recovery.items()
                                 if k[1] == target}

    # ---- move the failed workers' shards as chunked STATE traffic ----
    # each stream rides the shortest LIVE edge path holder -> newcomer:
    # adjacent edge normally, multi-hop around dark nodes/edges otherwise
    t0 = plan.t_start
    chunks_total = chunks_sent = chunks_reused = 0
    tickets, inflight = [], {}
    budget = faults.interrupt_after_chunks
    corrupt_left = faults.corrupt_chunks
    interrupted = False
    for wid in sorted(stream_wids):
        holder_wid = new_of[(old_of[wid] + 1) % ldp]
        holder = cluster.workers[holder_wid]
        key = (wid, target)
        if key in cluster._pending_recovery:
            stream, asm = cluster._pending_recovery[key]
            chunks_reused += asm.received
        else:
            stream = holder.engine.export_stream(target, which="neighbor")
            asm = StreamAssembler.for_stream(stream)
            cluster._pending_recovery[key] = (stream, asm)
        chunks_total += stream.n_chunks
        missing = asm.missing()
        take = missing
        if budget is not None:
            take = missing[:max(budget - chunks_sent, 0)]
            if len(take) < len(missing):
                interrupted = True
        # wire corruption: the CRC rejects these on delivery and the
        # NACK path retransmits each one immediately
        for seq in take[:corrupt_left]:
            cluster.transport.corrupt_once(stream.stream_id, seq)
        corrupt_left -= min(corrupt_left, len(take))
        if take:
            tickets.append(cluster.transport.send(
                stream, t0, assembler=asm, seqs=take,
                src=holder_wid, dst=wid, k=plan.route_k))
            chunks_sent += len(take)
        inflight[wid] = (stream, asm)
    if faults.mid_stream_degrade is not None and tickets:
        # a gray link browns out UNDER the in-flight streams: run the
        # fabric to the degrade instant, apply it (epoch bump), and let the
        # drain's entry check re-balance the not-yet-started chunks over
        # the surviving paths' residual capacity
        u, v, factor = faults.mid_stream_degrade
        cluster.transport.run(until=t0 + max(float(faults.degrade_at_s),
                                             0.0))
        cluster.degrade_edge(int(u), int(v), float(factor))
    cluster.transport.drain()
    bytes_streamed = cluster.transport.accounting()["state_bytes"] - acct0

    if interrupted:
        # the second failure struck mid-transfer: time (and the link
        # clock) advance to where the partial transfer stopped, so the
        # resumed recovery does NOT re-pay this attempt's transfer time
        finish = max([tk.finish_time for tk in tickets
                      if tk.finish_time is not None], default=t0)
        cluster.sim_time = max(cluster.sim_time, finish)
        timeline["network_and_state"] = finish - t0
        total = sum(timeline.values())
        return RecoveryReport("interrupted", "neighbor_partial", target,
                              0, timeline, total,
                              chunks_total=chunks_total,
                              chunks_sent=chunks_sent,
                              chunks_reused=chunks_reused,
                              policy=plan.policy,
                              state_bytes_streamed=bytes_streamed,
                              stream_seconds=finish - t0)

    # ---- every stream landed: rebuild the optimizer vector, slice by
    # slice of the SNAPSHOT layout (which differs from the live
    # numbering only across an elastic shrink) ----
    vec, meta = _flatten_opt(cluster.state["opt"])
    slices = shard_slices(len(vec), ldp)
    for o in range(ldp):
        owner = new_of.get(o)
        if owner is not None and owner in inflight:
            stream, asm = inflight[owner]
            # NACK retransmission heals CRC rejects in-stream, so
            # `rejected > 0` is fine as long as assembly completed
            assert asm.complete, \
                f"stream {stream.stream_id} incomplete"
            vec[slices[o]] = asm.to_flat_dict()["shard"]
            cluster._pending_recovery.pop((owner, target), None)
        elif owner is not None and owner in compute_wids:
            # replay leg: the replayers rebuild this slice at the current
            # iteration — the simulator vector already holds the truth, so
            # the slice stands as-is (zero fabric bytes moved for it)
            continue
        else:
            kind, src_wid = cluster._slice_source(o, ldp, new_of)
            keeper = (cluster.workers[src_wid].engine.own if kind == "own"
                      else cluster.workers[src_wid].engine.neighbor)
            snap = keeper.get(target)
            assert snap is not None, \
                f"version {target} missing for layout slice {o}"
            vec[slices[o]] = snap.state["shard"]
    cluster._layout = None         # live numbering is authoritative again
    new_opt = _unflatten_opt(vec, meta)
    params = jax.tree.map(
        lambda m, p: jnp.asarray(m).astype(p.dtype),
        new_opt["master"], cluster.state["params"])
    cluster.state = {"step": jnp.asarray(target, jnp.int32),
                     "params": params, "opt": jax.tree.map(jnp.asarray,
                                                           new_opt)}
    cluster.iteration = target

    # timeline: network recovery overlaps state loading (§5.2); the
    # state leg is the SCHEDULER's finish time for the recovery chunks,
    # so TRAIN traffic sharing the link delays recovery emergently. A
    # concurrent replay leg (hybrid) races the streams: the state leg is
    # whichever finishes last.
    n = cluster.dp
    t_net = 0.5 + 0.001 * n
    finish = max([tk.finish_time for tk in tickets if tk.finish_time
                  is not None], default=t0)
    replay_wall = _replay_wall(compute_legs)
    cluster.sim_time = max(cluster.sim_time, finish, t0 + replay_wall)
    t_state = (finish - t0) + 0.2 if stream_wids else 0.0
    timeline["network_and_state"] = max(t_net, t_state, replay_wall)
    total = sum(timeline.values())
    if compute_legs and inflight:
        source = "neighbor+compute"
    elif compute_legs:
        source = "compute_replay"
    else:
        source = "neighbor"
    return RecoveryReport("hardware" if faults.hardware else "software",
                          source, target, rolled, timeline, total,
                          chunks_total=chunks_total,
                          chunks_sent=chunks_sent,
                          chunks_reused=chunks_reused,
                          policy=plan.policy,
                          state_bytes_streamed=bytes_streamed,
                          compute_seconds=float(sum(
                              l.cost.compute_seconds for l in compute_legs)),
                          stream_seconds=finish - t0 if stream_wids else 0.0)


def _execute_full(plan: RecoveryPlan) -> RecoveryReport:
    """Restore from the periodic full checkpoint with rollback (verbatim
    from the old `_recover_from_full`)."""
    cluster = plan.cluster
    timeline = plan.timeline
    eng0 = cluster.workers[0].engine
    eng0.writer.drain()
    it = eng0.latest_full()
    assert it is not None, "no full checkpoint available (insurance gap)"
    like = jax.tree.map(lambda x: np.asarray(x), cluster.state)
    restored = eng0.restore_full(it, like)

    # integrity: re-chunk the restored artifact and check it against the
    # per-chunk CRC manifest written at save time
    from repro.ckpt.storage import load_manifest, verify_manifest
    manifest = load_manifest(eng0._full_path(it))
    chunks_total = 0
    if manifest is not None:
        stream = ChunkedStream.from_pytree(
            manifest["stream_id"], restored,
            quantum=int(manifest.get("quantum", cluster.quantum)))
        blob = b"".join(c.payload for c in stream.chunks)
        bad = verify_manifest(manifest, blob)
        assert not bad, f"full ckpt it{it}: corrupt chunks {bad}"
        chunks_total = stream.n_chunks

    cluster.state = jax.tree.map(jnp.asarray, restored)
    rolled = cluster.iteration - it
    cluster.iteration = it
    full_bytes = sum(np.asarray(l).nbytes
                     for l in jax.tree.leaves(restored))
    # serial reload from storage, still through the link model
    from repro.runtime.failover import FailoverCosts, schedule_state_phase
    t_state = 1.0 + schedule_state_phase(full_bytes,
                                         FailoverCosts().storage_bw,
                                         quantum=max(full_bytes, 1.0))
    timeline["network_and_state"] = max(0.5 + 0.001 * cluster.dp, t_state)
    total = sum(timeline.values())
    return RecoveryReport("fallback", "full_ckpt", it, rolled,
                          timeline, total, chunks_total=chunks_total,
                          chunks_sent=chunks_total, policy=plan.policy)
