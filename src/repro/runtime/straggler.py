"""Straggler detection & mitigation.

Synchronous SPMD training runs at the pace of the slowest worker. The
controller tracks per-worker step-time EWMAs; a worker persistently slower
than the cluster median by `threshold` is flagged, and mitigation migrates
its role to a spare (same path as failover, minus state loss — the straggler
itself provides its unique shard)."""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np


@dataclass
class StragglerPolicy:
    ewma_alpha: float = 0.2
    threshold: float = 1.5            # x median step time
    min_observations: int = 5


class StragglerDetector:
    def __init__(self, n_workers: int, policy: Optional[StragglerPolicy] = None):
        # default built per-instance: a shared StragglerPolicy() default
        # would alias tuning across every detector in the process
        self.policy = policy if policy is not None else StragglerPolicy()
        self.ewma = np.zeros(n_workers)
        self.count = np.zeros(n_workers, dtype=np.int64)

    def observe(self, worker: int, step_time: float) -> None:
        a = self.policy.ewma_alpha
        if self.count[worker] == 0:
            self.ewma[worker] = step_time
        else:
            self.ewma[worker] = a * step_time + (1 - a) * self.ewma[worker]
        self.count[worker] += 1

    def stragglers(self) -> List[int]:
        ready = self.count >= self.policy.min_observations
        if not ready.any():
            return []
        med = float(np.median(self.ewma[ready]))
        if med <= 0:
            return []
        flag = ready & (self.ewma > self.policy.threshold * med)
        return list(np.flatnonzero(flag))

    def cluster_step_time(self) -> float:
        """Synchronous step time = max over workers (what mitigation saves)."""
        ready = self.count > 0
        return float(self.ewma[ready].max()) if ready.any() else 0.0


def mitigation_speedup(step_times: np.ndarray, straggler_factor: float
                       ) -> float:
    """Expected step-time improvement from migrating the straggler away.

    `step_times` are the healthy per-worker baselines; the straggler runs at
    `straggler_factor` x the slowest of them. After migration the cluster
    paces at the max over the *remaining* workers — the straggler's own
    (inflated) time must not appear in the denominator.
    """
    base = np.sort(np.asarray(step_times, dtype=float))
    with_straggler = base[-1] * straggler_factor
    rest = base[:-1]
    without = rest[-1] if rest.size else with_straggler
    return with_straggler / max(without, 1e-9)
