"""Failover timeline orchestration (paper Fig. 1, Table 5).

Models both flows over the same recovery steps:
  serial (PyTorch/Gemini-style):   detect -> pod -> deps -> network -> state
  FFTrainer (overlapped):          detect -> pod (pre-pulled) ->
                                   max(network-recovery, state-load)   [§5.2]
plus lazy backup running in parallel with pod creation (§4.2).

The state-movement phase is no longer a closed-form `bytes / bandwidth`
constant: it is *derived from a LinkScheduler run*. Recovery state moves as
chunk-granular STATE traffic through the TRAIN/STATE two-queue link model
(§5.3), so concurrent TRAIN traffic (healthy DP groups resuming their
allreduce) preempts recovery chunks and delays the timeline exactly as it
would on the wire. Pass a `LinkTopology` + edge `path` and the state leg is
scheduled per-edge instead: recovery rides a (possibly multi-hop) path of
per-link schedulers while the allreduce loads every ring edge, so a single
hotspot edge bottlenecks the timeline by exactly its residual bandwidth.

On a hierarchical `PodFabric` the state leg can also be scheduled across
SEVERAL edge-disjoint paths at once (`paths=`): the bytes are water-filled
over up to k paths by residual bandwidth (`LinkTopology.split_bytes`) —
both ring directions, both ways around the DCN gateway ring past a darkened
pod, and any extra `dcn_uplinks` gateway rings — so the timeline's state
leg is the k paths' combined residual capacity, and cross-pod recovery is
bounded by the aggregate DCN bandwidth plus the per-hop delivery latency.
Pass `topology.disjoint_paths(src, dst, k=k)` to reproduce exactly what the
live transport stripes over (`TopologyTransport(route_k=k)`).

Orchestration steps we can only model (Docker pulls, pod scheduling) keep the
paper's measured Table 5 values; connection building is calibrated on our
lock-free init (fig8)."""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Iterable, Optional, Sequence, Tuple

from repro.core.detection import DetectionTimeline
from repro.core.lccl import (Edge, LinkScheduler, LinkTopology,
                             submit_chunked, submit_chunked_path)

# (t_submit_seconds, bytes) pairs of TRAIN traffic sharing the link
TrainTraffic = Sequence[Tuple[float, float]]


@dataclass(frozen=True)
class FailoverCosts:
    # paper Table 5 measured values (seconds)
    detection_baseline: float = 15.0
    pod_creation_baseline: float = 392.0
    dependency_baseline: float = 421.0
    detection_fft: float = 6.0
    pod_creation_fft: float = 7.0
    dependency_fft: float = 0.0
    # bandwidths for state movement (bytes/s)
    neighbor_bw: float = 50e9          # ICI link (instant ckpt fetch)
    storage_bw: float = 1e9            # remote storage (baseline reload)
    dcn_bw: float = 5e9                # inter-pod gateway hop (cross-pod)
    dcn_latency: float = 1e-3          # per-DCN-hop delivery latency (s)
    # network-recovery scaling (calibrated on our lock-free init, fig8)
    conn_base: float = 0.5
    conn_per_worker: float = 0.001
    conn_per_worker_baseline: float = 0.08
    # state-movement constants: link ramp (instant) / storage handshake
    state_ramp_fft: float = 0.2
    state_ramp_baseline: float = 2.0
    quantum: float = 4 << 20           # STATE preemption granularity


def schedule_state_phase(state_bytes: float, bandwidth: float, *,
                         quantum: float = 4 << 20,
                         train_traffic: TrainTraffic = (),
                         t0: float = 0.0,
                         scheduler: Optional[LinkScheduler] = None,
                         topology: Optional[LinkTopology] = None,
                         path: Optional[Sequence[Edge]] = None,
                         paths: Optional[Sequence[Sequence[Edge]]] = None
                         ) -> float:
    """Wall seconds to move `state_bytes` (bytes) of recovery state through
    a TRAIN/STATE link scheduler at `bandwidth` bytes/s, chunked at
    `quantum` granularity (bytes).

    Any `train_traffic` submitted on the same link preempts the recovery
    chunks — the returned duration grows by exactly the schedule the link
    model produces, not by a hand-tuned contention factor.

    With a `topology` (and an edge `path` through it), the recovery chunks
    move store-and-forward along the path's per-edge schedulers while the
    TRAIN traffic loads EVERY ring edge (the healthy groups' allreduce) —
    the timeline then derives from per-edge contention, and a single hotspot
    edge on the path bottlenecks recovery by exactly its residual bandwidth.
    Per-edge delivery latency accrues per hop, so a DCN detour pays its
    latency on every gateway crossing.

    `paths` (up to k edge-disjoint paths) enables k-path striping: the
    volume is water-filled across the paths by residual bandwidth
    (`LinkTopology.split_bytes`), so on an idle symmetric ring both
    directions carry half and the state leg halves; with k=4 disjoint
    DCN routes an idle cross-pod leg quarters (minus per-hop latency and
    pipeline-fill, which the per-edge schedulers model exactly).

    The returned duration is exact: the fabric clock is event-ordered, so
    `drain()` is a single pass that forwards every hop at its true arrival
    instant — the timeline derives from one window with no horizon slack
    (and, equivalently, would be identical measured through `run(until=)`
    windows)."""
    if topology is not None:
        routes = [list(p) for p in paths] if paths else \
            ([list(path)] if path else None)
        assert routes, "per-link scheduling needs an edge path (or paths)"
        shares = topology.split_bytes(routes, state_bytes) \
            if len(routes) > 1 else [state_bytes]
        pts = []
        for p, share in zip(routes, shares):
            if share <= 0:
                continue
            pts += submit_chunked_path(topology, "STATE", share, t0, p,
                                       quantum)
        for t, nbytes in train_traffic:
            topology.submit_train_ring(nbytes, t)
        topology.drain()
        return max(pt.t_finish for pt in pts) - t0
    sched = scheduler or LinkScheduler(bandwidth, quantum=quantum)
    chunks = submit_chunked(sched, "STATE", state_bytes, t0, quantum)
    for t, nbytes in train_traffic:
        sched.submit("TRAIN", nbytes, t)
    sched.drain()
    return max(tr.t_finish for tr in chunks) - t0


def fftrainer_timeline(n_workers: int, state_bytes_per_worker: float,
                       costs: FailoverCosts = FailoverCosts(),
                       detection: Optional[DetectionTimeline] = None,
                       train_traffic: TrainTraffic = (),
                       scheduler: Optional[LinkScheduler] = None,
                       topology: Optional[LinkTopology] = None,
                       path: Optional[Sequence[Edge]] = None,
                       paths: Optional[Sequence[Sequence[Edge]]] = None
                       ) -> Dict[str, float]:
    detection = detection if detection is not None else DetectionTimeline()
    t_net = costs.conn_base + costs.conn_per_worker * n_workers
    t_state = costs.state_ramp_fft + schedule_state_phase(
        state_bytes_per_worker, costs.neighbor_bw, quantum=costs.quantum,
        train_traffic=train_traffic, scheduler=scheduler,
        topology=topology, path=path, paths=paths)
    tl = {
        # lower-bounded by our measured heartbeat path; paper measured 6 s
        "detection": max(detection.detection_time(), costs.detection_fft),
        "pod_creation": costs.pod_creation_fft,
        "dependency_install": costs.dependency_fft,
        # role/rank decoupling overlaps the two (§5.2); the state leg comes
        # from the scheduler run above, so TRAIN preemption surfaces here
        "network_and_state": max(t_net, t_state),
    }
    tl["total"] = sum(v for k, v in tl.items())
    return tl


def compute_recovery_timeline(n_workers: int, state_bytes_per_worker: float,
                              costs: FailoverCosts = FailoverCosts(),
                              detection: Optional[DetectionTimeline] = None,
                              replay: Optional["ReplayCostModel"] = None,
                              n_replayers: int = 2) -> Dict[str, float]:
    """Checkpoint-free recovery flow ("All is Not Lost", PAPERS.md): same
    orchestration legs as FFTrainer, but the state leg is a REPLAY leg —
    healthy neighbors rebuild the lost worker's state by redundant compute
    at the modeled recompute rate (train/step.py `ReplayCostModel`). No
    fabric bytes move, so the leg is independent of link bandwidth, TRAIN
    contention, and storm damage; the bill lands on `replay_compute`
    seconds instead (plus `compute_seconds_burned`, the total worker
    compute spent, reported out-of-timeline)."""
    from repro.train.step import ReplayCostModel, replay_compute_cost
    detection = detection if detection is not None else DetectionTimeline()
    cost = replay_compute_cost(state_bytes_per_worker,
                               n_replayers=n_replayers,
                               model=replay or ReplayCostModel())
    tl = {
        "detection": max(detection.detection_time(), costs.detection_fft),
        "pod_creation": costs.pod_creation_fft,
        "dependency_install": costs.dependency_fft,
        # network setup overlaps the replay exactly like it overlaps the
        # stream leg in `fftrainer_timeline` (§5.2)
        "replay_compute": max(costs.conn_base
                              + costs.conn_per_worker * n_workers,
                              cost.wall_seconds),
    }
    tl["total"] = sum(tl.values())
    tl["compute_seconds_burned"] = cost.compute_seconds
    return tl


def hybrid_recovery_timeline(n_workers: int, state_bytes_per_worker: float,
                             costs: FailoverCosts = FailoverCosts(),
                             detection: Optional[DetectionTimeline] = None,
                             replay: Optional["ReplayCostModel"] = None,
                             n_replayers: int = 2,
                             train_traffic: TrainTraffic = (),
                             scheduler: Optional[LinkScheduler] = None,
                             topology: Optional[LinkTopology] = None,
                             path: Optional[Sequence[Edge]] = None,
                             paths: Optional[Sequence[Sequence[Edge]]] = None
                             ) -> Dict[str, float]:
    """Per-worker race between the stream leg and the replay leg: the state
    phase takes whichever finishes first (both start once pods are up).
    The closed-form analogue of `HybridRecovery` in runtime/recovery.py —
    useful for the table5 what-if rows without building a cluster."""
    from repro.train.step import ReplayCostModel, replay_compute_cost
    detection = detection if detection is not None else DetectionTimeline()
    t_net = costs.conn_base + costs.conn_per_worker * n_workers
    t_stream = costs.state_ramp_fft + schedule_state_phase(
        state_bytes_per_worker, costs.neighbor_bw, quantum=costs.quantum,
        train_traffic=train_traffic, scheduler=scheduler,
        topology=topology, path=path, paths=paths)
    t_replay = replay_compute_cost(state_bytes_per_worker,
                                   n_replayers=n_replayers,
                                   model=replay or ReplayCostModel()
                                   ).wall_seconds
    tl = {
        "detection": max(detection.detection_time(), costs.detection_fft),
        "pod_creation": costs.pod_creation_fft,
        "dependency_install": costs.dependency_fft,
        "network_and_state": max(t_net, min(t_stream, t_replay)),
    }
    tl["total"] = sum(tl.values())
    return tl


def baseline_timeline(n_workers: int, state_bytes_per_worker: float,
                      costs: FailoverCosts = FailoverCosts(),
                      train_traffic: TrainTraffic = ()
                      ) -> Dict[str, float]:
    t_net = costs.conn_base + costs.conn_per_worker_baseline * n_workers
    # serial reload from remote storage — same link model, storage bandwidth,
    # whole-artifact chunks (no FFTrainer quantum preemption to exploit)
    t_state = costs.state_ramp_baseline + schedule_state_phase(
        state_bytes_per_worker, costs.storage_bw,
        quantum=max(state_bytes_per_worker, 1.0),
        train_traffic=train_traffic)
    tl = {
        "detection": costs.detection_baseline,
        "pod_creation": costs.pod_creation_baseline,
        "dependency_install": costs.dependency_baseline,
        "network_recovery": t_net,
        "state_recovery": t_state,      # serial: after network
    }
    tl["total"] = sum(tl.values())
    return tl
