"""Failover timeline orchestration (paper Fig. 1, Table 5).

Models both flows over the same recovery steps:
  serial (PyTorch/Gemini-style):   detect -> pod -> deps -> network -> state
  FFTrainer (overlapped):          detect -> pod (pre-pulled) ->
                                   max(network-recovery, state-load)   [§5.2]
plus lazy backup running in parallel with pod creation (§4.2).

Step costs are either measured on our own control-plane code (connection
building, heartbeat processing — see benchmarks fig8/fig10) or taken from the
paper's measured Table 5 for orchestration steps we can only model (Docker
pulls, pod scheduling).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict

from repro.core.detection import DetectionTimeline


@dataclass(frozen=True)
class FailoverCosts:
    # paper Table 5 measured values (seconds)
    detection_baseline: float = 15.0
    pod_creation_baseline: float = 392.0
    dependency_baseline: float = 421.0
    detection_fft: float = 6.0
    pod_creation_fft: float = 7.0
    dependency_fft: float = 0.0
    # bandwidths for state movement
    neighbor_bw: float = 50e9          # ICI link (instant ckpt fetch)
    storage_bw: float = 1e9            # remote storage (baseline reload)
    # network-recovery scaling (calibrated on our lock-free init, fig8)
    conn_base: float = 0.5
    conn_per_worker: float = 0.001
    conn_per_worker_baseline: float = 0.08


def fftrainer_timeline(n_workers: int, state_bytes_per_worker: float,
                       costs: FailoverCosts = FailoverCosts(),
                       detection: DetectionTimeline = DetectionTimeline()
                       ) -> Dict[str, float]:
    t_net = costs.conn_base + costs.conn_per_worker * n_workers
    t_state = state_bytes_per_worker / costs.neighbor_bw + 0.2
    tl = {
        # lower-bounded by our measured heartbeat path; paper measured 6 s
        "detection": max(detection.detection_time(), costs.detection_fft),
        "pod_creation": costs.pod_creation_fft,
        "dependency_install": costs.dependency_fft,
        # role/rank decoupling overlaps the two (§5.2)
        "network_and_state": max(t_net, t_state),
    }
    tl["total"] = sum(v for k, v in tl.items())
    return tl


def baseline_timeline(n_workers: int, state_bytes_per_worker: float,
                      costs: FailoverCosts = FailoverCosts()
                      ) -> Dict[str, float]:
    t_net = costs.conn_base + costs.conn_per_worker_baseline * n_workers
    t_state = state_bytes_per_worker / costs.storage_bw + 2.0
    tl = {
        "detection": costs.detection_baseline,
        "pod_creation": costs.pod_creation_baseline,
        "dependency_install": costs.dependency_baseline,
        "network_recovery": t_net,
        "state_recovery": t_state,      # serial: after network
    }
    tl["total"] = sum(tl.values())
    return tl
