"""DP-ring cluster simulation with REAL training-state movement.

The cluster trains an actual (smoke-scale) model: one jit'd step computes the
global SPMD step, and the ZeRO-unique optimizer state is split into `dp`
contiguous shards — worker i owns shard i and, per the paper's neighboring
redundancy, worker (i+1) % dp holds a copy of it in host RAM (two versions,
consistency §4.2). Failure/recovery therefore moves REAL bytes and the
integration tests assert bitwise state equality against an uninterrupted run.

Failure semantics (paper §6.2, Table 3):
  * software failure: worker process dies, host RAM (backups) survives;
  * hardware failure: host dies — its shard AND the backup it held are lost;
    recovery needs the neighbor's copy; if worker i and i+1 both died, the
    instant checkpoint is lost and we fall back to the periodic full CKPT
    (multi-level insurance) with rollback;
  * healthy workers perform lazy backup (DP rank 0 persists redundant state).
"""
from __future__ import annotations

import dataclasses
import time
from dataclasses import dataclass, field
from pathlib import Path
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.ckpt.engine import CkptEngine, CkptEngineConfig
from repro.configs import ArchConfig
from repro.core.consistency import reconcile
from repro.core.controller import StateController
from repro.core.detection import DetectionTimeline
from repro.data.indexer import TidIndexer
from repro.data.loader import PrefetchingLoader, SyntheticTokens
from repro.models import build_model
from repro.optim import AdamWConfig, adamw_update, cast_params, cosine_schedule
from repro.train.state import init_state

PyTree = Any


def _flatten_opt(opt: PyTree) -> Tuple[np.ndarray, Any]:
    leaves, treedef = jax.tree_util.tree_flatten(opt)
    vec = np.concatenate([np.asarray(l, np.float32).ravel() for l in leaves])
    shapes = [(l.shape, l.dtype) for l in leaves]
    return vec, (treedef, shapes)


def _unflatten_opt(vec: np.ndarray, meta) -> PyTree:
    treedef, shapes = meta
    leaves, off = [], 0
    for shape, dtype in shapes:
        n = int(np.prod(shape))
        leaves.append(vec[off:off + n].reshape(shape).astype(dtype))
        off += n
    return jax.tree_util.tree_unflatten(treedef, leaves)


def shard_slices(n: int, dp: int) -> List[slice]:
    per = (n + dp - 1) // dp
    return [slice(i * per, min((i + 1) * per, n)) for i in range(dp)]


@dataclass
class Worker:
    wid: int
    alive: bool = True
    host_alive: bool = True           # hardware failure kills host RAM too
    engine: CkptEngine = None
    loader: PrefetchingLoader = None
    step_times: List[float] = field(default_factory=list)


@dataclass
class RecoveryReport:
    kind: str                          # software | hardware | fallback
    recovered_from: str                # neighbor | full_ckpt
    resume_iteration: int
    rolled_back_iterations: int
    timeline: Dict[str, float]
    total_time: float
    elastic_dp: Optional[int] = None


class SimCluster:
    def __init__(self, cfg: ArchConfig, *, dp: int = 4,
                 global_batch: int = 8, seq_len: int = 16,
                 dataset_size: int = 4096,
                 hp: AdamWConfig = AdamWConfig(warmup_steps=2, total_steps=100),
                 ckpt_dir: Path = Path("/tmp/repro_ckpt"),
                 full_every: int = 50, seed: int = 0):
        self.cfg = cfg
        self.dp = dp
        self.active_dp = dp
        self.global_batch = global_batch
        self.seq_len = seq_len
        self.hp = hp
        self.model = build_model(cfg)
        self.state = init_state(self.model, jax.random.key(seed))
        self.iteration = 0
        self.controller = StateController(dp=dp, pp=1, tp=1,
                                          global_batch=global_batch)
        self.indexer = TidIndexer(dataset_size, global_batch, seed=seed)
        self.source = SyntheticTokens(dataset_size, seq_len, cfg.vocab_size,
                                      seed=seed)
        self.detection = DetectionTimeline()
        eng_cfg = CkptEngineConfig(out_dir=Path(ckpt_dir),
                                   full_every=full_every)
        self.workers = [
            Worker(w,
                   engine=CkptEngine(dataclasses.replace(eng_cfg), worker_id=w),
                   loader=PrefetchingLoader(self.source, self.indexer, w, dp))
            for w in range(dp)
        ]
        self._step = jax.jit(self._make_step())
        self._opt_meta = None
        self.loss_history: List[float] = []

    # ------------------------------------------------------------------ #
    def _make_step(self):
        model, hp = self.model, self.hp

        def step(state, batch):
            (loss, aux), grads = jax.value_and_grad(
                lambda p: model.loss(p, batch), has_aux=True)(state["params"])
            lr = cosine_schedule(state["step"], lr=hp.lr,
                                 warmup_steps=hp.warmup_steps,
                                 total_steps=hp.total_steps)
            _, new_opt = adamw_update(grads, state["opt"], state["step"],
                                      hp, lr)
            new_params = cast_params(new_opt["master"], state["params"])
            return ({"step": state["step"] + 1, "params": new_params,
                     "opt": new_opt}, loss)

        return step

    def _assemble_batch(self) -> Dict[str, jnp.ndarray]:
        parts = []
        for w in self.workers[:self.active_dp]:
            parts.append(w.loader.get(self.iteration))
        return {"tokens": jnp.asarray(np.concatenate(parts, axis=0))}

    def _shard_and_backup(self) -> None:
        """Instant checkpoint: split unique opt state into dp shards; worker
        (i+1) stores worker i's shard (the in-step ppermute, host view)."""
        vec, meta = _flatten_opt(self.state["opt"])
        self._opt_meta = meta
        slices = shard_slices(len(vec), self.dp)
        it = self.iteration
        for i, w in enumerate(self.workers[:self.active_dp]):
            own = vec[slices[i]].copy()
            nbr = self.workers[(i + 1) % self.active_dp]
            w.engine.own.push(it, {"shard": own})
            if nbr.alive and nbr.host_alive:
                nbr.engine.neighbor.push(it, {"shard": own})
                nbr.engine.instant_count += 1
            self.controller.report_ckpt(i, it)

    def step(self) -> float:
        t0 = time.monotonic()
        batch = self._assemble_batch()
        self.state, loss = self._step(self.state, batch)
        jax.block_until_ready(loss)
        self.iteration += 1
        self._shard_and_backup()
        for w in self.workers[:self.active_dp]:
            w.engine.maybe_full_checkpoint(
                self.iteration, self.state if w.wid == 0 else
                {"marker": np.zeros(1)})
            self.controller.beat(w.wid)
            w.step_times.append(time.monotonic() - t0)
        self.loss_history.append(float(loss))
        return float(loss)

    def run(self, n_steps: int) -> List[float]:
        return [self.step() for _ in range(n_steps)]

    # ------------------------------------------------------------------ #
    # Failure injection + recovery
    # ------------------------------------------------------------------ #
    def inject_failure(self, wids: List[int], *, hardware: bool = False
                       ) -> None:
        for wid in wids:
            self.workers[wid].alive = False
            if hardware:
                self.workers[wid].host_alive = False
                # host RAM gone: its own + neighbor backups are lost
                self.workers[wid].engine.own = type(
                    self.workers[wid].engine.own)(2)
                self.workers[wid].engine.neighbor = type(
                    self.workers[wid].engine.neighbor)(2)

    def _recoverable_from_neighbors(self, failed: List[int]) -> bool:
        for wid in failed:
            holder = self.workers[(wid + 1) % self.dp]
            if not holder.host_alive or \
                    holder.engine.neighbor.latest() is None:
                return False
        return True

    def recover(self, *, hardware: bool = False) -> RecoveryReport:
        failed = [w.wid for w in self.workers if not w.alive]
        assert failed, "no failed workers"
        timeline: Dict[str, float] = {}
        timeline["detection"] = self.detection.detection_time()
        timeline["pod_creation"] = 7.0 if hardware else 0.5
        timeline["dependency_install"] = 0.0

        # lazy backup: healthy DP rank 0 persists redundant state (params)
        rank0 = self.workers[0]
        if rank0.alive:
            rank0.engine.lazy_backup(self.iteration,
                                     {"params": self.state["params"]},
                                     is_dp_rank0=True)

        if self._recoverable_from_neighbors(failed):
            report = self._recover_from_neighbors(failed, timeline, hardware)
        else:
            report = self._recover_from_full(failed, timeline)

        for wid in failed:
            self.workers[wid].alive = True
            self.workers[wid].host_alive = True
            self.controller.beat(wid)
            self.workers[wid].loader.repartition(self.active_dp)
        return report

    def _recover_from_neighbors(self, failed, timeline, hardware
                                ) -> RecoveryReport:
        # consistency: earliest globally-available version (§4.2)
        versions = {w.wid: w.engine.own.latest().iteration
                    if w.wid not in failed and w.engine.own.latest()
                    else self.workers[(w.wid + 1) % self.dp]
                    .engine.neighbor.latest().iteration
                    for w in self.workers}
        target = min(versions.values())
        rolled = self.iteration - target

        vec, meta = _flatten_opt(self.state["opt"])
        slices = shard_slices(len(vec), self.dp)
        for w in self.workers:
            snap_keeper = (self.workers[(w.wid + 1) % self.dp].engine.neighbor
                           if w.wid in failed else w.engine.own)
            snap = snap_keeper.get(target)
            assert snap is not None, \
                f"version {target} missing on worker {w.wid}"
            vec[slices[w.wid]] = snap.state["shard"]
        new_opt = _unflatten_opt(vec, meta)
        params = jax.tree.map(
            lambda m, p: jnp.asarray(m).astype(p.dtype),
            new_opt["master"], self.state["params"])
        self.state = {"step": jnp.asarray(target, jnp.int32),
                      "params": params, "opt": jax.tree.map(jnp.asarray,
                                                            new_opt)}
        self.iteration = target

        # timeline: network recovery overlaps state loading (§5.2)
        n = self.dp
        t_net = 0.5 + 0.001 * n
        shard_bytes = vec.nbytes / self.dp
        t_state = shard_bytes / 50e9 + 0.2
        timeline["network_and_state"] = max(t_net, t_state)
        total = sum(timeline.values())
        return RecoveryReport("hardware" if hardware else "software",
                              "neighbor", target, rolled, timeline, total)

    def _recover_from_full(self, failed, timeline) -> RecoveryReport:
        eng0 = self.workers[0].engine
        eng0.writer.drain()
        it = eng0.latest_full()
        assert it is not None, "no full checkpoint available (insurance gap)"
        like = jax.tree.map(lambda x: np.asarray(x), self.state)
        restored = eng0.restore_full(it, like)
        self.state = jax.tree.map(jnp.asarray, restored)
        rolled = self.iteration - it
        self.iteration = it
        full_bytes = sum(np.asarray(l).nbytes
                         for l in jax.tree.leaves(restored))
        timeline["network_and_state"] = max(0.5 + 0.001 * self.dp,
                                            full_bytes / 1e9 + 1.0)
        total = sum(timeline.values())
        return RecoveryReport("fallback", "full_ckpt", it, rolled,
                              timeline, total)

    # ------------------------------------------------------------------ #
    # Elastic rescale (no spare capacity): shrink DP, repartition data
    # ------------------------------------------------------------------ #
    def shrink(self, lost: List[int]) -> int:
        keep = [w for w in self.workers if w.wid not in lost]
        self.workers = keep
        for new_id, w in enumerate(self.workers):
            w.wid = new_id
        self.dp = len(self.workers)
        self.active_dp = self.dp
        self.controller.shrink_dp(lost)
        per = self.global_batch // max(self.active_dp, 1)
        self.global_batch = per * self.active_dp
        self.controller.global_batch = self.global_batch
        self.indexer = TidIndexer(self.indexer.dataset_size,
                                  self.global_batch, seed=self.indexer.seed)
        for i, w in enumerate(self.workers):
            w.loader = PrefetchingLoader(self.source, self.indexer, i,
                                         self.active_dp)
        return self.dp
