"""DP-ring cluster simulation with REAL training-state movement.

The cluster trains an actual (smoke-scale) model: one jit'd step computes the
global SPMD step, and the ZeRO-unique optimizer state is split into `dp`
contiguous shards — worker i owns shard i and, per the paper's neighboring
redundancy, worker (i+1) % dp holds a copy of it in host RAM (two versions,
consistency §4.2). Failure/recovery therefore moves REAL bytes and the
integration tests assert bitwise state equality against an uninterrupted run.

Failure semantics (paper §6.2, Table 3):
  * software failure: worker process dies, host RAM (backups) survives;
  * hardware failure: host dies — its shard AND the backup it held are lost;
    recovery needs the neighbor's copy; if worker i and i+1 both died, the
    instant checkpoint is lost and we fall back to the periodic full CKPT
    (multi-level insurance) with rollback;
  * healthy workers perform lazy backup (DP rank 0 persists redundant state).
"""
from __future__ import annotations

import dataclasses
import warnings
from dataclasses import dataclass, field
from pathlib import Path
from typing import Any, Callable, Dict, List, Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.ckpt.engine import CkptEngine, CkptEngineConfig
from repro.ckpt.stream import (DEFAULT_QUANTUM, ChunkedStream, StreamAssembler,
                               TopologyTransport)
from repro.configs import ArchConfig
from repro.core.consistency import reconcile
from repro.core.controller import StateController
from repro.core.detection import DetectionTimeline
from repro.core.lccl import (Edge, LinkTopology, PodFabric, StormReport,
                             edge_key, inject_storm)
from repro.data.indexer import TidIndexer
from repro.data.loader import PrefetchingLoader, SyntheticTokens
from repro.models import build_model
from repro.optim import AdamWConfig, adamw_update, cast_params, cosine_schedule
# recovery machinery lives in runtime/recovery.py; the vector/shard helpers
# and RecoveryReport are re-exported here for back-compat imports
from repro.runtime.recovery import (FaultScript, RecoveryError, RecoveryPlan,
                                    RecoveryPolicy, RecoveryReport,
                                    StreamRecovery, _flatten_opt,
                                    _unflatten_opt, orchestration_timeline,
                                    resolve_policy, shard_slices)
from repro.runtime.reliability import (ReliabilityConfig,
                                       ReliabilityController,
                                       ReliabilityEvent)
from repro.train.state import init_state
from repro.train.step import step_traffic, submit_step_traffic

PyTree = Any

__all__ = [
    "ClusterConfig", "FabricConfig", "FaultScript", "RecoveryError",
    "RecoveryPlan", "RecoveryPolicy", "RecoveryReport", "ReliabilityConfig",
    "SimCluster", "Worker", "shard_slices",
]


# --------------------------------------------------------------------------- #
# Configuration surface (replaces the old 17-kwarg constructor sprawl)
# --------------------------------------------------------------------------- #
@dataclass(frozen=True)
class ClusterConfig:
    """Model/batch knobs of a simulated cluster (what trains)."""
    dp: int = 4
    global_batch: int = 8
    seq_len: int = 16
    dataset_size: int = 4096
    hp: AdamWConfig = field(
        default_factory=lambda: AdamWConfig(warmup_steps=2, total_steps=100))
    ckpt_dir: Path = Path("/tmp/repro_ckpt")
    full_every: int = 50
    seed: int = 0
    t_iter_model: float = 0.05         # modeled wall seconds per iteration


@dataclass(frozen=True)
class FabricConfig:
    """Fabric knobs of a simulated cluster (what the bytes ride).

    `compile_plan=True` switches `LinkTopology.run` onto the decoupled fast
    path (exact timings, but only edges coupled by a pending multi-hop item
    pay the global event loop — see `repro/core/plan.py`) and keeps the BFS
    routing tables epoch-cached across steps."""
    link_bw: float = 50e9
    quantum: int = DEFAULT_QUANTUM
    topology: str = "ring"
    edge_bw: Optional[Dict[Edge, float]] = None
    pods: int = 1
    dcn_bw: float = 5e9
    ici_latency: float = 0.0
    dcn_latency: float = 0.0
    compile_plan: bool = False
    # routing budget for split-policy recovery/backup streams: max
    # edge-disjoint paths to stripe each stream across (k=2 reproduces the
    # historical both-ring-directions split bit-exactly)
    route_k: int = 2
    # DCN uplinks per pod on a PodFabric (each uplink forms its own
    # gateway ring; 1 reproduces the historical single-gateway fabric)
    dcn_uplinks: int = 1
    # re-run split_bytes over surviving paths when the topology epoch
    # bumps mid-transfer (False pins chunks to their original paths)
    rebalance: bool = True


_CLUSTER_FIELDS = {f.name for f in dataclasses.fields(ClusterConfig)}
_FABRIC_FIELDS = {f.name for f in dataclasses.fields(FabricConfig)}
LEGACY_CLUSTER_KWARGS = _CLUSTER_FIELDS | _FABRIC_FIELDS


def _split_legacy_kwargs(kw: Dict[str, Any],
                         cluster: Optional[ClusterConfig],
                         fabric: Optional[FabricConfig]
                         ) -> Tuple[ClusterConfig, FabricConfig]:
    """Fold flat legacy constructor kwargs into the two config dataclasses
    (over whatever explicit configs were also passed)."""
    unknown = set(kw) - LEGACY_CLUSTER_KWARGS
    if unknown:
        raise TypeError(f"SimCluster got unexpected keyword argument(s) "
                        f"{sorted(unknown)}")
    c_over = {k: v for k, v in kw.items() if k in _CLUSTER_FIELDS}
    f_over = {k: v for k, v in kw.items() if k in _FABRIC_FIELDS}
    cc = dataclasses.replace(cluster or ClusterConfig(), **c_over)
    fc = dataclasses.replace(fabric or FabricConfig(), **f_over)
    return cc, fc


@dataclass
class Worker:
    wid: int
    alive: bool = True
    host_alive: bool = True           # hardware failure kills host RAM too
    engine: Optional[CkptEngine] = None
    loader: Optional[PrefetchingLoader] = None
    step_times: List[float] = field(default_factory=list)


class SimCluster:
    def __init__(self, cfg: ArchConfig,
                 cluster: Optional[ClusterConfig] = None,
                 fabric: Optional[FabricConfig] = None,
                 recovery: Union[str, RecoveryPolicy, None] = None,
                 reliability: Optional[ReliabilityConfig] = None,
                 **legacy):
        """Build a simulated cluster from `ClusterConfig` (model/batch
        knobs) + `FabricConfig` (link knobs) + a recovery policy
        ("stream" | "compute" | "hybrid" or a `RecoveryPolicy` instance)
        + a `ReliabilityConfig` for the self-driving control loop
        (heartbeat/scan cadence, straggler + gray-link policy, adaptive
        checkpoint cadence — defaults match `DetectionTimeline`).

        The old flat kwargs (`dp=`, `link_bw=`, ...) still work but emit a
        `DeprecationWarning`; see also `SimCluster.from_kwargs`."""
        if legacy:
            # unknown names are a TypeError (as a real signature would
            # raise), not a deprecation — check before warning
            cluster, fabric = _split_legacy_kwargs(legacy, cluster, fabric)
            warnings.warn(
                f"SimCluster flat keyword(s) {sorted(legacy)} are "
                "deprecated; pass cluster=ClusterConfig(...) and "
                "fabric=FabricConfig(...) instead",
                DeprecationWarning, stacklevel=2)
        cc = cluster if cluster is not None else ClusterConfig()
        fc = fabric if fabric is not None else FabricConfig()
        self.cluster_config = cc
        self.fabric_config = fc
        self.recovery_policy: RecoveryPolicy = resolve_policy(recovery)
        dp, global_batch, seed = cc.dp, cc.global_batch, cc.seed
        self.cfg = cfg
        self.dp = dp
        self.active_dp = dp
        self.global_batch = global_batch
        self.seq_len = cc.seq_len
        self.hp = cc.hp
        self.model = build_model(cfg)
        self.state = init_state(self.model, jax.random.key(seed))
        self.iteration = 0
        rc = reliability if reliability is not None else ReliabilityConfig()
        self.reliability_config = rc
        self.controller = StateController(dp=dp, pp=1, tp=1,
                                          global_batch=global_batch,
                                          heartbeat_timeout=rc.timeout)
        self.indexer = TidIndexer(cc.dataset_size, global_batch, seed=seed)
        self.source = SyntheticTokens(cc.dataset_size, cc.seq_len,
                                      cfg.vocab_size, seed=seed)
        # the analytic timeline mirrors the live loop's cadence, so the
        # measured detection latency validates against detection_time()
        self.detection = DetectionTimeline(
            heartbeat_period=rc.heartbeat_period,
            controller_scan_period=rc.scan_period,
            notify_latency=rc.notify_latency)
        # per-link fabric: one LinkScheduler per edge. The train loop's
        # allreduce volume loads every edge (TRAIN, per tier on a pod
        # fabric); each checkpoint artifact rides its routed edge path
        # (STATE chunks), so TRAIN/STATE contention is per-edge and per-tier
        # instead of smeared over one global link. With `pods > 1` the dp
        # workers are grouped into that many ICI rings joined by a DCN
        # gateway ring (`PodFabric`) — cross-pod streams pay the DCN
        # bandwidth and per-hop latency
        self.quantum = fc.quantum
        self.link_bw = fc.link_bw
        self.topology_kind = fc.topology
        self.t_iter_model = cc.t_iter_model
        self.sim_time = 0.0
        self.pods = fc.pods
        self.dcn_bw = fc.dcn_bw
        self.ici_latency = fc.ici_latency
        self.dcn_latency = fc.dcn_latency
        self.route_k = fc.route_k
        self.dcn_uplinks = fc.dcn_uplinks
        if fc.pods > 1 and dp % fc.pods != 0:
            raise ValueError(
                f"pods={fc.pods} must divide dp={dp} to build a PodFabric "
                f"(every pod gets dp/pods workers)")
        self.topology = self._build_fabric(dp, fc.edge_bw)
        self.transport = TopologyTransport(self.topology, route_k=fc.route_k,
                                           auto_rebalance=fc.rebalance)
        self.last_storm: Optional[StormReport] = None
        self.instant_hidden = 0        # instant-ckpt drained within the iter
        self.instant_exposed = 0       # ... spilled past the boundary
        # per-edge view of the same condition (adjacent ring edge per worker)
        self.edge_instant_hidden: Dict[Edge, int] = {}
        self.edge_instant_exposed: Dict[Edge, int] = {}
        eng_cfg = CkptEngineConfig(out_dir=Path(cc.ckpt_dir),
                                   full_every=cc.full_every,
                                   quantum=fc.quantum)
        self.workers = [
            Worker(w,
                   engine=CkptEngine(dataclasses.replace(eng_cfg), worker_id=w,
                                     transport=self.transport),
                   loader=PrefetchingLoader(self.source, self.indexer, w, dp))
            for w in range(dp)
        ]
        self._step = jax.jit(self._make_step())
        self._opt_meta = None
        self._grad_bytes: Optional[float] = None
        # partial recovery transfers, keyed (failed_wid, target_iteration)
        self._pending_recovery: Dict[Tuple[int, int],
                                     Tuple[ChunkedStream, StreamAssembler]] = {}
        # shard layout the held snapshots were taken under; diverges from the
        # live (dp, wid) numbering only across an elastic shrink with a
        # recovery still pending (resume-after-rescale)
        self._layout: Optional[Dict[str, Any]] = None
        self._lazy_done_at: Optional[int] = None
        self.loss_history: List[float] = []
        # --- self-driving reliability loop (runtime/reliability.py) --- #
        # per-worker slowdown multipliers (scenario-injected stragglers)
        self._slow_factor: Dict[int, float] = {}
        # last step's per-worker modeled durations, consumed by the loop
        self.last_step_times: Optional[Dict[int, float]] = None
        # sim seconds trained while the instant checkpoint spilled past the
        # iteration boundary (the exposed complement of FCR)
        self.exposed_seconds = 0.0
        # the loop's on-clock detection replaces the analytic leg in the
        # next recover(): latency measured from fault injection, and a flag
        # that the sim clock already advanced THROUGH the detection window
        self._measured_detection: Optional[float] = None
        self._detection_elapsed = False
        # provisioned bandwidth of scenario-degraded edges (heal restores)
        self._spec_bw_edges: Dict[Edge, float] = {}
        # everybody beats at attach (a fresh heartbeat table reads -inf,
        # which a scan would misread as a pre-start breakdown)
        for w in self.workers:
            self.controller.beat(w.wid, now=0.0)
        self.reliability = ReliabilityController(self, rc)

    @classmethod
    def from_kwargs(cls, cfg: ArchConfig,
                    recovery: Union[str, RecoveryPolicy, None] = None,
                    **kw) -> "SimCluster":
        """Deprecated shim for the old flat-kwarg constructor
        (`SimCluster.from_kwargs(cfg, dp=4, link_bw=50e9, ...)`). Use
        `SimCluster(cfg, cluster=ClusterConfig(...),
        fabric=FabricConfig(...))` instead."""
        warnings.warn(
            "SimCluster.from_kwargs is a deprecated back-compat shim; "
            "pass cluster=ClusterConfig(...) and fabric=FabricConfig(...) "
            "to SimCluster directly",
            DeprecationWarning, stacklevel=2)
        cc, fc = _split_legacy_kwargs(kw, None, None)
        return cls(cfg, cluster=cc, fabric=fc, recovery=recovery)

    def shard_nbytes(self) -> float:
        """Bytes of one worker's unique optimizer-state shard under the
        snapshot layout (float32 flattened vector / layout dp) — the volume
        a recovery policy must move or recompute per failed worker."""
        n = int(sum(int(np.prod(l.shape))
                    for l in jax.tree.leaves(self.state["opt"])))
        ldp = self._shard_layout()[0]
        per = (n + ldp - 1) // ldp
        return float(per * 4)

    # ------------------------------------------------------------------ #
    def _build_fabric(self, dp: int,
                      edge_bw: Optional[Dict[Edge, float]] = None
                      ) -> LinkTopology:
        """The fabric for `dp` workers: a flat ring/full mesh, or — when
        `pods > 1` divides dp — a hierarchical `PodFabric` of ICI rings
        joined by a DCN gateway ring. The constructor rejects a
        non-dividing pod count; an elastic shrink that breaks divisibility
        degrades to a flat ring with a warning."""
        topo: Optional[LinkTopology] = None
        if self.pods > 1:
            if dp % self.pods == 0 and dp // self.pods >= 1:
                topo = PodFabric(self.pods, dp // self.pods, self.link_bw,
                                 self.dcn_bw, quantum=self.quantum,
                                 ici_latency=self.ici_latency,
                                 dcn_latency=self.dcn_latency,
                                 edge_bw=edge_bw,
                                 dcn_uplinks=self.dcn_uplinks)
            else:
                import warnings
                warnings.warn(
                    f"dp={dp} no longer divides into pods={self.pods} after "
                    f"rescale; the fabric degrades to a flat ring",
                    RuntimeWarning, stacklevel=2)
        if topo is None:
            topo = LinkTopology(dp, self.link_bw, quantum=self.quantum,
                                kind=self.topology_kind, edge_bw=edge_bw,
                                latency=self.ici_latency)
        topo.compile_plan = self.fabric_config.compile_plan
        return topo

    # ------------------------------------------------------------------ #
    def _make_step(self):
        model, hp = self.model, self.hp

        def step(state, batch):
            (loss, aux), grads = jax.value_and_grad(
                lambda p: model.loss(p, batch), has_aux=True)(state["params"])
            lr = cosine_schedule(state["step"], lr=hp.lr,
                                 warmup_steps=hp.warmup_steps,
                                 total_steps=hp.total_steps)
            _, new_opt = adamw_update(grads, state["opt"], state["step"],
                                      hp, lr)
            new_params = cast_params(new_opt["master"], state["params"])
            return ({"step": state["step"] + 1, "params": new_params,
                     "opt": new_opt}, loss)

        return step

    def _assemble_batch(self) -> Dict[str, jnp.ndarray]:
        parts = []
        for w in self.workers[:self.active_dp]:
            parts.append(w.loader.get(self.iteration))
        return {"tokens": jnp.asarray(np.concatenate(parts, axis=0))}

    def _shard_and_backup(self) -> None:
        """Instant checkpoint: split unique opt state into dp shards; worker
        (i+1) stores worker i's shard (the in-step ppermute, host view) AND
        streams it as chunked STATE traffic over its adjacent fabric edge."""
        vec, meta = _flatten_opt(self.state["opt"])
        self._opt_meta = meta
        slices = shard_slices(len(vec), self.dp)
        it = self.iteration
        active = self.active_dp
        shards = {i: vec[slices[i]].copy() for i in range(active)}
        for i, w in enumerate(self.workers[:active]):
            # predecessor's shard lands in this worker's host RAM
            nbr_shard = ({"shard": shards[(i - 1) % active]}
                         if (w.alive and w.host_alive) else None)
            w.engine.on_step(it, {"shard": shards[i]}, nbr_shard,
                             t=self.sim_time)
            self.controller.report_ckpt(i, it)

    def step_traffic_profile(self):
        """This step's wire volumes (train/step.py accounting). On a pod
        fabric the allreduce is two-level: intra-pod ring volume per ICI
        edge plus the inter-pod shard allreduce per DCN edge."""
        if self._grad_bytes is None:
            self._grad_bytes = float(sum(
                int(np.prod(l.shape)) * 4
                for l in jax.tree.leaves(self.state["params"])))
        if isinstance(self.topology, PodFabric):
            from repro.train.step import hierarchical_step_traffic
            return hierarchical_step_traffic(self._grad_bytes,
                                             self.topology.n_pods,
                                             self.topology.pod_size)
        return step_traffic(self._grad_bytes, self.active_dp)

    def step(self) -> float:
        batch = self._assemble_batch()
        # the allreduce volume for this step goes on EVERY live ring edge
        # (per-edge TRAIN), preempting any in-flight STATE chunks there
        submit_step_traffic(self.transport, self.step_traffic_profile(),
                            self.sim_time)
        self.state, loss = self._step(self.state, batch)
        jax.block_until_ready(loss)
        self.iteration += 1
        self._shard_and_backup()
        # per-worker MODELED durations (sim seconds, never wall time): the
        # synchronous step paces at the slowest worker, so an injected
        # straggler stretches everyone's iteration — exactly what the
        # reliability loop's EWMAs watch for
        step_times: Dict[int, float] = {}
        for w in self.workers[:self.active_dp]:
            w.engine.maybe_full_checkpoint(
                self.iteration, self.state if w.wid == 0 else
                {"marker": np.zeros(1)}, t=self.sim_time)
            dt_w = self.t_iter_model * self._slow_factor.get(w.wid, 1.0)
            step_times[w.wid] = dt_w
            w.step_times.append(dt_w)
        # advance the link model one modeled iteration in a single window:
        # the fabric clock is event-ordered, so a cross-pod (multi-hop)
        # instant stream lands at its exact store-and-forward instant inside
        # the iteration it was submitted in. Instant-ckpt chunks that drain
        # before the boundary were hidden (the FCR condition, emergent from
        # the transport instead of Eq. 2) — tracked globally and per
        # delivering fabric edge
        dt = max(step_times.values()) if step_times else self.t_iter_model
        self.sim_time += dt
        # live workers heartbeat ON THE SIM CLOCK at the step boundary — a
        # dead worker's slot freezes and the liveness scan finds it
        for w in self.workers[:self.active_dp]:
            if w.alive:
                self.controller.beat(w.wid, now=self.sim_time)
        self.last_step_times = step_times
        self.transport.run(until=self.sim_time)
        tickets = []
        for w in self.workers[:self.active_dp]:
            tk = w.engine.last_instant_ticket
            if tk is None:
                continue
            tickets.append(tk)
            # book the verdict on the fabric edge that DELIVERED the shard —
            # the last hop of the path the stream actually rode. On a pod
            # fabric, consecutive wids across a pod boundary have no direct
            # edge, so the raw (src, dst) pair would be a phantom key
            # invisible to per-edge summaries
            e = tk.delivery_edge
            if e is None:              # single-node fabric: local delivery
                src, dst = self.transport.instant_route(w.wid)
                e = edge_key(src, dst)
            book = (self.edge_instant_hidden if tk.complete
                    else self.edge_instant_exposed)
            book[e] = book.get(e, 0) + 1
        if tickets:
            if all(tk.complete for tk in tickets):
                self.instant_hidden += 1
            else:
                self.instant_exposed += 1
                self.exposed_seconds += dt
        self.reliability.tick(self.sim_time)
        self.loss_history.append(float(loss))
        return float(loss)

    def run(self, n_steps: int) -> List[float]:
        return [self.step() for _ in range(n_steps)]

    # ------------------------------------------------------------------ #
    # Self-driving reliability surface (gray failures, stragglers, stalls)
    # ------------------------------------------------------------------ #
    def advance_idle(self, dt: float) -> List[ReliabilityEvent]:
        """Advance the sim clock `dt` seconds with training STALLED — the
        collective hangs on a failed worker, no step completes. Live
        workers still heartbeat (their processes are fine), the fabric
        drains, and the reliability loop scans: this is the window in which
        on-clock failure detection happens. Returns the loop's events."""
        self.sim_time += dt
        self.transport.run(until=self.sim_time)
        for w in self.workers[:self.active_dp]:
            if w.alive:
                self.controller.beat(w.wid, now=self.sim_time)
        return self.reliability.tick(self.sim_time)

    def set_straggler(self, wid: int, factor: float) -> None:
        """Worker `wid` now takes `factor` x the modeled iteration time
        (thermal throttling, a sick HBM stack, a noisy neighbor...)."""
        self._slow_factor[wid] = float(factor)

    def clear_straggler(self, wid: int) -> None:
        self._slow_factor.pop(wid, None)

    def degrade_edge(self, u: int, v: int, factor: float) -> None:
        """Silently degrade link (u, v) to `factor` x its current rate — a
        gray failure: the link is up, routing still uses it, but traffic
        crawls. Only the reliability loop's observed-throughput scan can
        tell (`set_bandwidth` is the fabric model's knob, not a signal any
        worker receives)."""
        e = edge_key(u, v)
        sch = self.topology.links[e]
        self._spec_bw_edges.setdefault(e, sch.bw)
        self.topology.set_bandwidth(u, v, sch.bw * factor)

    def heal_edge(self, u: int, v: int) -> None:
        """Repair a degraded link to its provisioned rate and lift any
        quarantine the reliability loop placed on it."""
        e = edge_key(u, v)
        spec = self._spec_bw_edges.pop(e, None)
        if spec is not None:
            self.topology.set_bandwidth(u, v, spec)
        self.reliability.release_edge(u, v)

    # ------------------------------------------------------------------ #
    # Failure injection + recovery
    # ------------------------------------------------------------------ #
    def inject_failure(self, wids: List[int], *, hardware: bool = False
                       ) -> None:
        self.reliability.note_failure(wids, self.sim_time)
        for wid in wids:
            self.workers[wid].alive = False
            # the node's ring edges go dark: nothing routes through it
            self.topology.fail_node(wid)
            if hardware:
                self.workers[wid].host_alive = False
                # host RAM gone: its own + neighbor backups are lost
                self.workers[wid].engine.own = type(
                    self.workers[wid].engine.own)(2)
                self.workers[wid].engine.neighbor = type(
                    self.workers[wid].engine.neighbor)(2)

    def inject_storm(self, seed: int, *, pods: int = 1,
                     edge_failures: int = 0) -> StormReport:
        """Correlated failure storm, reproducible from `seed` (lccl
        `inject_storm`): whole pods darken at once and every worker in them
        dies (software — processes gone, host RAM survives), plus
        `edge_failures` extra clustered edge failures. Storm-darkened EDGES
        persist through `recover()` (only the failed workers' nodes relight
        when their replacement pods come up), so recovery streams must race
        around the damage — over the DCN gateway ring when a whole pod sits
        between holder and newcomer."""
        report = inject_storm(self.topology, seed, pods=pods,
                              edge_failures=edge_failures)
        dead = [wid for wid in report.nodes if wid < len(self.workers)]
        self.reliability.note_failure(dead, self.sim_time)
        for wid in dead:
            self.workers[wid].alive = False
        self.last_storm = report
        return report

    # ----------------------- shard layout plumbing ----------------------- #
    # Snapshots are sliced by the (dp, wid) numbering in force when they were
    # taken. After an elastic shrink with a recovery still pending, the live
    # numbering differs; `_shard_layout` maps between the two so the resumed
    # recovery reassembles the optimizer vector with the SNAPSHOT layout.
    def _shard_layout(self) -> Tuple[int, Dict[int, int], Dict[int, int]]:
        """(layout_dp, old_of: live wid -> layout wid, new_of: inverse)."""
        if self._layout is None:
            ident = {i: i for i in range(self.dp)}
            return self.dp, dict(ident), dict(ident)
        old_of = dict(self._layout["old_of"])
        return self._layout["dp"], old_of, {o: n for n, o in old_of.items()}

    def _slice_source(self, old_slice: int, ldp: int,
                      new_of: Dict[int, int]) -> Tuple[str, Optional[int]]:
        """Where old shard-slice `old_slice` comes from: ("own", live wid) if
        its owner is healthy, else ("neighbor", live wid of its ring-successor
        backup holder), else ("none", None)."""
        owner = new_of.get(old_slice)
        if owner is not None and self.workers[owner].alive and \
                self.workers[owner].host_alive and \
                self.workers[owner].engine.own.latest() is not None:
            return "own", owner
        holder = new_of.get((old_slice + 1) % ldp)
        if holder is not None and self.workers[holder].host_alive and \
                self.workers[holder].engine.neighbor.latest() is not None:
            return "neighbor", holder
        return "none", None

    def _recoverable_from_neighbors(self, failed: List[int]) -> bool:
        ldp, _, new_of = self._shard_layout()
        for o in range(ldp):
            kind, _ = self._slice_source(o, ldp, new_of)
            if kind == "none":
                return False
        return True

    def recover(self, faults: Optional[FaultScript] = None, *,
                policy: Union[str, RecoveryPolicy, None] = None,
                **legacy) -> RecoveryReport:
        """Recover every failed worker via a `RecoveryPolicy`.

        `faults` scripts what goes wrong DURING recovery (hardware loss,
        mid-transfer interruption, wire corruption) — see `FaultScript`.
        The old flat keywords (`hardware=`, `interrupt_after_chunks=`,
        `corrupt_chunks=`) still work but emit a `DeprecationWarning`.

        `policy` overrides the cluster's configured recovery policy for
        this one recovery ("stream" | "compute" | "hybrid" or an
        instance). A policy that cannot honor the fault script (e.g.
        interrupting a chunk transfer it never performs) raises
        `RecoveryError`."""
        if legacy:
            unknown = set(legacy) - {"hardware", "interrupt_after_chunks",
                                     "corrupt_chunks"}
            if unknown:
                raise TypeError(f"recover() got unexpected keyword "
                                f"argument(s) {sorted(unknown)}")
            warnings.warn(
                f"recover({', '.join(sorted(legacy))}=...) keywords are "
                "deprecated; pass faults=FaultScript(...) instead",
                DeprecationWarning, stacklevel=2)
            base = faults or FaultScript()
            faults = dataclasses.replace(base, **legacy)
        faults = faults or FaultScript()
        pol = resolve_policy(policy) if policy is not None \
            else self.recovery_policy
        failed = [w.wid for w in self.workers if not w.alive]
        assert failed, "no failed workers"
        # replacement pods come up before state moves: their ring edges
        # relight, while any OTHER dark node keeps its edges dark and
        # recovery paths route around it
        for wid in failed:
            self.topology.restore_node(wid)
        timeline = orchestration_timeline(self, faults)

        # lazy backup: healthy DP rank 0 persists redundant state (params).
        # It goes on the wire NOW, overlapping the detection/pod-creation
        # window (§4.2) — recovery chunks only start once pods are up, so
        # the lazy stream has the link to itself first
        rank0 = self.workers[0]
        if rank0.alive and self._lazy_done_at != self.iteration:
            # once per iteration: a resumed recovery must not re-save and
            # re-stream the multi-GB redundant state it already persisted
            rank0.engine.lazy_backup(self.iteration,
                                     {"params": self.state["params"]},
                                     is_dp_rank0=True, t=self.sim_time)
            self._lazy_done_at = self.iteration
        t_orch = sum(timeline.values())
        if self._detection_elapsed:
            # the reliability loop detected this breakdown ON the sim clock
            # (advance_idle windows) — the detection leg already elapsed, so
            # the streams must not wait through it a second time. The
            # timeline still reports it (measured): it is part of the
            # failover the job experienced.
            t_orch -= timeline.get("detection", 0.0)

        plan = pol.plan(self, failed, faults, timeline=timeline,
                        t_start=self.sim_time + t_orch)
        report = pol.execute(plan)
        if report.kind == "interrupted":
            # workers stay down; their edges go dark again
            for wid in failed:
                self.topology.fail_node(wid)
            return report              # partial chunks retained

        for wid in failed:
            self.workers[wid].alive = True
            self.workers[wid].host_alive = True
            self.controller.beat(wid, now=self.sim_time)
            self.workers[wid].loader.repartition(self.active_dp)
        self.reliability.on_recovered(failed)
        self._measured_detection = None
        self._detection_elapsed = False
        # a completed recovery repairs the storm's fabric damage along with
        # the pods: the recovery STREAMS had to race around the dark edges
        # (DCN detours), but the healed job trains on a whole fabric again
        if self.last_storm is not None:
            for e in self.last_storm.edges:
                self.topology.restore_edge(*e)
            self.last_storm = None
        return report

    # ------------------------------------------------------------------ #
    # Elastic rescale (no spare capacity): shrink DP, repartition data
    # ------------------------------------------------------------------ #
    def shrink(self, lost: List[int]) -> int:
        """Shrink DP by dropping `lost` workers (no spare capacity).

        A shrink can strike mid-recovery: partial recovery streams whose
        target worker SURVIVES the rescale are kept (their assemblers retain
        every received chunk) and the next `recover()` resumes them. The
        shard layout the pending snapshots/streams were sliced under is
        remembered in `_layout` so the resumed recovery reassembles
        correctly; streams aimed at removed workers are dropped with them."""
        old_dp = self.dp
        keep = [w for w in self.workers if w.wid not in lost]
        wid_map = {w.wid: new_id for new_id, w in enumerate(keep)}
        layout_old_of = {}
        if self._layout is None:
            # live numbering == snapshot layout until now
            layout_dp, prev_old_of = old_dp, {i: i for i in range(old_dp)}
        else:                           # stacked shrinks: compose mappings
            layout_dp = self._layout["dp"]
            prev_old_of = self._layout["old_of"]
        for old_wid, new_wid in wid_map.items():
            layout_old_of[new_wid] = prev_old_of[old_wid]
        # keep partial recovery streams for surviving workers (key on the
        # new numbering); streams for removed workers die with them
        self._pending_recovery = {
            (wid_map[wid], target): sa
            for (wid, target), sa in self._pending_recovery.items()
            if wid in wid_map}
        self.workers = keep
        for new_id, w in enumerate(self.workers):
            w.wid = new_id
            w.engine.worker_id = new_id
        self.dp = len(self.workers)
        self.active_dp = self.dp
        still_failed = [w.wid for w in self.workers if not w.alive]
        self._layout = ({"dp": layout_dp, "old_of": layout_old_of}
                        if (self._pending_recovery or still_failed)
                        else None)
        self.controller.shrink_dp(lost)
        per = self.global_batch // max(self.active_dp, 1)
        self.global_batch = per * self.active_dp
        self.controller.global_batch = self.global_batch
        self.indexer = TidIndexer(self.indexer.dataset_size,
                                  self.global_batch, seed=self.indexer.seed)
        for i, w in enumerate(self.workers):
            w.loader = PrefetchingLoader(self.source, self.indexer, i,
                                         self.active_dp)
        # the fabric rescales with the job: fresh per-edge fabric at the new
        # size; in-flight hops on the old fabric are lost (assemblers keep
        # their received chunks, so resumed recoveries only move `missing()`).
        # Surviving edges keep their configured bandwidth (hotspot edges stay
        # throttled); newly-adjacent pairs get the default. A pod fabric is
        # rebuilt at the same pod count while the shrunk dp still divides
        # into it; otherwise it degrades to a flat ring (`_build_fabric`).
        kept_bw = {edge_key(wid_map[a], wid_map[b]): sch.bw
                   for (a, b), sch in self.topology.links.items()
                   if a in wid_map and b in wid_map}
        if isinstance(self.topology, PodFabric):
            # renumbering reshuffles which pairs are ICI vs DCN: the rebuilt
            # fabric's tier defaults are authoritative, old per-edge
            # overrides would mislabel tier bandwidths
            kept_bw = None
        self.topology = self._build_fabric(self.dp, kept_bw)
        self.transport = TopologyTransport(self.topology)
        for w in self.workers:
            w.engine.transport = self.transport
            if not w.alive:
                self.topology.fail_node(w.wid)
        # the reliability loop's index-keyed books (EWMAs, quarantines, spec
        # snapshots) are meaningless under the new numbering/fabric
        self._slow_factor.clear()
        self._spec_bw_edges.clear()
        self.last_step_times = None
        for w in self.workers:
            if w.alive:
                self.controller.beat(w.wid, now=self.sim_time)
        self.reliability.on_rescale()
        return self.dp
