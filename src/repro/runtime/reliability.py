"""Self-driving reliability controller (paper §3.3, §4.3, §6.1 + the
ByteDance gray-failure operating report in PAPERS.md).

The dormant control-plane pieces — `core/controller.py` heartbeat liveness,
`core/detection.py` detection timeline, `runtime/straggler.py` step-time
EWMAs — become one closed loop driven by the *simulated* fabric clock:

  * **liveness**: live workers beat into the `StateController`'s lock-free
    heartbeat table every iteration (sim seconds, never wall time); the
    controller scans every `scan_period` and declares a breakdown
    `notify_latency` later. Detection latency is therefore a *measured*
    simulator output, and `SimCluster.recover()` books the measured leg
    instead of the analytic `DetectionTimeline` constant.
  * **stragglers**: per-worker modeled step times feed the
    `StragglerDetector`; a persistently slow worker's role is rebound to a
    spare (`StateController.replace_worker` — the same role-rebind path a
    failover takes, minus the state loss: the straggler itself is alive and
    provides its shard), and the cluster's synchronous step time drops back
    to the healthy pace on the next iteration.
  * **gray links**: per-edge observed-vs-expected throughput. The fabric's
    schedulers account delivered TRAIN bytes and transmit seconds; an edge
    whose observed rate over a scan window falls below
    ``degraded_ratio * spec_rate`` is *quarantined* (`fail_edge`), so BFS
    routing, the allreduce, and every recovery stream reroute around it —
    detection comes from the traffic that actually crossed the wire, not
    from reading the bandwidth knob.
  * **checkpoint cadence**: detected failures timestamp an observed-MTBF
    estimate; the full-checkpoint period is re-solved (Young–Daly,
    ``sqrt(2 * ckpt_cost * MTBF)``) and pushed to every worker's
    `CkptEngine`, so a stormy epoch checkpoints more often and a quiet one
    backs off — cadence is emergent from the failure trace.

Everything here is deterministic in sim time: the same scenario replays to
the same events, latencies, and verdicts (pinned in
`tests/test_scenario_fleet.py`).

Units: seconds of simulation time, bytes, bytes/second.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.core.lccl import Edge, edge_key
from repro.runtime.straggler import StragglerDetector, StragglerPolicy


@dataclass(frozen=True)
class ReliabilityConfig:
    """Knobs of the self-driving loop. The detection triplet defaults match
    `DetectionTimeline` (heartbeat 1 s, scan 1 s, notify 50 ms) so the
    measured latency validates against the closed form out of the box."""
    heartbeat_period: float = 1.0      # worker beat cadence (sim s)
    scan_period: float = 1.0           # controller liveness-scan cadence
    notify_latency: float = 0.05       # breakdown-notification delay
    heartbeat_timeout: Optional[float] = None   # default: heartbeat_period
    # straggler mitigation
    straggler: Optional[StragglerPolicy] = None  # default StragglerPolicy()
    migrate_stragglers: bool = True
    # gray-failure (degraded link) detection
    quarantine_gray_edges: bool = True
    degraded_ratio: float = 0.5        # observed/spec rate below this = gray
    min_gray_observations: int = 2     # TRAIN transfers before judging
    # adaptive checkpoint cadence (Young–Daly on observed MTBF)
    adapt_cadence: bool = True
    ckpt_cost_s: float = 1.0           # modeled full-checkpoint cost
    min_full_every: int = 5
    max_full_every: int = 500

    @property
    def timeout(self) -> float:
        return self.heartbeat_timeout if self.heartbeat_timeout is not None \
            else self.heartbeat_period


@dataclass(frozen=True)
class ReliabilityEvent:
    """One control-plane decision, timestamped on the sim clock."""
    t: float
    kind: str        # detect | straggler_migrate | gray_edge | cadence
    detail: Dict[str, Any]


def adapted_full_interval(mtbf_s: float, ckpt_cost_s: float) -> float:
    """Young–Daly optimal checkpoint interval (seconds) for an observed
    MTBF: ``sqrt(2 * delta * MTBF)`` with `delta` the checkpoint cost."""
    return math.sqrt(2.0 * max(ckpt_cost_s, 1e-9) * max(mtbf_s, 1e-9))


def observed_mtbf(failure_times: List[float]) -> Optional[float]:
    """Mean inter-failure interval of a detection timestamp trace (needs at
    least two failures; None otherwise)."""
    if len(failure_times) < 2:
        return None
    ts = sorted(failure_times)
    return (ts[-1] - ts[0]) / (len(ts) - 1)


class ReliabilityController:
    """The closed loop. `SimCluster` owns one and ticks it every time the
    sim clock advances (each training step and each stalled idle window);
    everything the loop decides lands in `events` and mutates the cluster
    through its public surface (role rebind, edge quarantine, engine
    cadence) — never through wall time."""

    def __init__(self, cluster, cfg: Optional[ReliabilityConfig] = None):
        self.cluster = cluster
        self.cfg = cfg or ReliabilityConfig()
        self.events: List[ReliabilityEvent] = []
        self.straggler = StragglerDetector(
            cluster.dp, policy=self.cfg.straggler)
        # liveness bookkeeping
        self.failed_at: Dict[int, float] = {}     # noted failure instants
        self.detected: Dict[int, float] = {}      # wid -> detection instant
        self.detection_latencies: List[float] = []
        self.detection_times: List[float] = []    # for observed MTBF
        self._next_scan = self.cfg.scan_period
        # gray-edge bookkeeping: spec rate snapshot + per-edge counters seen
        self.quarantined: Dict[Edge, float] = {}  # edge -> spec bw
        self.tolerated: Dict[Edge, float] = {}    # gray but irreplaceable
        self._spec_bw: Dict[Edge, float] = {}
        self._seen: Dict[Edge, Tuple[float, float]] = {}
        self.resnapshot_fabric()
        # cadence
        self.current_full_every: Optional[int] = None
        self._migrations = 0
        self._rank_of: Dict[int, int] = {}   # wid -> current role-table rank

    # ------------------------- fabric snapshot ------------------------- #
    def resnapshot_fabric(self) -> None:
        """(Re)learn the fabric's spec rates — at attach and after an
        elastic rescale rebuilds the topology. The spec rate is what the
        link was *provisioned* at; later `set_bandwidth` degradations are
        exactly what the observed-throughput scan is there to catch."""
        topo = self.cluster.topology
        self._spec_bw = {e: sch.bw for e, sch in topo.links.items()}
        self._seen = {e: (sch.train_bytes_done, sch.train_tx_seconds)
                      for e, sch in topo.links.items()}

    # ------------------------- cluster callbacks ------------------------- #
    def note_failure(self, wids: List[int], t: float) -> None:
        """The cluster tells the loop WHEN something broke (fault injection
        time); the loop only finds out by scanning heartbeats."""
        for wid in wids:
            self.failed_at.setdefault(wid, t)

    def on_recovered(self, wids: List[int]) -> None:
        for wid in wids:
            self.failed_at.pop(wid, None)
            self.detected.pop(wid, None)
            if wid < len(self.straggler.count):
                self.straggler.count[wid] = 0
                self.straggler.ewma[wid] = 0.0

    def on_rescale(self) -> None:
        """Elastic shrink renumbered workers and rebuilt the fabric: every
        index-keyed book restarts (the new numbering shares nothing with
        the old)."""
        self.straggler = StragglerDetector(
            self.cluster.dp, policy=self.cfg.straggler)
        self.failed_at.clear()
        self.detected.clear()
        self.quarantined.clear()
        self.tolerated.clear()
        self._rank_of.clear()
        self.resnapshot_fabric()

    def pending_detected(self) -> List[int]:
        """Workers the loop has declared failed that are still down —
        what a self-driving runner should now recover."""
        return sorted(w for w in self.detected
                      if w < len(self.cluster.workers)
                      and not self.cluster.workers[w].alive)

    @property
    def last_detection_latency(self) -> Optional[float]:
        return self.detection_latencies[-1] if self.detection_latencies \
            else None

    # ------------------------- the loop ------------------------- #
    def tick(self, now: float) -> List[ReliabilityEvent]:
        """Advance the control loop to sim time `now`. Runs every due
        liveness scan (catching up if the clock jumped past several scan
        boundaries), then the straggler and gray-edge policies. Returns the
        events this tick produced."""
        start = len(self.events)
        while self._next_scan <= now:
            self._scan(self._next_scan)
            self._next_scan += self.cfg.scan_period
        self._observe_stragglers(now)
        return self.events[start:]

    def _scan(self, t_scan: float) -> None:
        ctl = self.cluster.controller
        fresh = [w for w in ctl.detect_failures(now=t_scan)
                 if w not in self.detected and w < len(self.cluster.workers)]
        for wid in fresh:
            t_detect = t_scan + self.cfg.notify_latency
            self.detected[wid] = t_detect
            lat = t_detect - self.failed_at[wid] \
                if wid in self.failed_at else None
            if lat is not None:
                self.detection_latencies.append(lat)
            self._emit(t_detect, "detect",
                       {"worker": wid, "latency_s": lat})
        if fresh:
            # one failure INCIDENT per scan, however many workers it took
            # down — the MTBF estimate is about events, not casualties
            self.detection_times.append(t_scan + self.cfg.notify_latency)
            # the measured detection leg replaces the analytic constant in
            # the next recover()'s timeline; the clock has ALREADY advanced
            # through it, so recover() must not re-pay it before streaming
            lat = [l for l in (self.detected[w] -
                               self.failed_at.get(w, self.detected[w])
                               for w in fresh)]
            self.cluster._measured_detection = max(lat)
            self.cluster._detection_elapsed = True
            if self.cfg.adapt_cadence:
                self._adapt_cadence(t_scan)
        self._scan_gray_edges(t_scan)

    # ------------------------- stragglers ------------------------- #
    def _observe_stragglers(self, now: float) -> None:
        last = getattr(self.cluster, "last_step_times", None)
        if not last:
            return
        for wid, dt in last.items():
            if wid < len(self.straggler.count):
                self.straggler.observe(wid, dt)
        self.cluster.last_step_times = None      # consume once
        if not self.cfg.migrate_stragglers:
            return
        for wid in self.straggler.stragglers():
            self._migrate(wid, now)

    def _migrate(self, wid: int, now: float) -> None:
        """Role-rebind mitigation: the straggler's role moves to a spare
        (rank `dp + k` in the role table — the same rebind a failover
        does), its unique shard streams over (overlapped with training,
        like lazy backup — not charged to the sync step), and the sim
        worker sheds its slowdown: it now models the spare."""
        cluster = self.cluster
        spare = cluster.dp + self._migrations
        self._migrations += 1
        role = cluster.controller.replace_worker(
            self._rank_of.get(wid, wid), spare)
        self._rank_of[wid] = spare
        cluster.clear_straggler(wid)
        self.straggler.count[wid] = 0
        self.straggler.ewma[wid] = 0.0
        self._emit(now, "straggler_migrate",
                   {"worker": wid, "spare_rank": spare,
                    "role": role.as_tuple(),
                    "shard_bytes": cluster.shard_nbytes()})

    # ------------------------- gray links ------------------------- #
    def _scan_gray_edges(self, t_scan: float) -> None:
        if not self.cfg.quarantine_gray_edges:
            return
        topo = self.cluster.topology
        for e, sch in topo.links.items():
            if e in self.quarantined or e in self.tolerated \
                    or e not in self._spec_bw:
                continue
            b0, s0 = self._seen.get(e, (0.0, 0.0))
            db = sch.train_bytes_done - b0
            ds = sch.train_tx_seconds - s0
            self._seen[e] = (sch.train_bytes_done, sch.train_tx_seconds)
            if ds <= 0 or db <= 0:
                continue
            if sch.n_finished < self.cfg.min_gray_observations:
                continue
            observed = db / ds
            spec = self._spec_bw[e]
            if observed >= self.cfg.degraded_ratio * spec:
                continue
            # quarantine ONLY if the fabric stays connected without the
            # edge: fencing the sole uplink between two pods would
            # partition the job — a slow link beats no link
            topo.fail_edge(*e)
            try:
                topo.path(*e)
                redundant = True
            except RuntimeError:
                redundant = False
                topo.restore_edge(*e)
            if redundant:
                self.quarantined[e] = spec
            else:
                self.tolerated[e] = spec
            self._emit(t_scan, "gray_edge",
                       {"edge": e, "observed_bps": observed,
                        "spec_bps": spec, "ratio": observed / spec,
                        "quarantined": redundant})

    def release_edge(self, u: int, v: int) -> None:
        """Lift a quarantine after the link is repaired (scenario heal)."""
        e = edge_key(u, v)
        if self.quarantined.pop(e, None) is not None:
            self.cluster.topology.restore_edge(*e)
        self.tolerated.pop(e, None)
        sch = self.cluster.topology.links.get(e)
        if sch is not None:
            self._seen[e] = (sch.train_bytes_done, sch.train_tx_seconds)

    # ------------------------- cadence ------------------------- #
    def _adapt_cadence(self, now: float) -> None:
        mtbf = observed_mtbf(self.detection_times)
        if mtbf is None:
            return
        interval = adapted_full_interval(mtbf, self.cfg.ckpt_cost_s)
        every = int(round(interval / max(self.cluster.t_iter_model, 1e-9)))
        every = max(self.cfg.min_full_every,
                    min(self.cfg.max_full_every, every))
        if every == self.current_full_every:
            return
        self.current_full_every = every
        for w in self.cluster.workers:
            w.engine.cfg.full_every = every
        self._emit(now, "cadence",
                   {"observed_mtbf_s": mtbf, "interval_s": interval,
                    "full_every": every})

    def _emit(self, t: float, kind: str, detail: Dict[str, Any]) -> None:
        self.events.append(ReliabilityEvent(t, kind, dict(detail)))
