"""Adversarial scenario fleet — a declarative layer over `SimCluster`.

A `Scenario` is data: the cluster shape, the reliability-loop knobs, and a
seeded list of timed `Event`s (failures, storms, gray-link degradations,
stragglers, scripted recovery attempts). `run_scenario` replays it
deterministically on the sim clock and returns a `Verdict` — rollback
count, measured detection latency, exposed seconds, migrations,
quarantines, adapted cadence — that `tests/test_scenario_fleet.py` pins
per scenario. The corpus covers the gray-failure playbook ByteDance's
infra paper says dominates real fleets (PAPERS.md): multi-wave storms,
concurrent recovery races, lazy-backup pressure during recovery, gateway
oversubscription, mid-transfer link degradation, persistent stragglers.

The runner models a synchronous job honestly: after a failure event the
training loop STALLS (the collective hangs on the dead worker) and the
clock advances in idle windows until the reliability loop's heartbeat scan
detects the breakdown — recovery then starts with the *measured* detection
leg already elapsed. Nothing reads wall time, so the same scenario always
produces the same verdict, bit for bit.

Adding a scenario: append an `Event` list to a `Scenario` in `corpus()`
(or build your own and call `run_scenario`), run it once to see the
verdict, and pin the fields you care about in the fleet test. Event
actions:

  ``fail``              params: wids, hardware=False — kill workers NOW;
                        training stalls until detection + recovery
  ``storm``             params: seed, pods=1, edge_failures=0 — seeded
                        correlated storm (`SimCluster.inject_storm`)
  ``recover``           params: FaultScript fields (hardware,
                        interrupt_after_chunks, corrupt_chunks,
                        mid_stream_degrade=(u, v, factor), degrade_at_s),
                        policy — scripted recovery attempt (waits out
                        detection first); without one, the runner
                        auto-recovers
  ``degrade_edge``      params: u, v, factor — gray failure: the link
                        silently runs at factor x its current rate
  ``heal_edge``         params: u, v — repair + lift quarantine
  ``straggler``         params: wid, factor — worker runs factor x slower
  ``clear_straggler``   params: wid
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from pathlib import Path
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.runtime.recovery import FaultScript
from repro.runtime.reliability import ReliabilityConfig

__all__ = ["Event", "Scenario", "Verdict", "run_scenario", "build_cluster",
           "corpus",
           "random_scenario", "FAST_DETECTION"]

# the corpus default: a snappy control loop (5 Hz heartbeat/scan) so a
# 10-step scenario detects and recovers in a handful of idle windows;
# detection_time() = 0.2 + 0.2 + 0.01 = 0.41 s
FAST_DETECTION = ReliabilityConfig(heartbeat_period=0.2, scan_period=0.2,
                                   notify_latency=0.01, ckpt_cost_s=0.05)


@dataclass(frozen=True)
class Event:
    """One timed action. `at_step` is the training step BEFORE which the
    event applies; same-step events apply in list order."""
    at_step: int
    action: str
    params: Tuple[Tuple[str, Any], ...] = ()

    @staticmethod
    def make(at_step: int, action: str, **params) -> "Event":
        return Event(at_step, action, tuple(sorted(params.items())))

    def kwargs(self) -> Dict[str, Any]:
        return dict(self.params)


def ev(at_step: int, action: str, **params) -> Event:
    """Shorthand constructor: ``ev(5, "fail", wids=[1])``."""
    return Event.make(at_step, action, **params)


@dataclass(frozen=True)
class Scenario:
    """A declarative adversarial run. Everything needed to reproduce it is
    in this dataclass — same scenario, same verdict."""
    name: str
    steps: int = 10
    dp: int = 4
    pods: int = 1
    global_batch: int = 8
    link_bw: float = 50e9
    dcn_bw: float = 5e9
    quantum: int = 0                    # stream chunk bytes; 0 = default
    full_every: int = 50
    t_iter: float = 0.05
    recovery: str = "stream"
    # k-path routing surface (PR 10): stripe budget for split-policy
    # streams, DCN uplinks per pod, and whether in-flight stripes
    # re-balance on a topology-epoch bump (False pins the static split)
    route_k: int = 2
    dcn_uplinks: int = 1
    rebalance: bool = True
    reliability: ReliabilityConfig = FAST_DETECTION
    events: Tuple[Event, ...] = ()
    seed: int = 0


@dataclass
class Verdict:
    """What the scenario did to the job — the pinned surface."""
    name: str
    steps_completed: int = 0
    final_iteration: int = 0
    recoveries: int = 0
    rollbacks: int = 0                  # recoveries that lost iterations
    rolled_back_iterations: int = 0
    interrupted: int = 0                # recovery attempts cut mid-transfer
    detection_latency_s: Optional[float] = None   # last measured
    detections: int = 0                 # failure incidents detected on-clock
    exposed_seconds: float = 0.0
    mitigations: int = 0                # straggler role migrations
    gray_quarantined: int = 0           # links quarantined by the loop
    gray_tolerated: int = 0             # gray but irreplaceable (no detour)
    final_full_every: Optional[int] = None        # adapted cadence, if any
    state_bytes_streamed: float = 0.0
    chunks_reused: int = 0
    recovery_total_s: float = 0.0       # sum over completed recoveries
    # k-path striping surface: wall seconds the recovery chunk streams
    # spent on the fabric (finer than recovery_total_s, which is floored
    # by pod-allocation constants), plus the transport's re-balance books
    stream_seconds: float = 0.0         # sum over all recovery attempts
    rebalances: int = 0                 # mid-transfer re-balance passes
    chunks_rebalanced: int = 0          # chunks moved between paths

    def pinned(self) -> Dict[str, Any]:
        """The deterministic comparison dict the fleet test asserts."""
        d = dataclasses.asdict(self)
        d["detection_latency_s"] = (
            None if self.detection_latency_s is None
            else round(self.detection_latency_s, 9))
        d["exposed_seconds"] = round(self.exposed_seconds, 9)
        d["state_bytes_streamed"] = round(self.state_bytes_streamed, 3)
        d["recovery_total_s"] = round(self.recovery_total_s, 9)
        d["stream_seconds"] = round(self.stream_seconds, 9)
        return d


def _tiny_arch():
    from repro.configs import get_arch, reduce_for_smoke
    return dataclasses.replace(reduce_for_smoke(get_arch("qwen3-0.6b")),
                               dtype="float32")


def build_cluster(sc: Scenario, ckpt_dir):
    """A `SimCluster` wired exactly as `run_scenario` would build it —
    public so benchmarks can drive the same loop step by step."""
    from repro.optim import AdamWConfig
    from repro.runtime.cluster import ClusterConfig, FabricConfig, SimCluster
    cc = ClusterConfig(dp=sc.dp, global_batch=sc.global_batch, seq_len=16,
                       hp=AdamWConfig(lr=1e-3, warmup_steps=2,
                                      total_steps=max(50, sc.steps + 10)),
                       ckpt_dir=Path(ckpt_dir), full_every=sc.full_every,
                       seed=sc.seed, t_iter_model=sc.t_iter)
    fc = FabricConfig(link_bw=sc.link_bw, pods=sc.pods, dcn_bw=sc.dcn_bw,
                      route_k=sc.route_k, dcn_uplinks=sc.dcn_uplinks,
                      rebalance=sc.rebalance,
                      **({"quantum": sc.quantum} if sc.quantum else {}))
    return SimCluster(_tiny_arch(), cluster=cc, fabric=fc,
                      recovery=sc.recovery, reliability=sc.reliability)


class _Runner:
    def __init__(self, sc: Scenario, cluster):
        self.sc = sc
        self.clu = cluster
        self.verdict = Verdict(name=sc.name)
        self._last_hw = False

    # ------------------------- event dispatch ------------------------- #
    def apply(self, e: Event) -> None:
        clu, kw = self.clu, e.kwargs()
        if e.action == "fail":
            self._last_hw = bool(kw.get("hardware", False))
            clu.inject_failure(list(kw["wids"]), hardware=self._last_hw)
        elif e.action == "storm":
            self._last_hw = False
            clu.inject_storm(kw["seed"], pods=kw.get("pods", 1),
                             edge_failures=kw.get("edge_failures", 0))
        elif e.action == "recover":
            self.recover_now(kw)
        elif e.action == "degrade_edge":
            clu.degrade_edge(kw["u"], kw["v"], kw["factor"])
        elif e.action == "heal_edge":
            clu.heal_edge(kw["u"], kw["v"])
        elif e.action == "straggler":
            clu.set_straggler(kw["wid"], kw["factor"])
        elif e.action == "clear_straggler":
            clu.clear_straggler(kw["wid"])
        else:
            raise ValueError(f"unknown scenario action {e.action!r}")

    # ------------------------- detection + recovery ------------------------- #
    def wait_for_detection(self) -> None:
        """Training is stalled on a dead worker: advance the clock in
        idle windows until the heartbeat scan declares the breakdown."""
        clu = self.clu
        down = [w.wid for w in clu.workers if not w.alive]
        budget = int(np.ceil(
            (clu.detection.detection_time() / clu.t_iter_model))) + 4
        for _ in range(budget):
            if set(down) <= set(clu.reliability.detected):
                break
            clu.advance_idle(clu.t_iter_model)
        else:
            raise AssertionError(
                f"{self.sc.name}: workers {down} not detected within "
                f"{budget} idle windows — the liveness loop is broken")

    def recover_now(self, kw: Dict[str, Any]) -> None:
        clu, v = self.clu, self.verdict
        if all(w.alive for w in clu.workers):
            return                      # scripted recover with nobody down
        self.wait_for_detection()
        v.detections = len(clu.reliability.detection_times)
        v.detection_latency_s = clu.reliability.last_detection_latency
        msd = kw.get("mid_stream_degrade")
        faults = FaultScript(
            hardware=bool(kw.get("hardware", self._last_hw)),
            interrupt_after_chunks=kw.get("interrupt_after_chunks"),
            corrupt_chunks=int(kw.get("corrupt_chunks", 0)),
            mid_stream_degrade=(None if msd is None else
                                (int(msd[0]), int(msd[1]), float(msd[2]))),
            degrade_at_s=float(kw.get("degrade_at_s", 0.0)))
        rep = clu.recover(faults, policy=kw.get("policy"))
        v.stream_seconds += getattr(rep, "stream_seconds", 0.0) or 0.0
        if rep.kind == "interrupted":
            v.interrupted += 1
            return
        v.recoveries += 1
        v.recovery_total_s += rep.total_time
        v.state_bytes_streamed += rep.state_bytes_streamed
        v.chunks_reused += getattr(rep, "chunks_reused", 0) or 0
        if rep.rolled_back_iterations > 0:
            v.rollbacks += 1
            v.rolled_back_iterations += rep.rolled_back_iterations

    # ------------------------- the replay ------------------------- #
    def run(self) -> Verdict:
        sc, clu, v = self.sc, self.clu, self.verdict
        by_step: Dict[int, List[Event]] = {}
        for e in sc.events:
            by_step.setdefault(e.at_step, []).append(e)
        for s in range(sc.steps):
            for e in by_step.get(s, ()):
                self.apply(e)
            if any(not w.alive for w in clu.workers):
                # no scripted recovery handled it: the job self-drives
                self.recover_now({})
            clu.step()
            v.steps_completed += 1
        v.final_iteration = clu.iteration
        v.exposed_seconds = clu.exposed_seconds
        v.mitigations = sum(1 for e in clu.reliability.events
                            if e.kind == "straggler_migrate")
        gray = [e for e in clu.reliability.events if e.kind == "gray_edge"]
        v.gray_quarantined = sum(1 for e in gray
                                 if e.detail.get("quarantined"))
        v.gray_tolerated = sum(1 for e in gray
                               if not e.detail.get("quarantined"))
        v.final_full_every = clu.reliability.current_full_every
        v.rebalances = getattr(clu.transport, "rebalances", 0)
        v.chunks_rebalanced = getattr(clu.transport, "chunks_rebalanced", 0)
        if v.detection_latency_s is None:
            v.detection_latency_s = clu.reliability.last_detection_latency
        v.detections = len(clu.reliability.detection_times)
        return v


def run_scenario(sc: Scenario, ckpt_dir="/tmp/repro_scenarios") -> Verdict:
    """Replay `sc` deterministically and return its `Verdict`."""
    clu = build_cluster(sc, Path(ckpt_dir) / sc.name)
    return _Runner(sc, clu).run()


# --------------------------------------------------------------------------- #
# The pinned corpus
# --------------------------------------------------------------------------- #
def corpus() -> List[Scenario]:
    """The adversarial fleet. Order is stable; names are the pytest ids."""
    return [
        # one clean software death: the baseline every other scenario is
        # read against — detect on-clock, stream the shard back, 0 rollback
        Scenario(name="clean_software_failure", steps=10, events=(
            ev(5, "fail", wids=[1]),
        )),
        # two failures in the same scan: one incident, one recovery racing
        # two concurrent multi-hop fetches — still 0 rollback (backups of
        # non-adjacent workers both survive)
        Scenario(name="recovery_race_concurrent", steps=10, events=(
            ev(5, "fail", wids=[1, 3]),
        )),
        # rolling two-wave storm on a pod fabric: each wave darkens a pod,
        # kills its workers (software), and leaves storm edges dark through
        # the recovery — streams detour over the DCN gateway ring
        Scenario(name="multi_wave_storm", steps=12, dp=8, pods=2,
                 global_batch=16, events=(
            ev(4, "storm", seed=3, pods=1),
            ev(8, "storm", seed=4, pods=1),
        )),
        # lazy-backup pressure: a starved fabric (200 MB/s links) makes the
        # rank-0 lazy stream and the recovery chunks fight for the wire —
        # recovery still completes without rollback, just slower
        Scenario(name="lazy_backup_pressure", steps=10, link_bw=2e8,
                 events=(
            ev(6, "fail", wids=[2]),
        )),
        # gateway oversubscription: one shared DCN uplink silently degrades
        # to 20% while cross-pod traffic rides it; the loop quarantines it
        # from observed throughput and the gateway ring reroutes the other
        # way (4 pods => the DCN ring has a detour to route through)
        Scenario(name="gateway_oversubscription", steps=12, dp=8, pods=4,
                 global_batch=16, events=(
            ev(3, "degrade_edge", u=0, v=2, factor=0.2),
        )),
        # the 2-pod variant: the degraded uplink is the ONLY path between
        # the pods — fencing it would partition the job, so the loop
        # detects the gray link but TOLERATES it (slow beats severed)
        Scenario(name="gateway_oversubscription_no_detour", steps=10, dp=8,
                 pods=2, global_batch=16, events=(
            ev(3, "degrade_edge", u=0, v=4, factor=0.2),
        )),
        # mid-transfer degradation: recovery is interrupted after 2 chunks
        # (16 KiB chunking makes the shard a many-chunk stream), then the
        # resumed recovery's delivery link browns out UNDER the in-flight
        # stream — the transport re-balances the not-yet-started chunks
        # onto the surviving ring direction (slow links so the state leg
        # dominates and the re-balance is visible in recovery_total_s)
        Scenario(name="mid_transfer_degradation", steps=10, link_bw=2e8,
                 quantum=1 << 14, events=(
            ev(5, "fail", wids=[1]),
            ev(5, "recover", interrupt_after_chunks=2),
            ev(5, "recover", mid_stream_degrade=(1, 2, 0.05),
               degrade_at_s=3e-4),
        )),
        # the same brown-out with re-balancing DISABLED: chunks stay
        # pinned to their original paths and ride out the degraded wire —
        # the static-2-path baseline the re-balanced verdict is read
        # against (recovery_total_s strictly larger)
        Scenario(name="mid_transfer_degradation_static", steps=10,
                 link_bw=2e8, quantum=1 << 14, rebalance=False, events=(
            ev(5, "fail", wids=[1]),
            ev(5, "recover", interrupt_after_chunks=2),
            ev(5, "recover", mid_stream_degrade=(1, 2, 0.05),
               degrade_at_s=3e-4),
        )),
        # k>2 striping: with 4 DCN uplinks per pod every node is a
        # gateway, so the cross-pod stream 4 -> 3 has THREE edge-disjoint
        # paths (node 4's full fabric degree) and a route_k=3 budget
        # stripes the shard across all of them
        Scenario(name="cross_pod_k3_stripe", steps=10, dp=8, pods=2,
                 global_batch=16, dcn_bw=1e8, dcn_uplinks=4, route_k=3,
                 quantum=1 << 14, events=(
            ev(5, "fail", wids=[3]),
        )),
        # k>2 re-balancing: the same 3-path stripe loses most of its
        # primary DCN uplink mid-transfer; the remaining chunks re-balance
        # onto the two surviving paths' residual capacity
        Scenario(name="cross_pod_k3_rebalance", steps=10, dp=8, pods=2,
                 global_batch=16, dcn_bw=1e8, dcn_uplinks=4, route_k=3,
                 quantum=1 << 14, events=(
            ev(5, "fail", wids=[3]),
            ev(5, "recover", mid_stream_degrade=(0, 4, 0.1),
               degrade_at_s=1e-4),
        )),
        # a persistent 2x straggler: EWMAs flag it after min_observations
        # steps and its role migrates to a spare — the cluster's step time
        # returns to the healthy pace (speedup == straggler factor)
        Scenario(name="persistent_straggler", steps=12, events=(
            ev(3, "straggler", wid=2, factor=2.0),
        )),
        # a gray ICI link at 30% of spec: quarantined from observed
        # throughput; training (and any later recovery) routes around it
        Scenario(name="gray_link_degradation", steps=10, events=(
            ev(3, "degrade_edge", u=2, v=3, factor=0.3),
        )),
        # two failure incidents => an observed MTBF => Young–Daly cadence
        # pushed to every worker's checkpoint engine
        Scenario(name="adaptive_cadence", steps=14, events=(
            ev(4, "fail", wids=[1]),
            ev(10, "fail", wids=[3]),
        )),
        # adjacent double HARDWARE failure under the stream policy: worker
        # 1's backup lived in worker 2's host RAM — both gone, multi-level
        # insurance falls back to the periodic full checkpoint WITH rollback
        Scenario(name="hardware_double_stream_rollback", steps=10,
                 full_every=4, events=(
            ev(7, "fail", wids=[1, 2], hardware=True),
        )),
        # the same double hardware failure under ComputeRecovery: neighbors
        # replay compute, zero bytes streamed, zero rollback — exactly
        # where FCR/"all is not lost" predicts checkpoint-free survival
        Scenario(name="hardware_double_compute_free", steps=10,
                 full_every=4, recovery="compute", events=(
            ev(7, "fail", wids=[1, 2], hardware=True),
        )),
    ]


def random_scenario(seed: int) -> Scenario:
    """A seeded random adversarial scenario (hypothesis sweep): software
    failures, stragglers, and gray links only — the regime where FCR
    predicts every recovery is rollback-free. Pure function of `seed`."""
    rng = np.random.default_rng(seed)
    steps = int(rng.integers(7, 12))
    events: List[Event] = []
    wids = list(rng.permutation(np.arange(1, 4)))
    n_events = int(rng.integers(1, 3))
    used_steps: set = set()
    for i in range(n_events):
        s = int(rng.integers(2, steps - 1))
        while s in used_steps:
            s = int(rng.integers(2, steps - 1))
        used_steps.add(s)
        kind = int(rng.integers(0, 3))
        if kind == 0:
            events.append(ev(s, "fail", wids=[int(wids[i])]))
        elif kind == 1:
            events.append(ev(s, "straggler", wid=int(wids[i]),
                             factor=float(rng.uniform(1.8, 3.0))))
        else:
            u = int(rng.integers(0, 4))
            events.append(ev(s, "degrade_edge", u=u, v=(u + 1) % 4,
                             factor=float(rng.uniform(0.1, 0.4))))
    events.sort(key=lambda e: e.at_step)
    return Scenario(name=f"random_{seed}", steps=steps,
                    events=tuple(events), seed=seed)
