"""Pure-jnp oracles for every Pallas kernel (assert_allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def flash_attention_ref(q, k, v, *, causal: bool = True):
    """Dense masked attention; same math as the flash kernel."""
    from repro.models.attention import dense_attention
    return dense_attention(q, k, v, causal=causal)


def decode_attention_ref(q, k_cache, v_cache, cur_len, num_heads=None):
    from repro.models.attention import decode_attention as da
    return da(q, k_cache, v_cache, cur_len,
              num_heads or q.shape[2])


def ssd_ref(x, dt, a, b_mat, c_mat, *, chunk: int = 256, initial_state=None):
    """Sequential chunked SSD (repro.models.mamba2) — the training oracle."""
    from repro.models.mamba2 import ssd_chunked
    return ssd_chunked(x, dt, a, b_mat, c_mat, chunk=chunk,
                       initial_state=initial_state)


def ssd_recurrent_ref(x, dt, a, b_mat, c_mat, initial_state=None):
    """O(S) token-by-token recurrence — the ground-truth semantics both the
    chunked form and the kernel must match."""
    bsz, s, h, p = x.shape
    n = b_mat.shape[-1]
    state = (jnp.zeros((bsz, h, n, p), jnp.float32)
             if initial_state is None else initial_state)
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    af = a.astype(jnp.float32)
    bf = b_mat.astype(jnp.float32)
    cf = c_mat.astype(jnp.float32)

    def step(state, inputs):
        x_t, dt_t, b_t, c_t = inputs
        decay = jnp.exp(dt_t * af[None, :])                    # (B, H)
        upd = jnp.einsum("bh,bn,bhp->bhnp", dt_t, b_t, x_t)
        state = decay[..., None, None] * state + upd
        y = jnp.einsum("bn,bhnp->bhp", c_t, state)
        return state, y

    xs = (xf.transpose(1, 0, 2, 3), dtf.transpose(1, 0, 2),
          bf.transpose(1, 0, 2), cf.transpose(1, 0, 2))
    final, ys = jax.lax.scan(step, state, xs)
    return ys.transpose(1, 0, 2, 3).astype(x.dtype), final
