"""Pallas TPU flash-attention forward kernel.

Tiling: grid = (B*H, Sq/BQ); each grid cell holds one (BQ, hd) query tile in
VMEM and streams KV in (BK, hd) tiles with online-softmax accumulators in
fp32 VREGs. BQ/BK default 128/256 — MXU-aligned (multiples of 128 on the
contracting/lane dims); the VMEM working set is
BQ*hd + 2*BK*hd + BQ*BK floats, far under the ~16 MB/core budget.

Validated against the pure-jnp oracle (repro.kernels.ref / dense_attention)
in interpret mode across shape/dtype sweeps; used for training via
jax.custom_vjp with a rematerializing blockwise backward.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

_NEG_INF = -1e30


def _flash_fwd_kernel(q_ref, k_ref, v_ref, o_ref, *, causal: bool, sq: int,
                      skv: int, bq: int, bk: int, scale: float):
    qi = pl.program_id(1)
    q = q_ref[0].astype(jnp.float32) * scale            # (BQ, hd)
    hd = q.shape[-1]
    n_kv = skv // bk

    def body(j, carry):
        acc, m_i, l_i = carry
        k = k_ref[0, pl.ds(j * bk, bk), :].astype(jnp.float32)   # (BK, hd)
        v = v_ref[0, pl.ds(j * bk, bk), :].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # (BQ, BK)
        if causal:
            q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            k_pos = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(q_pos >= k_pos, s, _NEG_INF)
        m_new = jnp.maximum(m_i, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_i - m_new)
        acc = acc * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())))
        l_i = l_i * corr + jnp.sum(p, axis=1)
        return acc, m_new, l_i

    if causal:
        # skip blocks strictly above the diagonal
        last = jnp.minimum(((qi + 1) * bq + bk - 1) // bk, n_kv)
    else:
        last = n_kv
    acc, m_i, l_i = jax.lax.fori_loop(
        0, last, body,
        (jnp.zeros((bq, hd), jnp.float32),
         jnp.full((bq,), _NEG_INF, jnp.float32),
         jnp.zeros((bq,), jnp.float32)))
    o_ref[0] = (acc / jnp.maximum(l_i, 1e-30)[:, None]).astype(o_ref.dtype)


def flash_attention_fwd(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool = True, bq: int = 128, bk: int = 256,
                        interpret: bool = True) -> jax.Array:
    """q: (B, Sq, H, hd); k/v: (B, Skv, H, hd) (kv already head-repeated).
    Returns (B, Sq, H, hd)."""
    b, sq, h, hd = q.shape
    skv = k.shape[1]
    bq = min(bq, sq)
    bk = min(bk, skv)
    assert sq % bq == 0 and skv % bk == 0, "seq dims must tile evenly"
    scale = 1.0 / np.sqrt(hd)

    qr = q.transpose(0, 2, 1, 3).reshape(b * h, sq, hd)
    kr = k.transpose(0, 2, 1, 3).reshape(b * h, skv, hd)
    vr = v.transpose(0, 2, 1, 3).reshape(b * h, skv, hd)

    kernel = functools.partial(_flash_fwd_kernel, causal=causal, sq=sq,
                               skv=skv, bq=bq, bk=bk, scale=scale)
    out = pl.pallas_call(
        kernel,
        grid=(b * h, sq // bq),
        in_specs=[
            pl.BlockSpec((1, bq, hd), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, skv, hd), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((1, skv, hd), lambda i, j: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, hd), lambda i, j: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, sq, hd), q.dtype),
        interpret=interpret,
    )(qr, kr, vr)
    return out.reshape(b, h, sq, hd).transpose(0, 2, 1, 3)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def flash_attention(q, k, v, causal: bool = True, bq: int = 128,
                    bk: int = 256, interpret: bool = True):
    return flash_attention_fwd(q, k, v, causal=causal, bq=bq, bk=bk,
                               interpret=interpret)


def _fwd(q, k, v, causal, bq, bk, interpret):
    o = flash_attention_fwd(q, k, v, causal=causal, bq=bq, bk=bk,
                            interpret=interpret)
    return o, (q, k, v)


def _bwd(causal, bq, bk, interpret, res, do):
    """Rematerializing backward: re-derive gradients with the blockwise
    reference (pure-jnp oracle) — numerically the same attention."""
    q, k, v = res
    from repro.models.attention import blockwise_attention

    def f(q, k, v):
        return blockwise_attention(q, k, v, causal=causal, kv_block=bk)

    _, vjp = jax.vjp(f, q, k, v)
    return vjp(do)


flash_attention.defvjp(_fwd, _bwd)
