"""jit'd public wrappers for the Pallas kernels.

``interpret`` defaults to True off-TPU (the kernel body executes in Python
via the Pallas interpreter — correctness path); on TPU backends it compiles
to Mosaic."""
from __future__ import annotations

import functools

import jax

from repro.kernels import decode_attn as _dec
from repro.kernels import flash_attention as _fa
from repro.kernels import ssd as _ssd


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("causal", "bq", "bk"))
def flash_attention(q, k, v, *, causal: bool = True, bq: int = 128,
                    bk: int = 256):
    return _fa.flash_attention(q, k, v, causal, bq, bk, _default_interpret())


@functools.partial(jax.jit, static_argnames=("chunk", "head_tile"))
def ssd(x, dt, a, b_mat, c_mat, *, chunk: int = 256, head_tile: int = 8):
    return _ssd.ssd(x, dt, a, b_mat, c_mat, chunk=chunk, head_tile=head_tile,
                    interpret=_default_interpret())


@functools.partial(jax.jit, static_argnames=("bt",))
def decode_attention(q, k_cache, v_cache, cur_len, *, bt: int = 512):
    return _dec.decode_attention(q, k_cache, v_cache, cur_len, bt=bt,
                                 interpret=_default_interpret())
