"""Pallas TPU GQA decode-attention kernel (one query token vs. a long KV
cache).

Grid = (B,); the kernel streams the cache in (BT, K, hd) tiles with an
online-softmax accumulator per q head — decode is HBM-bandwidth-bound, so the
tile loop is exactly the cache read stream. The current length arrives as a
scalar-prefetch operand (SMEM) used to mask the tail tile.

GQA mapping: q heads grouped G = H/K per kv head; scores computed as
(K, G, hd) x (K, hd) contractions so the kv tile is read once per group.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

_NEG_INF = -1e30


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, *, t: int, bt: int,
                   kh: int, g: int, hd: int, scale: float):
    cur_len = len_ref[0]
    q = q_ref[0].astype(jnp.float32) * scale         # (H, hd) = (K*G, hd)
    qg = q.reshape(kh, g, hd)
    n_t = t // bt

    def body(j, carry):
        acc, m_i, l_i = carry                        # (K,G,hd) (K,G) (K,G)
        k = k_ref[0, pl.ds(j * bt, bt), :, :].astype(jnp.float32)  # (BT,K,hd)
        v = v_ref[0, pl.ds(j * bt, bt), :, :].astype(jnp.float32)
        s = jnp.einsum("kgd,tkd->kgt", qg, k)        # (K, G, BT)
        pos = j * bt + jax.lax.broadcasted_iota(jnp.int32, (kh, g, bt), 2)
        s = jnp.where(pos < cur_len, s, _NEG_INF)
        m_new = jnp.maximum(m_i, jnp.max(s, axis=2))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m_i - m_new)
        acc = acc * corr[..., None] + jnp.einsum("kgt,tkd->kgd", p, v)
        l_i = l_i * corr + jnp.sum(p, axis=2)
        return acc, m_new, l_i

    # only tiles below cur_len contribute
    last = jnp.minimum((cur_len + bt - 1) // bt, n_t)
    acc, m_i, l_i = jax.lax.fori_loop(
        0, last, body,
        (jnp.zeros((kh, g, hd), jnp.float32),
         jnp.full((kh, g), _NEG_INF, jnp.float32),
         jnp.zeros((kh, g), jnp.float32)))
    out = acc / jnp.maximum(l_i, 1e-30)[..., None]
    o_ref[0] = out.reshape(kh * g, hd).astype(o_ref.dtype)


def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     cur_len: jax.Array, *, bt: int = 512,
                     interpret: bool = True) -> jax.Array:
    """q: (B, 1, H, hd); caches: (B, T, K, hd); cur_len: () int32.
    Returns (B, 1, H, hd)."""
    b, _, h, hd = q.shape
    t, kh = k_cache.shape[1], k_cache.shape[2]
    g = h // kh
    bt = min(bt, t)
    assert t % bt == 0
    scale = 1.0 / np.sqrt(hd)

    kernel = functools.partial(_decode_kernel, t=t, bt=bt, kh=kh, g=g, hd=hd,
                               scale=scale)
    lens = jnp.broadcast_to(jnp.asarray(cur_len, jnp.int32), (b,))
    out = pl.pallas_call(
        kernel,
        grid=(b,),
        in_specs=[
            pl.BlockSpec((1,), lambda i: (i,), memory_space=pl.ANY),
            pl.BlockSpec((1, h, hd), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, t, kh, hd), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((1, t, kh, hd), lambda i: (i, 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, h, hd), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, hd), q.dtype),
        interpret=interpret,
    )(lens, q[:, 0], k_cache, v_cache)
    return out[:, None]
