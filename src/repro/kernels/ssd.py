"""Pallas TPU kernel for the Mamba2 SSD intra-chunk block.

Per grid cell (batch b, chunk c, head-tile h): computes the quadratic
intra-chunk output, the chunk's end-state contribution, and the chunk decay —
the (Lc, Lc) score tile lives only in VMEM (the pure-JAX form materializes it
in HBM per chunk). The cheap inter-chunk recurrence (combine over chunk
states) stays in JAX (associative scan) — same split as the Mamba2 paper's
SSD algorithm.

Tile sizes: Lc=ssm_chunk (256 default), head tile HT=8, state N<=128, head
dim P=64: VMEM = Lc*HT*P (x) + Lc*N (B,C) + Lc^2 (per-head scores) floats
~= 1.3 MB. All matmul dims multiples of 64/128 for the MXU.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ssd_chunk_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref,
                      y_ref, st_ref, dec_ref, *, lc: int, ht: int):
    x = x_ref[0].astype(jnp.float32)          # (Lc, HT, P)
    dt = dt_ref[0].astype(jnp.float32)        # (Lc, HT)
    a = a_ref[:]                              # (HT,)
    bm = b_ref[0].astype(jnp.float32)         # (Lc, N)
    cm = c_ref[0].astype(jnp.float32)         # (Lc, N)

    da = dt * a[None, :]                      # (Lc, HT)
    cs = jnp.cumsum(da, axis=0)
    cb = jax.lax.dot_general(cm, bm, (((1,), (1,)), ((), ())))  # (Lc, Lc)
    idx = jax.lax.broadcasted_iota(jnp.int32, (lc, lc), 0)
    jdx = jax.lax.broadcasted_iota(jnp.int32, (lc, lc), 1)
    causal = idx >= jdx
    last = cs[-1, :]                          # (HT,)

    def per_head(h, _):
        decay = jnp.exp(cs[:, None, h] - cs[None, :, h])         # (Lc, Lc)
        att = jnp.where(causal, cb * decay * dt[None, :, h], 0.0)
        y_h = jax.lax.dot_general(att, x[:, h, :],
                                  (((1,), (0,)), ((), ())))      # (Lc, P)
        y_ref[0, :, h, :] = y_h.astype(y_ref.dtype)
        w = dt[:, h] * jnp.exp(last[h] - cs[:, h])               # (Lc,)
        st_h = jax.lax.dot_general(bm * w[:, None], x[:, h, :],
                                   (((0,), (0,)), ((), ())))     # (N, P)
        st_ref[0, h, :, :] = st_h
        return 0

    jax.lax.fori_loop(0, ht, per_head, 0)
    dec_ref[0] = jnp.exp(last)


def ssd_intra_chunk(x: jax.Array, dt: jax.Array, a: jax.Array,
                    b_mat: jax.Array, c_mat: jax.Array, *, chunk: int,
                    head_tile: int = 8, interpret: bool = True
                    ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """x: (B, S, H, P); dt: (B, S, H) (post-softplus); a: (H,) negative;
    b/c: (B, S, N). S must divide by chunk, H by head_tile.
    Returns (y_intra (B,S,H,P), chunk_states (B,NC,H,N,P), decay (B,NC,H))."""
    bsz, s, h, p = x.shape
    n = b_mat.shape[-1]
    lc = min(chunk, s)
    assert s % lc == 0 and h % head_tile == 0
    nc = s // lc
    ht = head_tile

    xr = x.reshape(bsz * nc, lc, h, p)
    dtr = dt.reshape(bsz * nc, lc, h)
    br = b_mat.reshape(bsz * nc, lc, n)
    cr = c_mat.reshape(bsz * nc, lc, n)

    kernel = functools.partial(_ssd_chunk_kernel, lc=lc, ht=ht)
    y, states, decay = pl.pallas_call(
        kernel,
        grid=(bsz * nc, h // ht),
        in_specs=[
            pl.BlockSpec((1, lc, ht, p), lambda i, j: (i, 0, j, 0)),
            pl.BlockSpec((1, lc, ht), lambda i, j: (i, 0, j)),
            pl.BlockSpec((ht,), lambda i, j: (j,)),
            pl.BlockSpec((1, lc, n), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((1, lc, n), lambda i, j: (i, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, lc, ht, p), lambda i, j: (i, 0, j, 0)),
            pl.BlockSpec((1, ht, n, p), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, ht), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz * nc, lc, h, p), x.dtype),
            jax.ShapeDtypeStruct((bsz * nc, h, n, p), jnp.float32),
            jax.ShapeDtypeStruct((bsz * nc, h), jnp.float32),
        ],
        interpret=interpret,
    )(xr, dtr, a.astype(jnp.float32), br, cr)

    return (y.reshape(bsz, s, h, p),
            states.reshape(bsz, nc, h, n, p),
            decay.reshape(bsz, nc, h))


def ssd(x: jax.Array, dt: jax.Array, a: jax.Array, b_mat: jax.Array,
        c_mat: jax.Array, *, chunk: int = 256, head_tile: int = 8,
        initial_state=None, interpret: bool = True):
    """Full SSD = Pallas intra-chunk kernel + JAX inter-chunk combine.
    Matches repro.models.mamba2.ssd_chunked (the oracle)."""
    bsz, s, h, p = x.shape
    n = b_mat.shape[-1]
    lc = min(chunk, s)
    pad = (-s) % lc
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        b_mat = jnp.pad(b_mat, ((0, 0), (0, pad), (0, 0)))
        c_mat = jnp.pad(c_mat, ((0, 0), (0, pad), (0, 0)))
    sp = s + pad
    nc = sp // lc

    y_intra, chunk_states, chunk_decay = ssd_intra_chunk(
        x, dt, a, b_mat, c_mat, chunk=lc, head_tile=head_tile,
        interpret=interpret)

    if initial_state is None:
        initial_state = jnp.zeros((bsz, h, n, p), jnp.float32)

    # inter-chunk: inclusive associative scan over (decay, state)
    def combine(u, w):
        d1, s1 = u
        d2, s2 = w
        return d1 * d2, s1 * d2[..., None, None] + s2

    dec_sw = jnp.moveaxis(chunk_decay, 1, 0)
    st_sw = jnp.moveaxis(chunk_states, 1, 0)
    run_dec, run_st = jax.lax.associative_scan(combine, (dec_sw, st_sw))
    init = initial_state
    prev = jnp.concatenate(
        [init[None], run_st[:-1] + run_dec[:-1][..., None, None] * init[None]],
        axis=0)                                       # (NC, B, H, N, P)
    prev = jnp.moveaxis(prev, 0, 1)

    # y_inter = C_i . S_prev * exp(cs_i) — cs recomputed cheaply in fp32
    da = (dt.astype(jnp.float32) * a.astype(jnp.float32)[None, None, :]
          ).reshape(bsz, nc, lc, h)
    cs = jnp.cumsum(da, axis=2)
    cm = c_mat.astype(jnp.float32).reshape(bsz, nc, lc, n)
    y_inter = jnp.einsum("bcin,bchnp->bcihp", cm, prev) * \
        jnp.exp(cs)[..., None]
    y = y_intra.astype(jnp.float32) + \
        y_inter.reshape(bsz, sp, h, p)[:, :, :, :]
    y = y.reshape(bsz, sp, h, p)[:, :s]
    final_state = run_st[-1] + run_dec[-1][..., None, None] * init
    return y.astype(x.dtype), final_state
