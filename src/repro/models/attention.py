"""Attention: blockwise (flash-style) training/prefill attention, KV-cache decode,
and cross-attention.

The training/prefill path is a pure-JAX online-softmax scan over KV blocks — the
TPU-idiomatic formulation (bounded VMEM working set, MXU-aligned blocks). It is
also the numerical oracle for the Pallas flash kernel in ``repro.kernels``.

Head layout: q is (B, S, H, hd); k/v are stored with K kv-heads and repeated to H
on the fly (a local broadcast when kv-heads are replicated or evenly sharded —
no resharding collective is induced; see DESIGN.md §4).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.layers import apply_rope, head_rms_norm

_NEG_INF = -1e30


def repeat_kv(x: jax.Array, num_heads: int) -> jax.Array:
    """(B, S, K, hd) -> (B, S, H, hd) by repeating each kv head H//K times."""
    b, s, k, hd = x.shape
    if k == num_heads:
        return x
    reps = num_heads // k
    return jnp.repeat(x, reps, axis=2)


def dense_attention(q, k, v, *, causal: bool, q_offset: int = 0) -> jax.Array:
    """Reference masked attention (materializes scores). Identical math to
    blockwise_attention; used in analysis mode and as the kernel oracle."""
    b, sq, h, hd = q.shape
    skv = k.shape[1]
    scale = 1.0 / np.sqrt(hd)
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32) * scale,
                   k.astype(jnp.float32))
    if causal:
        q_pos = q_offset + jnp.arange(sq)
        mask = q_pos[:, None] >= jnp.arange(skv)[None, :]
        s = jnp.where(mask[None, None], s, _NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


def blockwise_attention(
    q: jax.Array,            # (B, Sq, H, hd)
    k: jax.Array,            # (B, Skv, H, hd)  (already repeated)
    v: jax.Array,            # (B, Skv, H, hd)
    *,
    causal: bool,
    q_offset: int = 0,       # absolute position of q[0] (prefill continuation)
    kv_block: int = 1024,
) -> jax.Array:
    """Online-softmax attention, scanning KV in blocks. fp32 accumulators.

    Analysis mode uses the dense masked form (identical FLOPs; its backward
    all-reduces make the reported collective term an UPPER BOUND on the
    production blockwise form — both measured, EXPERIMENTS.md §Perf)."""
    from repro.models.modes import in_analysis_mode
    if in_analysis_mode():
        return dense_attention(q, k, v, causal=causal, q_offset=q_offset)
    b, sq, h, hd = q.shape
    skv = k.shape[1]
    kv_block = min(kv_block, skv)
    n_blocks = (skv + kv_block - 1) // kv_block
    pad = n_blocks * kv_block - skv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))

    scale = 1.0 / np.sqrt(hd)
    qf = (q.astype(jnp.float32) * scale).transpose(0, 2, 1, 3)  # (B,H,Sq,hd)
    kb = k.transpose(0, 2, 1, 3).reshape(b, h, n_blocks, kv_block, hd)
    vb = v.transpose(0, 2, 1, 3).reshape(b, h, n_blocks, kv_block, hd)
    q_pos = q_offset + jnp.arange(sq)

    def body(carry, inputs):
        acc, row_max, row_sum = carry
        blk_idx, k_blk, v_blk = inputs
        kv_pos = blk_idx * kv_block + jnp.arange(kv_block)
        s = jnp.einsum("bhqd,bhkd->bhqk", qf, k_blk.astype(jnp.float32))
        mask = kv_pos[None, :] < skv  # padding
        if causal:
            mask = mask & (q_pos[:, None] >= kv_pos[None, :])
        s = jnp.where(mask[None, None], s, _NEG_INF)
        blk_max = jnp.max(s, axis=-1)
        new_max = jnp.maximum(row_max, blk_max)
        corr = jnp.exp(row_max - new_max)
        p = jnp.exp(s - new_max[..., None])
        acc = acc * corr[..., None] + jnp.einsum(
            "bhqk,bhkd->bhqd", p, v_blk.astype(jnp.float32))
        row_sum = row_sum * corr + jnp.sum(p, axis=-1)
        return (acc, new_max, row_sum), None

    init = (
        jnp.zeros((b, h, sq, hd), jnp.float32),
        jnp.full((b, h, sq), _NEG_INF, jnp.float32),
        jnp.zeros((b, h, sq), jnp.float32),
    )
    # remat the block body: without this the scan saves the fp32 (B,H,Sq,BK)
    # score/prob tensors of EVERY block for backward (measured ~17 GB/device
    # at deepseek-67b train_4k; with remat only the (B,H,Sq,hd) carries stack)
    (acc, _, row_sum), _ = jax.lax.scan(
        jax.checkpoint(body, prevent_cse=False), init,
        (jnp.arange(n_blocks), kb.transpose(2, 0, 1, 3, 4),
         vb.transpose(2, 0, 1, 3, 4)),
    )
    out = acc / jnp.maximum(row_sum[..., None], 1e-30)
    return out.transpose(0, 2, 1, 3).astype(q.dtype)  # (B,Sq,H,hd)


def decode_attention(
    q: jax.Array,          # (B, 1, H, hd)
    k_cache: jax.Array,    # (B, T, K, hd)
    v_cache: jax.Array,    # (B, T, K, hd)
    cur_len: jax.Array,    # () int32 — number of valid cache positions
    num_heads: int,
) -> jax.Array:
    from repro.parallel.constraints import BATCH, constrain
    b, t, kh, hd = k_cache.shape
    k = repeat_kv(k_cache, num_heads)
    v = repeat_kv(v_cache, num_heads)
    scale = 1.0 / np.sqrt(hd)
    # flash-decode sharding: keep scores SEQUENCE-sharded over "model" (the
    # cache's layout) — XLA then all-gathers the tiny q heads instead of
    # replicating the multi-GB cache (observed "involuntary full
    # rematerialization" warnings + 75 ms/step collective otherwise); the
    # softmax reduction becomes a cheap cross-shard psum.
    q = constrain((q.astype(jnp.float32) * scale).astype(k.dtype),
                  BATCH, None, None, None)
    # MXU-native mixed precision: bf16 inputs, fp32 accumulation — never
    # materializes an fp32 copy of the (B, T, H, hd) repeated cache
    s = jnp.einsum("bqhd,bthd->bhqt", q, k,
                   preferred_element_type=jnp.float32)
    # match the cache's sequence sharding: batch=1 caches (long_500k) shard T
    # over ("data","model"); batched decode shards T over "model" only
    t_parts = ("data", "model") if b == 1 else "model"
    s = constrain(s, BATCH, None, None, t_parts)
    mask = jnp.arange(t)[None, None, None, :] < cur_len
    s = jnp.where(mask, s, _NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqt,bthd->bqhd", p.astype(k.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.astype(k_cache.dtype)


# --------------------------------------------------------------------------- #
# Full attention sub-block (projections + rope + attention + out-proj)
# --------------------------------------------------------------------------- #
def attn_init(key, cfg, dtype) -> Dict:
    hd = cfg.resolved_head_dim
    h, kh, d = cfg.num_heads, cfg.num_kv_heads, cfg.d_model
    ks = jax.random.split(key, 4)
    sc = 1.0 / np.sqrt(d)
    so = 1.0 / np.sqrt(h * hd)
    p = {
        "wq": (jax.random.normal(ks[0], (d, h * hd)) * sc).astype(dtype),
        "wk": (jax.random.normal(ks[1], (d, kh * hd)) * sc).astype(dtype),
        "wv": (jax.random.normal(ks[2], (d, kh * hd)) * sc).astype(dtype),
        "wo": (jax.random.normal(ks[3], (h * hd, d)) * so).astype(dtype),
    }
    if cfg.use_qk_norm:
        p["q_norm"] = jnp.zeros((hd,), dtype)
        p["k_norm"] = jnp.zeros((hd,), dtype)
    return p


def _project_qkv(p: Dict, cfg, x: jax.Array, positions: jax.Array,
                 *, rope: bool = True) -> Tuple[jax.Array, jax.Array, jax.Array]:
    b, s, _ = x.shape
    hd = cfg.resolved_head_dim
    q = (x @ p["wq"]).reshape(b, s, cfg.num_heads, hd)
    k = (x @ p["wk"]).reshape(b, s, cfg.num_kv_heads, hd)
    v = (x @ p["wv"]).reshape(b, s, cfg.num_kv_heads, hd)
    if cfg.use_qk_norm:
        q = head_rms_norm(q, p["q_norm"])
        k = head_rms_norm(k, p["k_norm"])
    if rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _ulysses(q, k, v):
    """Sequence->head resharding (DeepSpeed-Ulysses style) — REFUTED under
    XLA's pre-Shardy auto-partitioner: the head-sharding constraints added
    ~13.9 GB/layer of all-to-alls WITHOUT removing the partial-sum
    all-reduces (the KV repeat broadcast defeats the partitioner; measured,
    EXPERIMENTS.md §Perf). Kept as an identity hook for when Shardy lands."""
    return q, k, v


def self_attention(p: Dict, cfg, x: jax.Array, *, causal: bool = True,
                   rope: bool = True, kv_block: int = 1024) -> jax.Array:
    b, s, _ = x.shape
    positions = jnp.arange(s)
    q, k, v = _project_qkv(p, cfg, x, positions, rope=rope)
    k = repeat_kv(k, cfg.num_heads)
    v = repeat_kv(v, cfg.num_heads)
    q, k, v = _ulysses(q, k, v)
    out = blockwise_attention(q, k, v, causal=causal, kv_block=kv_block)
    return out.reshape(b, s, -1) @ p["wo"]


def self_attention_prefill(p: Dict, cfg, x: jax.Array, cache_len: int,
                           kv_block: int = 1024):
    """Returns (out, (k_cache, v_cache)) with caches padded to ``cache_len``."""
    b, s, _ = x.shape
    positions = jnp.arange(s)
    q, k, v = _project_qkv(p, cfg, x, positions)
    qh, kh, vh = _ulysses(q, repeat_kv(k, cfg.num_heads),
                          repeat_kv(v, cfg.num_heads))
    out = blockwise_attention(qh, kh, vh, causal=True, kv_block=kv_block)
    out = out.reshape(b, s, -1) @ p["wo"]
    pad = cache_len - s
    k_c = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
    v_c = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    return out, (k_c, v_c)


def self_attention_decode(p: Dict, cfg, x: jax.Array, cache: Tuple,
                          index: jax.Array):
    """One-token decode. x: (B, 1, D); cache: (k,v) each (B, T, K, hd);
    index: () current position. Returns (out, new_cache)."""
    b = x.shape[0]
    hd = cfg.resolved_head_dim
    positions = jnp.full((1,), index, jnp.int32)
    q, k, v = _project_qkv(p, cfg, x, positions)
    k_cache, v_cache = cache
    k_cache = jax.lax.dynamic_update_slice(k_cache, k, (0, index, 0, 0))
    v_cache = jax.lax.dynamic_update_slice(v_cache, v, (0, index, 0, 0))
    out = decode_attention(q, k_cache, v_cache, index + 1, cfg.num_heads)
    out = out.reshape(b, 1, -1) @ p["wo"]
    return out, (k_cache, v_cache)


# --------------------------------------------------------------------------- #
# Cross-attention (Whisper decoder)
# --------------------------------------------------------------------------- #
def cross_attn_init(key, cfg, dtype) -> Dict:
    return attn_init(key, cfg, dtype)


def cross_attention(p: Dict, cfg, x: jax.Array, enc_kv: Tuple[jax.Array, jax.Array],
                    ) -> jax.Array:
    """x: (B, S, D); enc_kv: precomputed (k, v) each (B, Senc, K, hd)."""
    b, s, _ = x.shape
    hd = cfg.resolved_head_dim
    q = (x @ p["wq"]).reshape(b, s, cfg.num_heads, hd)
    k, v = enc_kv
    qh, kh, vh = _ulysses(q, repeat_kv(k, cfg.num_heads),
                          repeat_kv(v, cfg.num_heads))
    out = blockwise_attention(qh, kh, vh, causal=False)
    return out.reshape(b, s, -1) @ p["wo"]


def cross_kv(p: Dict, cfg, enc_out: jax.Array) -> Tuple[jax.Array, jax.Array]:
    b, s, _ = enc_out.shape
    hd = cfg.resolved_head_dim
    k = (enc_out @ p["wk"]).reshape(b, s, cfg.num_kv_heads, hd)
    v = (enc_out @ p["wv"]).reshape(b, s, cfg.num_kv_heads, hd)
    return k, v
