from repro.models.transformer import (Model, active_param_count, build_model,
                                      param_count)

__all__ = ["Model", "build_model", "param_count", "active_param_count"]
