"""Analysis mode: cost-exact graph variants for the roofline dry-run.

XLA's HLO cost analysis counts a while-loop body ONCE (not x trip count), so a
production graph built with ``lax.scan`` under-reports FLOPs/bytes/collective
traffic. For the roofline measurement we re-lower the same math with:

  * layer stacks unrolled (Python loop over layers),
  * blockwise attention replaced by the dense masked form (identical FLOPs;
    score-materialization bytes are corrected analytically in the analyzer),
  * chunked SSD replaced by the parallel form (vmapped intra-chunk quadratic +
    associative-scan over chunk states — no sequential while at all).

Production compiles (memory proof, collective schedule) never use this mode.
"""
from __future__ import annotations

import contextlib
import contextvars
from typing import Any, Callable, Optional, Tuple

import jax

_ANALYSIS = contextvars.ContextVar("repro_analysis_mode", default=False)
_FSDP_UNSHARD = contextvars.ContextVar("repro_fsdp_unshard", default=False)


@contextlib.contextmanager
def analysis_mode(on: bool = True):
    tok = _ANALYSIS.set(on)
    try:
        yield
    finally:
        _ANALYSIS.reset(tok)


def in_analysis_mode() -> bool:
    return _ANALYSIS.get()


@contextlib.contextmanager
def fsdp_unshard(on: bool = True):
    """With FSDP param storage, layer bodies re-constrain their param slice
    to the TP-only spec INSIDE the scan body, so the "data"-axis all-gather
    is loop-variant and cannot be hoisted out of the loop (the whole-stack
    gather otherwise materializes every layer's weights at once)."""
    tok = _FSDP_UNSHARD.set(on)
    try:
        yield
    finally:
        _FSDP_UNSHARD.reset(tok)


def unshard_layer_params(p: Any, cfg) -> Any:
    """Applied at the top of every layer body (no-op unless fsdp_unshard)."""
    if not _FSDP_UNSHARD.get():
        return p
    import jax
    from jax.sharding import PartitionSpec as P

    from repro.parallel.constraints import _mesh_shape
    from repro.parallel.sharding import _leaf_spec

    mesh = _mesh_shape()
    tp = mesh.get("model", 1)
    if not mesh or "data" not in mesh:
        return p

    def rule(path, leaf):
        keys = [getattr(k, "key", getattr(k, "name", None)) for k in path]
        spec = _leaf_spec(keys[-1], leaf.shape, cfg, tp, stacked=False)
        try:
            return jax.lax.with_sharding_constraint(leaf, spec)
        except Exception:
            return leaf

    return jax.tree_util.tree_map_with_path(rule, p)


def scan_layers(body: Callable, carry: Any, xs: Any,
                length: Optional[int] = None) -> Tuple[Any, Any]:
    """``lax.scan`` in production; unrolled Python loop in analysis mode.

    body(carry, x) -> (carry, y). Returns (carry, ys) with ys stacked (or None
    if every y is None).
    """
    if not in_analysis_mode():
        return jax.lax.scan(body, carry, xs)
    if length is None:
        length = jax.tree.leaves(xs)[0].shape[0]
    ys = []
    for i in range(length):
        x_i = jax.tree.map(lambda a: a[i], xs)
        carry, y = body(carry, x_i)
        ys.append(y)
    if all(y is None for y in ys):
        return carry, None
    import jax.numpy as jnp
    stacked = jax.tree.map(lambda *zs: jnp.stack(zs), *ys)
    return carry, stacked
