"""Model assembly for every architecture family.

One functional ``Model`` per ArchConfig with:
  init(key)                      -> params (real arrays; smoke-scale only)
  param_specs()                  -> ShapeDtypeStruct pytree (production-scale safe)
  loss(params, batch)            -> (scalar, metrics)
  prefill(params, batch)         -> (last_logits, cache)
  decode_step(params, cache, tok)-> (logits, cache)
  cache_specs(batch, max_len)    -> ShapeDtypeStruct pytree
  input_specs(shape)             -> batch pytree of ShapeDtypeStruct

Decoder stacks are ``lax.scan`` over stacked layer params so HLO size (and
compile time) is depth-independent; hybrid (Zamba2) applies its weight-shared
attention block inside the scan via ``lax.cond``. Remat policy per config.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ArchConfig, ShapeConfig
from repro.models import attention as attn
from repro.models import mamba2, moe
from repro.models.layers import (chunked_xent, embed_init, embed_lookup,
                                 mlp_apply, mlp_init, rms_norm,
                                 sinusoidal_positions, unembed)
from repro.models.modes import (in_analysis_mode, scan_layers,
                                unshard_layer_params)
from repro.parallel.constraints import BATCH, constrain

PyTree = Any


def _dtype(cfg: ArchConfig):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


def _remat(fn, cfg: ArchConfig):
    if cfg.remat_policy == "none":
        return fn
    if cfg.remat_policy == "full":
        return jax.checkpoint(fn, prevent_cse=False)
    if cfg.remat_policy == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
            prevent_cse=False)
    raise ValueError(f"unknown remat policy {cfg.remat_policy}")


# =========================================================================== #
# Per-layer parameter initializers
# =========================================================================== #
def _attn_block_init(key, cfg: ArchConfig, dtype, *, d_ff: Optional[int] = None):
    k1, k2 = jax.random.split(key)
    p = {
        "ln1": jnp.zeros((cfg.d_model,), dtype),
        "attn": attn.attn_init(k1, cfg, dtype),
        "ln2": jnp.zeros((cfg.d_model,), dtype),
    }
    if cfg.is_moe and d_ff is None:
        p["moe"] = moe.moe_init(k2, cfg, dtype)
    else:
        p["mlp"] = mlp_init(k2, cfg.d_model, d_ff or cfg.d_ff, cfg.mlp_type, dtype)
    return p


def _mamba_block_init(key, cfg: ArchConfig, dtype):
    return {
        "ln1": jnp.zeros((cfg.d_model,), dtype),
        "mamba": mamba2.mamba_init(key, cfg, dtype),
    }


def _encdec_block_init(key, cfg: ArchConfig, dtype, *, cross: bool):
    k1, k2, k3 = jax.random.split(key, 3)
    p = {
        "ln1": jnp.zeros((cfg.d_model,), dtype),
        "attn": attn.attn_init(k1, cfg, dtype),
        "ln2": jnp.zeros((cfg.d_model,), dtype),
        "mlp": mlp_init(k3, cfg.d_model, cfg.d_ff, cfg.mlp_type, dtype),
    }
    if cross:
        p["ln_cross"] = jnp.zeros((cfg.d_model,), dtype)
        p["cross"] = attn.cross_attn_init(k2, cfg, dtype)
    return p


# =========================================================================== #
# Per-layer forward bodies
# =========================================================================== #
def _attn_block(p, cfg: ArchConfig, x, aux):
    p = unshard_layer_params(p, cfg)         # FSDP: in-body all-gather
    x = constrain(x, BATCH, "model", None)   # Megatron-style SP
    h = rms_norm(x, p["ln1"])
    # constraining the addend BEFORE the residual add turns the TP
    # partial-sum resolution into a reduce-scatter (bytes/16) instead of a
    # full all-reduce — measured 7.3 GB/layer -> see EXPERIMENTS.md §Perf
    a_out = constrain(attn.self_attention(p["attn"], cfg, h),
                      BATCH, "model", None)
    x = x + a_out
    h = rms_norm(x, p["ln2"])
    if "moe" in p:
        out, lb = moe.moe_apply(p["moe"], cfg, h)
        x = x + constrain(out, BATCH, "model", None)
        aux = aux + lb
    else:
        x = x + constrain(mlp_apply(p["mlp"], h, cfg.mlp_type),
                          BATCH, "model", None)
    # exit constraint: keeps the scan carry (and the remat-saved stack of
    # layer inputs) sequence-sharded over "model"
    return constrain(x, BATCH, "model", None), aux


def _mamba_block(p, cfg: ArchConfig, x):
    p = unshard_layer_params(p, cfg)
    x = constrain(x, BATCH, "model", None)   # SP on the residual stream
    h = rms_norm(x, p["ln1"])
    x = x + constrain(mamba2.mamba_apply(p["mamba"], cfg, h),
                      BATCH, "model", None)
    return constrain(x, BATCH, "model", None)


def _shared_attn_block(p, cfg: ArchConfig, x):
    """Zamba2's weight-shared attention+MLP block."""
    x = constrain(x, BATCH, "model", None)
    h = rms_norm(x, p["ln1"])
    x = x + constrain(attn.self_attention(p["attn"], cfg, h),
                      BATCH, "model", None)
    h = rms_norm(x, p["ln2"])
    x = x + constrain(mlp_apply(p["mlp"], h, cfg.mlp_type),
                      BATCH, "model", None)
    return constrain(x, BATCH, "model", None)


# =========================================================================== #
# Model
# =========================================================================== #
@dataclass(frozen=True)
class Model:
    cfg: ArchConfig
    init: Callable[[jax.Array], PyTree]
    param_specs: Callable[[], PyTree]
    loss: Callable[[PyTree, Dict], Tuple[jax.Array, Dict]]
    forward: Callable[[PyTree, Dict], jax.Array]
    prefill: Callable[[PyTree, Dict], Tuple[jax.Array, PyTree]]
    decode_step: Callable[[PyTree, PyTree, jax.Array], Tuple[jax.Array, PyTree]]
    cache_specs: Callable[[int, int], PyTree]
    input_specs: Callable[[ShapeConfig], Dict]


def build_model(cfg: ArchConfig) -> Model:
    builder = {
        "dense": _build_decoder_lm,
        "moe": _build_decoder_lm,
        "vlm": _build_decoder_lm,
        "ssm": _build_ssm_lm,
        "hybrid": _build_hybrid_lm,
        "encdec": _build_encdec,
    }[cfg.family]
    return builder(cfg)


def _stacked_init(block_init, key, n: int):
    keys = jax.random.split(key, n)
    return jax.vmap(block_init)(keys)


def _xent(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Mean next-token cross-entropy; logits fp32 (B, S, V).

    logsumexp - label-logit form: avoids materializing a second (B, S, V)
    log-softmax buffer (the logits themselves are unavoidable)."""
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    lab = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(lse - lab)


# --------------------------------------------------------------------------- #
# Dense / MoE / VLM decoder-only LM
# --------------------------------------------------------------------------- #
def _build_decoder_lm(cfg: ArchConfig) -> Model:
    dtype = _dtype(cfg)
    n_layers = cfg.num_layers
    v = cfg.padded_vocab

    def init(key):
        k_emb, k_blocks, k_head = jax.random.split(key, 3)
        params = {
            "embed": embed_init(k_emb, v, cfg.d_model, dtype),
            "blocks": _stacked_init(
                lambda k: _attn_block_init(k, cfg, dtype), k_blocks, n_layers),
            "final_norm": jnp.zeros((cfg.d_model,), dtype),
        }
        if not cfg.tie_embeddings:
            params["lm_head"] = embed_init(k_head, v, cfg.d_model, dtype)
        return params

    def param_specs():
        return jax.eval_shape(init, jax.random.key(0))

    def _embed_inputs(params, batch, seq_in):
        """Token (and patch) embeddings -> (B, S_total, D)."""
        x = embed_lookup(params["embed"], seq_in)
        if cfg.num_patch_tokens:
            x = jnp.concatenate([batch["patch_embeds"].astype(x.dtype), x], axis=1)
        return x

    def _backbone(params, x):
        body = _remat(lambda carry, p: _attn_block(p, cfg, *carry), cfg)
        (x, aux), _ = scan_layers(
            lambda c, p: (body(c, p), None), (x, jnp.zeros((), jnp.float32)),
            params["blocks"], length=n_layers)
        return rms_norm(x, params["final_norm"]), aux

    def _logits(params, x):
        head = params["embed"] if cfg.tie_embeddings else params["lm_head"]
        return unembed(head, x)

    def forward(params, batch):
        tokens = batch["tokens"]
        x = _embed_inputs(params, batch, tokens)
        x, _ = _backbone(params, x)
        return _logits(params, x)

    def loss(params, batch):
        tokens = batch["tokens"]                    # (B, S_text+1)
        inp, labels = tokens[:, :-1], tokens[:, 1:]
        x = _embed_inputs(params, batch, inp)
        x, aux = _backbone(params, x)
        npatch = cfg.num_patch_tokens
        head = params["embed"] if cfg.tie_embeddings else params["lm_head"]
        l = chunked_xent(head, x[:, npatch:] if npatch else x, labels)
        total = l + 0.01 * aux
        return total, {"xent": l, "aux": aux}

    def cache_specs(batch: int, max_len: int):
        kh, hd = cfg.num_kv_heads, cfg.resolved_head_dim
        kv = jax.ShapeDtypeStruct((n_layers, batch, max_len, kh, hd), dtype)
        return {"k": kv, "v": kv, "index": jax.ShapeDtypeStruct((), jnp.int32)}

    def prefill(params, batch):
        tokens = batch["tokens"]
        max_len = batch.get("max_len", tokens.shape[1] + (cfg.num_patch_tokens or 0))
        x = _embed_inputs(params, batch, tokens)
        s_total = x.shape[1]

        def body(carry, p):
            x = carry
            p = unshard_layer_params(p, cfg)
            h = rms_norm(x, p["ln1"])
            a_out, (k_c, v_c) = attn.self_attention_prefill(
                p["attn"], cfg, h, max_len)
            x = x + a_out
            h = rms_norm(x, p["ln2"])
            if "moe" in p:
                x = x + moe.moe_apply(p["moe"], cfg, h)[0]
            else:
                x = x + mlp_apply(p["mlp"], h, cfg.mlp_type)
            return x, (k_c, v_c)

        x, (k_all, v_all) = scan_layers(_remat(body, cfg), x, params["blocks"],
                                        length=n_layers)
        x = rms_norm(x, params["final_norm"])
        logits = _logits(params, x[:, -1])
        cache = {"k": k_all, "v": v_all,
                 "index": jnp.asarray(s_total, jnp.int32)}
        return logits, cache

    def decode_step(params, cache, token):
        x = embed_lookup(params["embed"], token[:, None])  # (B,1,D)
        index = cache["index"]

        def body(x, layer):
            p, k_c, v_c = layer
            p = unshard_layer_params(p, cfg)
            h = rms_norm(x, p["ln1"])
            a_out, (k_c, v_c) = attn.self_attention_decode(
                p["attn"], cfg, h, (k_c, v_c), index)
            x = x + a_out
            h = rms_norm(x, p["ln2"])
            if "moe" in p:
                x = x + moe.moe_apply(p["moe"], cfg, h)[0]
            else:
                x = x + mlp_apply(p["mlp"], h, cfg.mlp_type)
            return x, (k_c, v_c)

        x, (k_all, v_all) = scan_layers(
            body, x, (params["blocks"], cache["k"], cache["v"]),
            length=n_layers)
        x = rms_norm(x, params["final_norm"])
        logits = _logits(params, x[:, 0])
        return logits, {"k": k_all, "v": v_all, "index": index + 1}

    def input_specs(shape: ShapeConfig):
        b, s = shape.global_batch, shape.seq_len
        npatch = cfg.num_patch_tokens
        specs: Dict[str, Any] = {}
        if shape.kind == "train":
            specs["tokens"] = jax.ShapeDtypeStruct((b, s - npatch + 1), jnp.int32)
        elif shape.kind == "prefill":
            specs["tokens"] = jax.ShapeDtypeStruct((b, s - npatch), jnp.int32)
        else:  # decode
            specs["token"] = jax.ShapeDtypeStruct((b,), jnp.int32)
            specs["cache"] = cache_specs(b, s)
        if npatch and shape.kind != "decode":
            specs["patch_embeds"] = jax.ShapeDtypeStruct(
                (b, npatch, cfg.d_model), dtype)
        return specs

    return Model(cfg, init, param_specs, loss, forward, prefill, decode_step,
                 cache_specs, input_specs)


# --------------------------------------------------------------------------- #
# Pure SSM (Mamba2) LM
# --------------------------------------------------------------------------- #
def _build_ssm_lm(cfg: ArchConfig) -> Model:
    dtype = _dtype(cfg)
    n_layers = cfg.num_layers
    v = cfg.padded_vocab

    def init(key):
        k_emb, k_blocks = jax.random.split(key)
        return {
            "embed": embed_init(k_emb, v, cfg.d_model, dtype),
            "blocks": _stacked_init(
                lambda k: _mamba_block_init(k, cfg, dtype), k_blocks, n_layers),
            "final_norm": jnp.zeros((cfg.d_model,), dtype),
        }

    def param_specs():
        return jax.eval_shape(init, jax.random.key(0))

    def _hidden(params, tokens):
        x = embed_lookup(params["embed"], tokens)
        body = _remat(lambda x, p: _mamba_block(p, cfg, x), cfg)
        x, _ = scan_layers(lambda x, p: (body(x, p), None), x,
                           params["blocks"], length=n_layers)
        return rms_norm(x, params["final_norm"])

    def forward(params, batch):
        return unembed(params["embed"], _hidden(params, batch["tokens"]))

    def loss(params, batch):
        tokens = batch["tokens"]
        x = _hidden(params, tokens[:, :-1])
        l = chunked_xent(params["embed"], x, tokens[:, 1:])
        return l, {"xent": l, "aux": jnp.zeros((), jnp.float32)}

    def cache_specs(batch: int, max_len: int):
        per_layer = mamba2.mamba_state_specs(cfg, batch)
        stacked = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct((n_layers,) + s.shape, s.dtype), per_layer)
        return {"mamba": stacked, "index": jax.ShapeDtypeStruct((), jnp.int32)}

    def prefill(params, batch):
        tokens = batch["tokens"]
        x = embed_lookup(params["embed"], tokens)

        def body(x, p):
            p = unshard_layer_params(p, cfg)
            h = rms_norm(x, p["ln1"])
            out, state = mamba2.mamba_prefill(p["mamba"], cfg, h)
            return x + out, state

        x, states = scan_layers(_remat(body, cfg), x, params["blocks"],
                                length=n_layers)
        x = rms_norm(x, params["final_norm"])
        logits = unembed(params["embed"], x[:, -1])
        return logits, {"mamba": states,
                        "index": jnp.asarray(tokens.shape[1], jnp.int32)}

    def decode_step(params, cache, token):
        x = embed_lookup(params["embed"], token[:, None])

        def body(x, layer):
            p, state = layer
            p = unshard_layer_params(p, cfg)
            h = rms_norm(x, p["ln1"])
            out, state = mamba2.mamba_decode(p["mamba"], cfg, h, state)
            return x + out, state

        x, states = scan_layers(body, x, (params["blocks"], cache["mamba"]),
                                length=n_layers)
        x = rms_norm(x, params["final_norm"])
        logits = unembed(params["embed"], x[:, 0])
        return logits, {"mamba": states, "index": cache["index"] + 1}

    def input_specs(shape: ShapeConfig):
        b, s = shape.global_batch, shape.seq_len
        if shape.kind == "train":
            return {"tokens": jax.ShapeDtypeStruct((b, s + 1), jnp.int32)}
        if shape.kind == "prefill":
            return {"tokens": jax.ShapeDtypeStruct((b, s), jnp.int32)}
        return {"token": jax.ShapeDtypeStruct((b,), jnp.int32),
                "cache": cache_specs(b, s)}

    return Model(cfg, init, param_specs, loss, forward, prefill, decode_step,
                 cache_specs, input_specs)


# --------------------------------------------------------------------------- #
# Hybrid (Zamba2): scanned Mamba2 stack + weight-shared attention block
# --------------------------------------------------------------------------- #
def _build_hybrid_lm(cfg: ArchConfig) -> Model:
    dtype = _dtype(cfg)
    n_layers = cfg.num_layers
    v = cfg.padded_vocab
    kinds = cfg.layer_kinds()
    attn_layers = tuple(i for i, k in enumerate(kinds) if k == "mamba_attn")
    n_attn = len(attn_layers)
    is_attn = jnp.asarray([k == "mamba_attn" for k in kinds], jnp.bool_)

    def init(key):
        k_emb, k_blocks, k_shared = jax.random.split(key, 3)
        return {
            "embed": embed_init(k_emb, v, cfg.d_model, dtype),
            "blocks": _stacked_init(
                lambda k: _mamba_block_init(k, cfg, dtype), k_blocks, n_layers),
            "shared_attn": _attn_block_init(k_shared, cfg, dtype, d_ff=cfg.d_ff),
            "final_norm": jnp.zeros((cfg.d_model,), dtype),
        }

    def param_specs():
        return jax.eval_shape(init, jax.random.key(0))

    def _hidden(params, tokens):
        x = embed_lookup(params["embed"], tokens)
        shared = params["shared_attn"]

        if in_analysis_mode():  # static unroll: exact cost accounting
            for i in range(n_layers):
                p = jax.tree.map(lambda a: a[i], params["blocks"])
                x = _mamba_block(p, cfg, x)
                if kinds[i] == "mamba_attn":
                    x = _shared_attn_block(shared, cfg, x)
            return rms_norm(x, params["final_norm"])

        def body(x, layer):
            p, apply_attn = layer
            x = _mamba_block(p, cfg, x)
            x = jax.lax.cond(apply_attn,
                             lambda x: _shared_attn_block(shared, cfg, x),
                             lambda x: x, x)
            return x

        wrapped = _remat(body, cfg)
        x, _ = jax.lax.scan(lambda x, l: (wrapped(x, l), None), x,
                            (params["blocks"], is_attn))
        return rms_norm(x, params["final_norm"])

    def forward(params, batch):
        return unembed(params["embed"], _hidden(params, batch["tokens"]))

    def loss(params, batch):
        tokens = batch["tokens"]
        x = _hidden(params, tokens[:, :-1])
        l = chunked_xent(params["embed"], x, tokens[:, 1:])
        return l, {"xent": l, "aux": jnp.zeros((), jnp.float32)}

    def cache_specs(batch: int, max_len: int):
        kh, hd = cfg.num_kv_heads, cfg.resolved_head_dim
        per_layer = mamba2.mamba_state_specs(cfg, batch)
        stacked = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct((n_layers,) + s.shape, s.dtype), per_layer)
        kv = jax.ShapeDtypeStruct((n_attn, batch, max_len, kh, hd), dtype)
        return {"mamba": stacked, "k": kv, "v": kv,
                "index": jax.ShapeDtypeStruct((), jnp.int32)}

    def _layer_params(params, i):
        return jax.tree.map(lambda a: a[i], params["blocks"])

    def prefill(params, batch):
        tokens = batch["tokens"]
        max_len = batch.get("max_len", tokens.shape[1])
        x = embed_lookup(params["embed"], tokens)
        shared = params["shared_attn"]
        mamba_states, k_list, v_list = [], [], []
        for i in range(n_layers):
            p = _layer_params(params, i)
            h = rms_norm(x, p["ln1"])
            out, st = mamba2.mamba_prefill(p["mamba"], cfg, h)
            x = x + out
            mamba_states.append(st)
            if kinds[i] == "mamba_attn":
                h = rms_norm(x, shared["ln1"])
                a_out, (k_c, v_c) = attn.self_attention_prefill(
                    shared["attn"], cfg, h, max_len)
                x = x + a_out
                h = rms_norm(x, shared["ln2"])
                x = x + mlp_apply(shared["mlp"], h, cfg.mlp_type)
                k_list.append(k_c)
                v_list.append(v_c)
        x = rms_norm(x, params["final_norm"])
        logits = unembed(params["embed"], x[:, -1])
        cache = {
            "mamba": jax.tree.map(lambda *xs: jnp.stack(xs), *mamba_states),
            "k": jnp.stack(k_list), "v": jnp.stack(v_list),
            "index": jnp.asarray(tokens.shape[1], jnp.int32),
        }
        return logits, cache

    def decode_step(params, cache, token):
        x = embed_lookup(params["embed"], token[:, None])
        shared = params["shared_attn"]
        index = cache["index"]
        new_states, new_k, new_v = [], [], []
        a_i = 0
        for i in range(n_layers):
            p = _layer_params(params, i)
            st = jax.tree.map(lambda a: a[i], cache["mamba"])
            h = rms_norm(x, p["ln1"])
            out, st = mamba2.mamba_decode(p["mamba"], cfg, h, st)
            x = x + out
            new_states.append(st)
            if kinds[i] == "mamba_attn":
                h = rms_norm(x, shared["ln1"])
                a_out, (k_c, v_c) = attn.self_attention_decode(
                    shared["attn"], cfg, h,
                    (cache["k"][a_i], cache["v"][a_i]), index)
                x = x + a_out
                h = rms_norm(x, shared["ln2"])
                x = x + mlp_apply(shared["mlp"], h, cfg.mlp_type)
                new_k.append(k_c)
                new_v.append(v_c)
                a_i += 1
        x = rms_norm(x, params["final_norm"])
        logits = unembed(params["embed"], x[:, 0])
        cache = {
            "mamba": jax.tree.map(lambda *xs: jnp.stack(xs), *new_states),
            "k": jnp.stack(new_k), "v": jnp.stack(new_v),
            "index": index + 1,
        }
        return logits, cache

    def input_specs(shape: ShapeConfig):
        b, s = shape.global_batch, shape.seq_len
        if shape.kind == "train":
            return {"tokens": jax.ShapeDtypeStruct((b, s + 1), jnp.int32)}
        if shape.kind == "prefill":
            return {"tokens": jax.ShapeDtypeStruct((b, s), jnp.int32)}
        return {"token": jax.ShapeDtypeStruct((b,), jnp.int32),
                "cache": cache_specs(b, s)}

    return Model(cfg, init, param_specs, loss, forward, prefill, decode_step,
                 cache_specs, input_specs)


# --------------------------------------------------------------------------- #
# Encoder-decoder (Whisper): stubbed conv frontend -> frame embeddings
# --------------------------------------------------------------------------- #
def _build_encdec(cfg: ArchConfig) -> Model:
    dtype = _dtype(cfg)
    n_dec, n_enc = cfg.num_layers, cfg.encoder_layers
    v = cfg.padded_vocab

    def init(key):
        k_emb, k_enc, k_dec = jax.random.split(key, 3)
        return {
            "embed": embed_init(k_emb, v, cfg.d_model, dtype),
            "encoder": _stacked_init(
                lambda k: _encdec_block_init(k, cfg, dtype, cross=False),
                k_enc, n_enc),
            "enc_norm": jnp.zeros((cfg.d_model,), dtype),
            "decoder": _stacked_init(
                lambda k: _encdec_block_init(k, cfg, dtype, cross=True),
                k_dec, n_dec),
            "final_norm": jnp.zeros((cfg.d_model,), dtype),
        }

    def param_specs():
        return jax.eval_shape(init, jax.random.key(0))

    def _encode(params, frames):
        pos = jnp.asarray(sinusoidal_positions(frames.shape[1], cfg.d_model),
                          dtype)
        x = frames.astype(dtype) + pos[None]

        def body(x, p):
            p = unshard_layer_params(p, cfg)
            h = rms_norm(x, p["ln1"])
            x = x + attn.self_attention(p["attn"], cfg, h, causal=False, rope=False)
            h = rms_norm(x, p["ln2"])
            return x + mlp_apply(p["mlp"], h, cfg.mlp_type)

        wrapped = _remat(body, cfg)
        x, _ = scan_layers(lambda x, p: (wrapped(x, p), None), x,
                           params["encoder"], length=n_enc)
        return rms_norm(x, params["enc_norm"])

    def _decode_train(params, enc_out, tokens):
        x = embed_lookup(params["embed"], tokens)

        def body(x, p):
            p = unshard_layer_params(p, cfg)
            h = rms_norm(x, p["ln1"])
            x = x + attn.self_attention(p["attn"], cfg, h, causal=True)
            h = rms_norm(x, p["ln_cross"])
            kv = attn.cross_kv(p["cross"], cfg, enc_out)
            x = x + attn.cross_attention(p["cross"], cfg, h, kv)
            h = rms_norm(x, p["ln2"])
            return x + mlp_apply(p["mlp"], h, cfg.mlp_type)

        wrapped = _remat(body, cfg)
        x, _ = scan_layers(lambda x, p: (wrapped(x, p), None), x,
                           params["decoder"], length=n_dec)
        return rms_norm(x, params["final_norm"])

    def forward(params, batch):
        enc_out = _encode(params, batch["frames"])
        x = _decode_train(params, enc_out, batch["tokens"])
        return unembed(params["embed"], x)

    def loss(params, batch):
        tokens = batch["tokens"]
        enc_out = _encode(params, batch["frames"])
        x = _decode_train(params, enc_out, tokens[:, :-1])
        l = chunked_xent(params["embed"], x, tokens[:, 1:])
        return l, {"xent": l, "aux": jnp.zeros((), jnp.float32)}

    def cache_specs(batch: int, max_len: int):
        kh, hd = cfg.num_kv_heads, cfg.resolved_head_dim
        kv = jax.ShapeDtypeStruct((n_dec, batch, max_len, kh, hd), dtype)
        cross = jax.ShapeDtypeStruct((n_dec, batch, cfg.encoder_seq, kh, hd), dtype)
        return {"k": kv, "v": kv, "cross_k": cross, "cross_v": cross,
                "index": jax.ShapeDtypeStruct((), jnp.int32)}

    def prefill(params, batch):
        tokens = batch["tokens"]
        max_len = batch.get("max_len", tokens.shape[1])
        enc_out = _encode(params, batch["frames"])
        x = embed_lookup(params["embed"], tokens)

        def body(x, p):
            p = unshard_layer_params(p, cfg)
            h = rms_norm(x, p["ln1"])
            a_out, (k_c, v_c) = attn.self_attention_prefill(
                p["attn"], cfg, h, max_len)
            x = x + a_out
            h = rms_norm(x, p["ln_cross"])
            ckv = attn.cross_kv(p["cross"], cfg, enc_out)
            x = x + attn.cross_attention(p["cross"], cfg, h, ckv)
            h = rms_norm(x, p["ln2"])
            x = x + mlp_apply(p["mlp"], h, cfg.mlp_type)
            return x, (k_c, v_c, ckv[0], ckv[1])

        x, (k_all, v_all, ck, cv) = scan_layers(_remat(body, cfg), x,
                                                params["decoder"], length=n_dec)
        x = rms_norm(x, params["final_norm"])
        logits = unembed(params["embed"], x[:, -1])
        return logits, {"k": k_all, "v": v_all, "cross_k": ck, "cross_v": cv,
                        "index": jnp.asarray(tokens.shape[1], jnp.int32)}

    def decode_step(params, cache, token):
        x = embed_lookup(params["embed"], token[:, None])
        index = cache["index"]

        def body(x, layer):
            p, k_c, v_c, ck, cv = layer
            p = unshard_layer_params(p, cfg)
            h = rms_norm(x, p["ln1"])
            a_out, (k_c, v_c) = attn.self_attention_decode(
                p["attn"], cfg, h, (k_c, v_c), index)
            x = x + a_out
            h = rms_norm(x, p["ln_cross"])
            x = x + attn.cross_attention(p["cross"], cfg, h, (ck, cv))
            h = rms_norm(x, p["ln2"])
            x = x + mlp_apply(p["mlp"], h, cfg.mlp_type)
            return x, (k_c, v_c)

        x, (k_all, v_all) = scan_layers(
            body, x, (params["decoder"], cache["k"], cache["v"],
                      cache["cross_k"], cache["cross_v"]), length=n_dec)
        x = rms_norm(x, params["final_norm"])
        logits = unembed(params["embed"], x[:, 0])
        return logits, {"k": k_all, "v": v_all, "cross_k": cache["cross_k"],
                        "cross_v": cache["cross_v"], "index": index + 1}

    def input_specs(shape: ShapeConfig):
        b, s = shape.global_batch, shape.seq_len
        frames = jax.ShapeDtypeStruct((b, cfg.encoder_seq, cfg.d_model), dtype)
        if shape.kind == "train":
            return {"tokens": jax.ShapeDtypeStruct((b, s + 1), jnp.int32),
                    "frames": frames}
        if shape.kind == "prefill":
            return {"tokens": jax.ShapeDtypeStruct((b, s), jnp.int32),
                    "frames": frames}
        return {"token": jax.ShapeDtypeStruct((b,), jnp.int32),
                "cache": cache_specs(b, s)}

    return Model(cfg, init, param_specs, loss, forward, prefill, decode_step,
                 cache_specs, input_specs)


# --------------------------------------------------------------------------- #
# Parameter accounting (used by roofline MODEL_FLOPS and the checkpoint razor)
# --------------------------------------------------------------------------- #
def param_count(cfg: ArchConfig) -> int:
    specs = build_model(cfg).param_specs()
    return int(sum(np.prod(s.shape) for s in jax.tree.leaves(specs)))


def active_param_count(cfg: ArchConfig) -> int:
    """Params touched per token (MoE: top_k of routed experts + shared)."""
    total = param_count(cfg)
    if not cfg.is_moe:
        return total
    e = cfg.padded_experts
    per_expert = 3 * cfg.d_model * cfg.moe_d_ff
    routed_all = cfg.num_layers * e * per_expert
    routed_active = cfg.num_layers * cfg.top_k * per_expert
    return total - routed_all + routed_active
