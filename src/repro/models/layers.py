"""Shared neural-net layers: norms, RoPE, MLP flavors, embeddings.

All functions are pure; parameters are plain dict pytrees. Compute runs in the
array's dtype (bf16 in production) with fp32 accumulation where it matters
(norm statistics, softmax, logits).
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * (1.0 + scale.astype(jnp.float32))).astype(dtype)


def head_rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    """Qwen3-style qk-norm over the head dim of (..., heads, head_dim)."""
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * (1.0 + scale.astype(jnp.float32))).astype(dtype)


def rope_frequencies(head_dim: int, theta: float) -> np.ndarray:
    return 1.0 / (theta ** (np.arange(0, head_dim, 2, dtype=np.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotary embedding. x: (..., seq, heads, head_dim); positions: (..., seq)."""
    dtype = x.dtype
    head_dim = x.shape[-1]
    freqs = jnp.asarray(rope_frequencies(head_dim, theta))
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (..., seq, hd/2)
    cos = jnp.cos(angles)[..., :, None, :]  # broadcast over heads
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(dtype)


def sinusoidal_positions(seq: int, d_model: int) -> np.ndarray:
    """Whisper-style fixed sinusoidal embeddings for encoder frames."""
    pos = np.arange(seq, dtype=np.float32)[:, None]
    dim = np.arange(d_model // 2, dtype=np.float32)[None, :]
    inv = np.exp(-np.log(10_000.0) * dim / max(d_model // 2 - 1, 1))
    ang = pos * inv
    return np.concatenate([np.sin(ang), np.cos(ang)], axis=-1)


# --------------------------------------------------------------------------- #
# MLP flavors
# --------------------------------------------------------------------------- #
def mlp_apply(params: Dict[str, jax.Array], x: jax.Array, mlp_type: str) -> jax.Array:
    if mlp_type == "swiglu":
        g = x @ params["w_gate"]
        u = x @ params["w_up"]
        return (jax.nn.silu(g) * u) @ params["w_down"]
    if mlp_type == "geglu":
        g = x @ params["w_gate"]
        u = x @ params["w_up"]
        return (jax.nn.gelu(g, approximate=True) * u) @ params["w_down"]
    if mlp_type == "sq_relu":
        u = jax.nn.relu(x @ params["w_up"])
        return jnp.square(u) @ params["w_down"]
    if mlp_type == "gelu":
        return jax.nn.gelu(x @ params["w_up"], approximate=True) @ params["w_down"]
    raise ValueError(f"unknown mlp_type {mlp_type!r}")


def mlp_init(key: jax.Array, d_model: int, d_ff: int, mlp_type: str, dtype) -> Dict:
    k1, k2, k3 = jax.random.split(key, 3)
    scale_in = 1.0 / np.sqrt(d_model)
    scale_out = 1.0 / np.sqrt(d_ff)
    p = {
        "w_up": (jax.random.normal(k2, (d_model, d_ff)) * scale_in).astype(dtype),
        "w_down": (jax.random.normal(k3, (d_ff, d_model)) * scale_out).astype(dtype),
    }
    if mlp_type in ("swiglu", "geglu"):
        p["w_gate"] = (jax.random.normal(k1, (d_model, d_ff)) * scale_in).astype(dtype)
    return p


def embed_init(key: jax.Array, vocab: int, d_model: int, dtype) -> Dict:
    return {"w": (jax.random.normal(key, (vocab, d_model)) * 0.02).astype(dtype)}


def embed_lookup(params: Dict, tokens: jax.Array) -> jax.Array:
    return params["w"][tokens]


def chunked_xent(params: Dict, x: jax.Array, labels: jax.Array,
                 *, chunk: int = 512) -> jax.Array:
    """Mean next-token cross-entropy WITHOUT materializing (B, S, V) logits:
    scan over sequence chunks with a rematerialized body, so the live logits
    buffer is (B, chunk, V/tp) — the standard big-vocab memory trick.

    x: (B, S, D) final hidden states; labels: (B, S)."""
    from repro.models.modes import in_analysis_mode
    from repro.parallel.constraints import BATCH, constrain
    if in_analysis_mode():  # cost-exact: no scan (bodies are counted once)
        logits = jnp.einsum("bsd,vd->bsv", bf16_grad_barrier(x),
                            constrain(params["w"], "model", None),
                            preferred_element_type=jnp.float32)
        logits = constrain(logits, BATCH, None, "model")
        lse = jax.scipy.special.logsumexp(logits, axis=-1)
        lab = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
        return jnp.mean(lse - lab)
    b, s, d = x.shape
    chunk = min(chunk, s)
    pad = (-s) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)))
    nc = (s + pad) // chunk
    x = bf16_grad_barrier(x)
    xc = x.reshape(b, nc, chunk, d).transpose(1, 0, 2, 3)
    lc = labels.reshape(b, nc, chunk).transpose(1, 0, 2)
    mask = (jnp.arange(nc * chunk) < s).reshape(nc, chunk)
    # constraint propagates to the cotangent: d(w) accumulates vocab-sharded
    # instead of as a full fp32 (V, D) replica on every device (measured
    # 3x3.4 GB at deepseek scale)
    w = constrain(params["w"], "model", None)

    def body(acc, inp):
        x_k, l_k, m_k = inp
        logits = jnp.einsum("bsd,vd->bsv", x_k, w,
                            preferred_element_type=jnp.float32)
        logits = constrain(logits, BATCH, None, "model")
        lse = jax.scipy.special.logsumexp(logits, axis=-1)
        lab = jnp.take_along_axis(logits, l_k[..., None], axis=-1)[..., 0]
        return acc + jnp.sum((lse - lab) * m_k[None, :]), None

    body = jax.checkpoint(body, prevent_cse=False)
    total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32),
                            (xc, lc, mask))
    return total / (b * s)


@jax.custom_vjp
def bf16_grad_barrier(x: jax.Array) -> jax.Array:
    """Identity whose cotangent is cast to bf16: keeps the backward residual
    stream in bf16 (the fp32 logits otherwise push fp32 cotangents through
    every layer — 2x the activation-grad HBM traffic and footprint)."""
    return x


def _bgb_fwd(x):
    return x, jnp.zeros((0,), x.dtype)  # dtype token (JAX-typed residual)


def _bgb_bwd(token, g):
    return (g.astype(token.dtype),)


bf16_grad_barrier.defvjp(_bgb_fwd, _bgb_bwd)


def unembed(params: Dict, x: jax.Array) -> jax.Array:
    """Logits in fp32 via MXU-native bf16 x bf16 -> f32 accumulation.

    Output constrained vocab-sharded over "model": keeps d(embed) gradients
    sharded (otherwise the backward materializes full (V, D) fp32 embedding
    grads on every device — measured ~3.4 GB x several at deepseek scale)."""
    from repro.parallel.constraints import BATCH, constrain
    x = bf16_grad_barrier(x)   # backward residual stream stays bf16
    logits = jnp.einsum("...d,vd->...v", x, params["w"],
                        preferred_element_type=jnp.float32)
    if logits.ndim == 3:
        return constrain(logits, BATCH, None, "model")
    return constrain(logits, BATCH, "model")
