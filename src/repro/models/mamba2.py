"""Mamba2 / SSD (state-space duality) blocks.

Chunked SSD forward: the sequence is split into chunks of ``ssm_chunk``; a
``lax.scan`` over chunks carries the (B, H, N, P) inter-chunk state while the
quadratic intra-chunk term is computed per chunk — the transient (B, H, Lc, Lc)
attention-like tensor stays bounded (this mirrors the Mamba2 paper's blocked
algorithm and is the oracle for the Pallas SSD kernel in ``repro.kernels``).

Head layout: d_inner = H * P is head-major, so sharding d_inner over the
``model`` mesh axis shards SSD heads with no resharding at the reshape.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.layers import rms_norm


# --------------------------------------------------------------------------- #
# Depthwise causal conv (k=4): shift-and-sum form — fuses cleanly, no conv op.
# --------------------------------------------------------------------------- #
def causal_conv(x: jax.Array, w: jax.Array) -> jax.Array:
    """x: (B, S, C); w: (C, k). Causal depthwise conv + SiLU."""
    k = w.shape[-1]
    out = x * w[None, None, :, k - 1]
    for i in range(k - 1):
        shift = k - 1 - i
        out = out + jnp.pad(x, ((0, 0), (shift, 0), (0, 0)))[:, :-shift] * w[None, None, :, i]
    return jax.nn.silu(out)


def causal_conv_step(x: jax.Array, w: jax.Array, state: jax.Array
                     ) -> Tuple[jax.Array, jax.Array]:
    """One decode step. x: (B, 1, C); state: (B, k-1, C). Returns (y, new_state)."""
    window = jnp.concatenate([state, x], axis=1)          # (B, k, C)
    y = jnp.einsum("bkc,ck->bc", window, w)[:, None, :]   # (B, 1, C)
    return jax.nn.silu(y), window[:, 1:, :]


# --------------------------------------------------------------------------- #
# Core SSD
# --------------------------------------------------------------------------- #
def ssd_chunked(
    x: jax.Array,        # (B, S, H, P)
    dt: jax.Array,       # (B, S, H)   post-softplus, > 0
    a: jax.Array,        # (H,)        negative
    b_mat: jax.Array,    # (B, S, N)   single SSD group
    c_mat: jax.Array,    # (B, S, N)
    *,
    chunk: int,
    initial_state: Optional[jax.Array] = None,  # (B, H, N, P)
) -> Tuple[jax.Array, jax.Array]:
    """Returns (y: (B, S, H, P), final_state: (B, H, N, P))."""
    bsz, s, h, p = x.shape
    n = b_mat.shape[-1]
    chunk = min(chunk, s)
    pad = (-s) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        b_mat = jnp.pad(b_mat, ((0, 0), (0, pad), (0, 0)))
        c_mat = jnp.pad(c_mat, ((0, 0), (0, pad), (0, 0)))
    nc = (s + pad) // chunk

    xc = x.reshape(bsz, nc, chunk, h, p).transpose(1, 0, 2, 3, 4)
    dtc = dt.astype(jnp.float32).reshape(bsz, nc, chunk, h).transpose(1, 0, 2, 3)
    bc = b_mat.astype(jnp.float32).reshape(bsz, nc, chunk, n).transpose(1, 0, 2, 3)
    cc = c_mat.astype(jnp.float32).reshape(bsz, nc, chunk, n).transpose(1, 0, 2, 3)
    af = a.astype(jnp.float32)

    if initial_state is None:
        initial_state = jnp.zeros((bsz, h, n, p), jnp.float32)

    def body(state, inputs):
        x_k, dt_k, b_k, c_k = inputs            # (B,Lc,H,P) (B,Lc,H) (B,Lc,N) ...
        da = dt_k * af                           # (B,Lc,H), <= 0
        cs = jnp.cumsum(da, axis=1)              # inclusive cumsum
        # intra-chunk quadratic term
        cb = jnp.einsum("bin,bjn->bij", c_k, b_k)                  # (B,Lc,Lc)
        decay = jnp.exp(cs[:, :, None, :] - cs[:, None, :, :])     # (B,i,j,H)
        idx = jnp.arange(cs.shape[1])
        causal = (idx[:, None] >= idx[None, :])[None, :, :, None]
        att = jnp.where(causal, cb[..., None] * decay * dt_k[:, None, :, :], 0.0)
        y = jnp.einsum("bijh,bjhp->bihp", att, x_k.astype(jnp.float32))
        # inter-chunk contribution from carried state
        y = y + jnp.einsum("bin,bhnp->bihp", c_k, state) * jnp.exp(cs)[..., None]
        # state update
        last = cs[:, -1:, :]                                       # (B,1,H)
        w = dt_k * jnp.exp(last - cs)                              # (B,Lc,H)
        chunk_state = jnp.einsum("bjh,bjn,bjhp->bhnp", w, b_k,
                                 x_k.astype(jnp.float32))
        state = jnp.exp(last[:, 0, :])[:, :, None, None] * state + chunk_state
        return state, y

    from repro.models.modes import in_analysis_mode
    if in_analysis_mode():
        return _ssd_parallel(xc, dtc, bc, cc, af, initial_state,
                             bsz, s, h, p, chunk)
    # remat per chunk: avoids saving the (B,Lc,Lc,H) decay tensors of every
    # chunk for backward (same reasoning as blockwise attention)
    final_state, ys = jax.lax.scan(jax.checkpoint(body, prevent_cse=False),
                                   initial_state, (xc, dtc, bc, cc))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(bsz, nc * chunk, h, p)
    return y[:, :s].astype(x.dtype), final_state


def _ssd_parallel(xc, dtc, bc, cc, af, initial_state, bsz, s, h, p, chunk):
    """Parallel SSD: vmapped intra-chunk quadratic + associative scan over
    chunk states — no sequential while loop, so HLO cost analysis counts every
    FLOP. Same math as the scan form (validated in tests)."""
    nc = xc.shape[0]
    # to (B, Nc, Lc, ...) layout
    x = xc.transpose(1, 0, 2, 3, 4).astype(jnp.float32)      # (B,Nc,Lc,H,P)
    dt = dtc.transpose(1, 0, 2, 3)                            # (B,Nc,Lc,H)
    bm = bc.transpose(1, 0, 2, 3)                             # (B,Nc,Lc,N)
    cm = cc.transpose(1, 0, 2, 3)
    da = dt * af
    cs = jnp.cumsum(da, axis=2)                               # (B,Nc,Lc,H)
    # intra-chunk
    cb = jnp.einsum("bcin,bcjn->bcij", cm, bm)
    decay = jnp.exp(cs[:, :, :, None, :] - cs[:, :, None, :, :])  # (B,Nc,i,j,H)
    idx = jnp.arange(cs.shape[2])
    causal = (idx[:, None] >= idx[None, :])[None, None, :, :, None]
    att = jnp.where(causal, cb[..., None] * decay * dt[:, :, None, :, :], 0.0)
    y = jnp.einsum("bcijh,bcjhp->bcihp", att, x)
    # per-chunk end states + decays
    last = cs[:, :, -1:, :]                                   # (B,Nc,1,H)
    w = dt * jnp.exp(last - cs)
    chunk_states = jnp.einsum("bcjh,bcjn,bcjhp->bchnp", w, bm, x)
    chunk_decay = jnp.exp(last[:, :, 0, :])                   # (B,Nc,H)
    # inclusive running states via associative scan over chunks
    def combine(a, b):
        d1, s1 = a
        d2, s2 = b
        return d1 * d2, s1 * d2[..., None, None] + s2

    dec_sw = jnp.moveaxis(chunk_decay, 1, 0)                  # (Nc,B,H)
    st_sw = jnp.moveaxis(chunk_states, 1, 0)                  # (Nc,B,H,N,P)
    run_dec, run_st = jax.lax.associative_scan(combine, (dec_sw, st_sw))
    # state *before* chunk c = inclusive state of c-1 + decayed initial state
    init = initial_state                                      # (B,H,N,P)
    prev_st = jnp.concatenate(
        [init[None], run_st[:-1] + run_dec[:-1][..., None, None] * init[None]],
        axis=0)                                               # (Nc,B,H,N,P)
    prev_st = jnp.moveaxis(prev_st, 0, 1)                     # (B,Nc,H,N,P)
    y = y + jnp.einsum("bcin,bchnp->bcihp", cm, prev_st) * \
        jnp.exp(cs)[..., None]
    final_state = run_st[-1] + run_dec[-1][..., None, None] * init
    yout = y.reshape(bsz, nc * chunk, h, p)
    return yout[:, :s].astype(xc.dtype), final_state


def ssd_step(
    x: jax.Array,        # (B, H, P)
    dt: jax.Array,       # (B, H)
    a: jax.Array,        # (H,)
    b_vec: jax.Array,    # (B, N)
    c_vec: jax.Array,    # (B, N)
    state: jax.Array,    # (B, H, N, P)
) -> Tuple[jax.Array, jax.Array]:
    """One recurrent decode step. Returns (y: (B,H,P), new_state)."""
    dtf = dt.astype(jnp.float32)
    decay = jnp.exp(dtf * a.astype(jnp.float32))                   # (B,H)
    upd = jnp.einsum("bh,bn,bhp->bhnp", dtf, b_vec.astype(jnp.float32),
                     x.astype(jnp.float32))
    state = decay[:, :, None, None] * state + upd
    y = jnp.einsum("bn,bhnp->bhp", c_vec.astype(jnp.float32), state)
    return y.astype(x.dtype), state


# --------------------------------------------------------------------------- #
# Full Mamba2 block
# --------------------------------------------------------------------------- #
def mamba_init(key, cfg, dtype) -> Dict:
    d, inner = cfg.d_model, cfg.ssm_inner
    h, n, k = cfg.ssm_heads, cfg.ssm_state, cfg.ssm_conv_kernel
    ks = jax.random.split(key, 8)
    sc = 1.0 / np.sqrt(d)
    dt = np.exp(np.random.RandomState(0).uniform(np.log(1e-3), np.log(0.1), h))
    dt_bias = dt + np.log(-np.expm1(-dt))  # inverse softplus
    return {
        "w_x": (jax.random.normal(ks[0], (d, inner)) * sc).astype(dtype),
        "w_z": (jax.random.normal(ks[1], (d, inner)) * sc).astype(dtype),
        "w_b": (jax.random.normal(ks[2], (d, n)) * sc).astype(dtype),
        "w_c": (jax.random.normal(ks[3], (d, n)) * sc).astype(dtype),
        "w_dt": (jax.random.normal(ks[4], (d, h)) * sc).astype(dtype),
        "conv_x": (jax.random.normal(ks[5], (inner, k)) / np.sqrt(k)).astype(dtype),
        "conv_b": (jax.random.normal(ks[6], (n, k)) / np.sqrt(k)).astype(dtype),
        "conv_c": (jax.random.normal(ks[7], (n, k)) / np.sqrt(k)).astype(dtype),
        "a_log": jnp.asarray(np.log(np.linspace(1.0, 16.0, h)), jnp.float32),
        "d_skip": jnp.ones((h,), jnp.float32),
        "dt_bias": jnp.asarray(dt_bias, jnp.float32),
        "norm": jnp.zeros((inner,), dtype),
        "out": (jax.random.normal(jax.random.fold_in(key, 99), (inner, d))
                / np.sqrt(inner)).astype(dtype),
    }


def _mamba_projections(p: Dict, cfg, x: jax.Array):
    z = x @ p["w_z"]
    xr = x @ p["w_x"]
    br = x @ p["w_b"]
    cr = x @ p["w_c"]
    dt_raw = x @ p["w_dt"]
    return z, xr, br, cr, dt_raw


def mamba_apply(p: Dict, cfg, x: jax.Array,
                use_kernel: bool = False) -> jax.Array:
    """Training/prefill forward (full sequence). x: (B, S, D)."""
    bsz, s, _ = x.shape
    h, pdim = cfg.ssm_heads, cfg.ssm_head_dim
    z, xr, br, cr, dt_raw = _mamba_projections(p, cfg, x)
    xr = causal_conv(xr, p["conv_x"])
    br = causal_conv(br, p["conv_b"])
    cr = causal_conv(cr, p["conv_c"])
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])
    a = -jnp.exp(p["a_log"])
    xh = xr.reshape(bsz, s, h, pdim)
    if use_kernel:
        from repro.kernels import ops as kops
        y, _ = kops.ssd(xh, dt, a, br, cr, chunk=cfg.ssm_chunk)
    else:
        y, _ = ssd_chunked(xh, dt, a, br, cr, chunk=cfg.ssm_chunk)
    y = y + (p["d_skip"].astype(jnp.float32)[:, None] * xh.astype(jnp.float32)
             ).astype(y.dtype)
    y = y.reshape(bsz, s, -1)
    y = rms_norm(y * jax.nn.silu(z), p["norm"])
    return y @ p["out"]


def mamba_state_specs(cfg, batch: int):
    """ShapeDtypeStructs of a single block's decode state (conv window + SSD state)."""
    inner, n, k = cfg.ssm_inner, cfg.ssm_state, cfg.ssm_conv_kernel
    h, pdim = cfg.ssm_heads, cfg.ssm_head_dim
    return {
        "conv_x": jax.ShapeDtypeStruct((batch, k - 1, inner), jnp.bfloat16),
        "conv_b": jax.ShapeDtypeStruct((batch, k - 1, n), jnp.bfloat16),
        "conv_c": jax.ShapeDtypeStruct((batch, k - 1, n), jnp.bfloat16),
        "ssm": jax.ShapeDtypeStruct((batch, h, n, pdim), jnp.float32),
    }


def mamba_decode(p: Dict, cfg, x: jax.Array, state: Dict
                 ) -> Tuple[jax.Array, Dict]:
    """One-token decode. x: (B, 1, D); state per mamba_state_specs."""
    bsz = x.shape[0]
    h, pdim = cfg.ssm_heads, cfg.ssm_head_dim
    z, xr, br, cr, dt_raw = _mamba_projections(p, cfg, x)
    xr, conv_x = causal_conv_step(xr, p["conv_x"], state["conv_x"])
    br, conv_b = causal_conv_step(br, p["conv_b"], state["conv_b"])
    cr, conv_c = causal_conv_step(cr, p["conv_c"], state["conv_c"])
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])[:, 0]  # (B,H)
    a = -jnp.exp(p["a_log"])
    xh = xr.reshape(bsz, h, pdim)
    y, ssm = ssd_step(xh, dt, a, br[:, 0], cr[:, 0], state["ssm"])
    y = y + (p["d_skip"].astype(jnp.float32)[:, None] * xh.astype(jnp.float32)
             ).astype(y.dtype)
    y = y.reshape(bsz, 1, -1)
    y = rms_norm(y * jax.nn.silu(z), p["norm"])
    new_state = {"conv_x": conv_x, "conv_b": conv_b, "conv_c": conv_c, "ssm": ssm}
    return y @ p["out"], new_state


def mamba_prefill(p: Dict, cfg, x: jax.Array) -> Tuple[jax.Array, Dict]:
    """Full-sequence forward that also returns the decode state at seq end."""
    bsz, s, _ = x.shape
    h, pdim = cfg.ssm_heads, cfg.ssm_head_dim
    k = cfg.ssm_conv_kernel
    z, xr_raw, br_raw, cr_raw, dt_raw = _mamba_projections(p, cfg, x)
    # conv windows: last k-1 *pre-conv* inputs
    def window(t):
        pad = max(k - 1 - s, 0)
        w = t[:, -(k - 1):, :] if s >= k - 1 else t
        if pad:
            w = jnp.pad(w, ((0, 0), (pad, 0), (0, 0)))
        return w
    xr = causal_conv(xr_raw, p["conv_x"])
    br = causal_conv(br_raw, p["conv_b"])
    cr = causal_conv(cr_raw, p["conv_c"])
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])
    a = -jnp.exp(p["a_log"])
    xh = xr.reshape(bsz, s, h, pdim)
    y, final_state = ssd_chunked(xh, dt, a, br, cr, chunk=cfg.ssm_chunk)
    y = y + (p["d_skip"].astype(jnp.float32)[:, None] * xh.astype(jnp.float32)
             ).astype(y.dtype)
    y = y.reshape(bsz, s, -1)
    y = rms_norm(y * jax.nn.silu(z), p["norm"])
    state = {"conv_x": window(xr_raw), "conv_b": window(br_raw),
             "conv_c": window(cr_raw), "ssm": final_state}
    return y @ p["out"], state
