"""Mixture-of-Experts: top-k router with capacity-bounded index dispatch.

TPU-native adaptation: instead of GShard's dense one-hot dispatch einsum (O(T·E·C)
memory) we build (E, C) token-index tables with scatter, gather tokens into an
(E, C, D) buffer (sharded expert-parallel over the ``model`` axis), run the expert
matmuls as one batched einsum on the MXU, and combine with a weighted gather.
Tokens over capacity are dropped (GShard semantics, capacity_factor default 1.25).

Padded experts (e.g. Qwen2-MoE's 60 -> 64 for EP-16) get -inf router logits and
receive only padding slots.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.parallel.constraints import BATCH, constrain


def moe_capacity(num_tokens: int, num_experts: int, top_k: int,
                 capacity_factor: float) -> int:
    cap = int(np.ceil(top_k * num_tokens * capacity_factor / num_experts))
    return max(8, ((cap + 7) // 8) * 8)  # pad to 8 for TPU lane alignment


def moe_init(key, cfg, dtype) -> Dict:
    e = cfg.padded_experts
    d, f = cfg.d_model, cfg.moe_d_ff
    ks = jax.random.split(key, 6)
    sc, so = 1.0 / np.sqrt(d), 1.0 / np.sqrt(f)
    p = {
        "router": (jax.random.normal(ks[0], (d, e)) * sc).astype(jnp.float32),
        "w_gate": (jax.random.normal(ks[1], (e, d, f)) * sc).astype(dtype),
        "w_up": (jax.random.normal(ks[2], (e, d, f)) * sc).astype(dtype),
        "w_down": (jax.random.normal(ks[3], (e, f, d)) * so).astype(dtype),
    }
    if cfg.num_shared_experts:
        fs = cfg.shared_expert_d_ff
        p["shared"] = {
            "w_gate": (jax.random.normal(ks[4], (d, fs)) * sc).astype(dtype),
            "w_up": (jax.random.normal(ks[5], (d, fs)) * sc).astype(dtype),
            "w_down": (jax.random.normal(
                jax.random.fold_in(ks[5], 1), (fs, d)) / np.sqrt(fs)).astype(dtype),
        }
        p["shared_gate"] = jnp.zeros((d,), jnp.float32)
    return p


def moe_groups(num_tokens: int) -> int:
    """Dispatch groups (GShard-style). Groups map onto the data axis so the
    position sort/scatter/gather stay SHARD-LOCAL — a global argsort over the
    data axis cost ~29 s/step of collectives at qwen3-moe train_4k scale."""
    for g in (16, 8, 4, 2):
        if num_tokens % g == 0 and num_tokens // g >= 8:
            return g
    return 1


def moe_apply(p: Dict, cfg, x: jax.Array):
    """x: (B, S, D) -> (out: (B, S, D), aux_loss: scalar).

    Grouped capacity dispatch: tokens are split into G groups aligned with
    the data axis; routing positions, the (G, E, C) index table, and the
    combine-gather are all group-local. Only the expert einsum crosses the
    mesh (token <-> expert all-to-all, EP over "model").
    """
    b, s, d = x.shape
    t = b * s
    e = cfg.padded_experts
    k = cfg.top_k
    grp = moe_groups(t)
    tg = t // grp
    cap = moe_capacity(tg, e, k, cfg.capacity_factor)
    xf = constrain(x.reshape(grp, tg, d), BATCH, None, None)

    # --- routing (fp32) ---
    logits = xf.astype(jnp.float32) @ p["router"]  # (G, Tg, E)
    if e != cfg.num_experts:  # mask padded experts
        pad_mask = jnp.arange(e) >= cfg.num_experts
        logits = jnp.where(pad_mask[None, None, :], -1e30, logits)
    gate_probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_e = jax.lax.top_k(gate_probs, k)  # (G, Tg, k)
    top_w = top_w / jnp.maximum(jnp.sum(top_w, -1, keepdims=True), 1e-9)

    # Switch-style load-balance aux (reuses this router pass)
    hard = jnp.argmax(gate_probs, -1).reshape(-1)
    frac = jnp.zeros((e,), jnp.float32).at[hard].add(1.0) / t
    aux = cfg.num_experts * jnp.sum(
        frac * jnp.mean(gate_probs.reshape(t, e), axis=0))

    # --- group-local capacity positions via stable sort ---
    flat_e = top_e.reshape(grp, tg * k)
    order = jnp.argsort(flat_e, axis=1, stable=True)
    sorted_e = jnp.take_along_axis(flat_e, order, axis=1)
    first = jax.vmap(lambda se: jnp.searchsorted(se, jnp.arange(e)))(sorted_e)
    pos_sorted = jnp.arange(tg * k)[None, :] - \
        jnp.take_along_axis(first, sorted_e, axis=1)
    pos = jnp.zeros((grp, tg * k), jnp.int32).at[
        jnp.arange(grp)[:, None], order].set(pos_sorted.astype(jnp.int32))
    valid = pos < cap

    # --- dispatch: (G, E, C) token-index table, then gather ---
    tok_ids = jnp.repeat(jnp.arange(tg), k)[None, :]              # (1, Tg*k)
    safe_pos = jnp.where(valid, pos, cap)
    table = jnp.full((grp, e, cap + 1), tg, jnp.int32)            # tg = "none"
    gidx = jnp.broadcast_to(jnp.arange(grp)[:, None], flat_e.shape)
    table = table.at[gidx, flat_e, safe_pos].set(
        jnp.where(valid, jnp.broadcast_to(tok_ids, flat_e.shape), tg))
    table = constrain(table[:, :, :cap], BATCH, None, None)       # (G, E, C)
    xpad = jnp.concatenate([xf, jnp.zeros((grp, 1, d), xf.dtype)], axis=1)
    dispatched = jnp.take_along_axis(
        xpad[:, :, None, :], table[..., None], axis=1)            # (G, E, C, D)
    # NOTE perf: constraining this buffer 2D (groups x experts) makes XLA's
    # gather partitioning replicate operands (measured 30.9 s -> 271 s
    # collective — refuted hypothesis, EXPERIMENTS.md §Perf). Group-sharded
    # only; the true all-to-all dispatch needs an explicit shard_map
    # (future work, blocked on the Shardy partitioner).
    dispatched = constrain(dispatched, BATCH, None, None, None)

    # --- expert compute (EP over "model"; groups gathered per expert) ---
    g_ = jnp.einsum("gecd,edf->gecf", dispatched, p["w_gate"])
    u = jnp.einsum("gecd,edf->gecf", dispatched, p["w_up"])
    y = jnp.einsum("gecf,efd->gecd", jax.nn.silu(g_) * u, p["w_down"])
    y = constrain(y, BATCH, None, None, None)

    # --- combine: group-local weighted gather back to tokens ---
    flat_pos = jnp.minimum(pos, cap - 1).reshape(grp, tg, k)
    gathered = y[jnp.arange(grp)[:, None, None], top_e, flat_pos]
    gathered = constrain(gathered, BATCH, None, None, None)       # (G,Tg,k,D)
    w = (top_w * valid.reshape(grp, tg, k)).astype(jnp.float32)
    out = jnp.sum(gathered.astype(jnp.float32) * w[..., None], axis=2)

    # --- shared experts (Qwen2-MoE): dense MLP + sigmoid gate ---
    if "shared" in p:
        sp = p["shared"]
        sg = jax.nn.silu(xf @ sp["w_gate"]) * (xf @ sp["w_up"])
        shared_out = sg @ sp["w_down"]
        gate = jax.nn.sigmoid(
            xf.astype(jnp.float32) @ p["shared_gate"][:, None])
        out = out + shared_out.astype(jnp.float32) * gate

    return out.reshape(b, s, d).astype(x.dtype), aux
