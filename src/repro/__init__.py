"""Public API of the FFTrainer reproduction.

The stable import surface — everything else under `repro.*` is
implementation detail and may move between releases:

    from repro import SimCluster, ClusterConfig, FabricConfig, FaultScript
    from repro import RecoveryPolicy, StreamRecovery, ComputeRecovery
    from repro import HybridRecovery, RecoveryError, RoutingError
    from repro import fftrainer_timeline, baseline_timeline
    from repro import compute_recovery_timeline, PodFabric
    from repro import TrafficPlan, compile_traffic_plan
    from repro import ReliabilityConfig, Scenario, run_scenario

The list is pinned by `tools/check_docs.py` (CI `docs` job), so it cannot
drift from the README/docs. Imports are lazy: touching `repro.SimCluster`
pulls in jax + the runtime, plain `import repro` stays light.
"""
from __future__ import annotations

__all__ = [
    "SimCluster",
    "ClusterConfig",
    "FabricConfig",
    "FaultScript",
    "RecoveryPolicy",
    "RecoveryPlan",
    "RecoveryReport",
    "RecoveryError",
    "RoutingError",
    "StreamRecovery",
    "ComputeRecovery",
    "HybridRecovery",
    "fftrainer_timeline",
    "baseline_timeline",
    "compute_recovery_timeline",
    "PodFabric",
    "TrafficPlan",
    "compile_traffic_plan",
    "ReliabilityConfig",
    "Scenario",
    "run_scenario",
]

_EXPORTS = {
    "SimCluster": "repro.runtime.cluster",
    "ClusterConfig": "repro.runtime.cluster",
    "FabricConfig": "repro.runtime.cluster",
    "FaultScript": "repro.runtime.recovery",
    "RecoveryPolicy": "repro.runtime.recovery",
    "RecoveryPlan": "repro.runtime.recovery",
    "RecoveryReport": "repro.runtime.recovery",
    "RecoveryError": "repro.runtime.recovery",
    "RoutingError": "repro.core.lccl",
    "StreamRecovery": "repro.runtime.recovery",
    "ComputeRecovery": "repro.runtime.recovery",
    "HybridRecovery": "repro.runtime.recovery",
    "fftrainer_timeline": "repro.runtime.failover",
    "baseline_timeline": "repro.runtime.failover",
    "compute_recovery_timeline": "repro.runtime.failover",
    "PodFabric": "repro.core.lccl",
    "TrafficPlan": "repro.core.plan",
    "compile_traffic_plan": "repro.core.plan",
    "ReliabilityConfig": "repro.runtime.reliability",
    "Scenario": "repro.runtime.scenarios",
    "run_scenario": "repro.runtime.scenarios",
}


def __getattr__(name: str):
    mod = _EXPORTS.get(name)
    if mod is None:
        raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
    import importlib
    value = getattr(importlib.import_module(mod), name)
    globals()[name] = value            # cache: resolve each name once
    return value


def __dir__():
    return sorted(set(globals()) | set(__all__))
