"""Cross-version jax shims.

The repo targets the `jax.shard_map` / `jax.sharding.AxisType` era but must
also run on the 0.4.37 floor, where `shard_map` lives in
`jax.experimental.shard_map` with the older `check_rep`/`auto` spelling.
`launch/mesh.py:make_mesh_compat` handles the mesh side; this module holds
the rest.
"""
from __future__ import annotations

from typing import Any, Optional, Set

import jax


def shard_map_compat(f, mesh, in_specs, out_specs, *,
                     axis_names: Optional[Set[Any]] = None,
                     check: bool = False):
    """`jax.shard_map` across jax versions.

    `axis_names` (new spelling) marks the axes that are manual inside `f`;
    on old jax it is translated to the complementary `auto` set. `check`
    maps to `check_vma` (new) / `check_rep` (old)."""
    if hasattr(jax, "shard_map"):
        kw = {"check_vma": check}
        if axis_names is not None:
            kw["axis_names"] = set(axis_names)
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, **kw)
    from jax.experimental.shard_map import shard_map
    kw = {"check_rep": check}
    if axis_names is not None:
        kw["auto"] = frozenset(mesh.axis_names) - set(axis_names)
    return shard_map(f, mesh, in_specs=in_specs, out_specs=out_specs, **kw)
