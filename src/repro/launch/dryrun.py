import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input shape) on the
production meshes, prove memory fits, and derive roofline inputs.

    PYTHONPATH=src python -m repro.launch.dryrun --arch deepseek-67b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--out DIR]

Two compiles per single-pod cell:
  * PRODUCTION form (lax.scan stacks, blockwise attention, chunked SSD):
    compile proof + memory_analysis + collective schedule.
  * ANALYSIS form (unrolled layers, dense attention, parallel SSD): exact
    FLOPs / bytes / collective-byte accounting (XLA cost analysis counts while
    bodies once — see repro.models.modes).
Multi-pod cells compile the production form only (the roofline table is
single-pod per the assignment).

The XLA_FLAGS line above MUST precede every other import (jax locks the device
count at first init); this module is the only place it is set — smoke tests
and benchmarks see the real single CPU device.
"""

import argparse
import gc
import json
import time
import traceback
from pathlib import Path

import jax

from repro.configs import dryrun_cells, get_arch, get_shape
from repro.launch.mesh import make_production_mesh
from repro.models import active_param_count, build_model
from repro.models.modes import analysis_mode
from repro.roofline.analyze import analyze_from_costs, parse_collectives


def lower_cell(cfg, shape, mesh, *, instant_ckpt: bool = True):
    """Build and lower the step for one cell. Returns jax.stages.Lowered."""
    model = build_model(cfg)
    with mesh:
        if shape.kind == "train":
            from repro.train.state import make_state_specs
            from repro.train.step import build_train_step
            art = build_train_step(model, mesh, instant_ckpt=instant_ckpt,
                                   shape=shape)
            return art.step_fn.lower(make_state_specs(model),
                                     model.input_specs(shape))
        if shape.kind == "prefill":
            from repro.train.serve import build_prefill_step
            fn, plan, _ = build_prefill_step(model, mesh, shape)
            return fn.lower(plan.state_specs["params"],
                            model.input_specs(shape))
        from repro.train.serve import build_decode_step
        fn, plan, _ = build_decode_step(model, mesh, shape)
        specs = model.input_specs(shape)
        return fn.lower(plan.state_specs["params"], specs["cache"],
                        specs["token"])


def run_cell(arch_name: str, shape_name: str, *, multi_pod: bool,
             out_dir: Path, instant_ckpt: bool = True,
             remat: str = None, verbose: bool = True,
             production_only: bool = False) -> dict:
    cfg = get_arch(arch_name)
    shape = get_shape(shape_name)
    if remat:
        import dataclasses
        cfg = dataclasses.replace(cfg, remat_policy=remat)
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    n_dev = mesh.size

    # --- production compile: proof + memory + schedule ---
    t0 = time.time()
    prod_lowered = lower_cell(cfg, shape, mesh, instant_ckpt=instant_ckpt)
    t_lower = time.time() - t0
    t0 = time.time()
    prod_compiled = prod_lowered.compile()
    t_compile = time.time() - t0
    mem = prod_compiled.memory_analysis()
    prod_colls = parse_collectives(prod_compiled.as_text())

    result = {
        "arch": arch_name, "shape": shape_name, "mesh": mesh_name,
        "kind": shape.kind, "n_devices": n_dev,
        "instant_ckpt": instant_ckpt,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "memory_analysis": {
            "argument_size_in_bytes": mem.argument_size_in_bytes,
            "output_size_in_bytes": mem.output_size_in_bytes,
            "temp_size_in_bytes": mem.temp_size_in_bytes,
            "alias_size_in_bytes": mem.alias_size_in_bytes,
        },
        "production_collectives": prod_colls,
    }
    if verbose:
        print(f"[{mesh_name}] {arch_name} x {shape_name}: production compile "
              f"ok ({t_lower:.1f}s lower, {t_compile:.1f}s compile)")
        print("  ", mem)
        print("   production collective schedule:", prod_colls["count_by_kind"])

    # --- analysis compile: exact cost accounting (single-pod only) ---
    if not multi_pod and not production_only:
        n = active_param_count(cfg)
        d_tok = shape.global_batch * (shape.seq_len
                                      if shape.kind != "decode" else 1)
        model_flops = (6 if shape.kind == "train" else 2) * n * d_tok
        t0 = time.time()
        from repro.roofline.probes import measure_costs
        costs = measure_costs(cfg, shape, mesh, instant_ckpt=instant_ckpt)
        t_ana = time.time() - t0
        # first-principles HBM model (memory term)
        from repro.core.razor import razor_plan
        from repro.roofline.memory_model import analytic_hbm_traffic
        from repro.train.state import make_state_plan
        plan = make_state_plan(build_model(cfg), mesh)
        razor = razor_plan(plan.state_specs["opt"], plan.opt_pspecs,
                           plan.state_specs["params"], mesh) \
            if shape.kind == "train" else None
        hbm = analytic_hbm_traffic(cfg, shape, mesh, plan, razor)
        rep = analyze_from_costs(costs, prod_compiled, arch=arch_name,
                                 shape=shape, mesh_name=mesh_name,
                                 n_devices=n_dev, model_flops=model_flops,
                                 cfg=cfg, hbm_model_bytes=hbm["traffic"])
        result.update(rep.to_dict())
        result["probe_costs"] = {k: v for k, v in costs.items()
                                 if k != "probe_rows"}
        result["hbm_model"] = hbm
        result["analysis_compile_s"] = round(t_ana, 2)
        result["active_params"] = n
        if verbose:
            print(f"   roofline: compute={rep.compute_s*1e3:.2f}ms "
                  f"memory={rep.memory_s*1e3:.2f}ms (raw {rep.memory_s_raw*1e3:.2f}) "
                  f"collective={rep.collective_s*1e3:.2f}ms -> {rep.bottleneck}-bound; "
                  f"useful={rep.useful_ratio:.2f} roofline={rep.roofline_fraction:.3f} "
                  f"fits_hbm={rep.fits_hbm} (analysis {t_ana:.0f}s)")

    out_dir.mkdir(parents=True, exist_ok=True)
    path = out_dir / f"{mesh_name}__{arch_name}__{shape_name}.json"
    path.write_text(json.dumps(result, indent=2))
    del prod_compiled, prod_lowered
    gc.collect()
    return result


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--no-instant-ckpt", action="store_true")
    ap.add_argument("--remat", default=None)
    ap.add_argument("--production-only", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()
    out_dir = Path(args.out)

    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    if args.all:
        cells = [(cfg.name, shape.name) for cfg, shape, _ in dryrun_cells()]
    else:
        if not args.arch or not args.shape:
            ap.error("--arch and --shape required unless --all")
        cells = [(args.arch, args.shape)]

    failures = []
    for multi_pod in meshes:
        for arch_name, shape_name in cells:
            mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
            path = out_dir / f"{mesh_name}__{arch_name}__{shape_name}.json"
            if args.skip_existing and path.exists():
                print(f"skip {path.name} (exists)")
                continue
            try:
                run_cell(arch_name, shape_name, multi_pod=multi_pod,
                         out_dir=out_dir,
                         instant_ckpt=not args.no_instant_ckpt,
                         remat=args.remat,
                         production_only=args.production_only)
            except Exception as e:  # record, keep sweeping
                traceback.print_exc()
                failures.append((mesh_name, arch_name, shape_name, repr(e)))
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for f in failures:
            print("  ", f)
        raise SystemExit(1)
    print(f"\nall {len(cells) * len(meshes)} dry-run cells passed")


if __name__ == "__main__":
    main()
