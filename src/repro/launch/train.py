"""Training CLI.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-0.6b --smoke \\
        --steps 20 --dp 2 --tp 1 [--inject-failure 10]

Runs the full stack: controller-indexed data loading, SPMD train step with
instant checkpointing, the ckpt engine (instant + periodic full), failure
injection and recovery. Smoke scale by default (this container is CPU-only);
--full uses the production config (requires a real TPU slice).
"""
from __future__ import annotations

import argparse
import dataclasses
import time
from pathlib import Path


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--dp", type=int, default=2)
    ap.add_argument("--seq-len", type=int, default=32)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--inject-failure", type=int, default=None,
                    help="step at which to kill a worker (tests failover)")
    ap.add_argument("--hardware-failure", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    ap.add_argument("--full-every", type=int, default=500)
    ap.add_argument("--topology", choices=("ring", "full"), default="ring",
                    help="per-link fabric shape (one scheduler per edge)")
    ap.add_argument("--link-bw", type=float, default=50e9,
                    help="default per-ICI-edge bandwidth, bytes/s")
    ap.add_argument("--hotspot-edge", type=int, nargs=2, default=None,
                    metavar=("U", "V"),
                    help="ring edge to throttle (asymmetric-bandwidth run)")
    ap.add_argument("--hotspot-bw", type=float, default=5e9,
                    help="bandwidth of the hotspot edge, bytes/s")
    ap.add_argument("--pods", type=int, default=1,
                    help="group the dp workers into this many pods: per-pod "
                         "ICI rings joined by a DCN gateway ring")
    ap.add_argument("--dcn-bw", type=float, default=5e9,
                    help="inter-pod (DCN) edge bandwidth, bytes/s")
    ap.add_argument("--edge-latency", type=float, default=1e-3,
                    help="per-DCN-hop delivery latency, seconds")
    ap.add_argument("--storm", type=int, default=None, metavar="SEED",
                    help="at --inject-failure, unleash a seeded correlated "
                         "failure storm (darkens a whole pod + nearby "
                         "edges) instead of a single-worker failure")
    ap.add_argument("--storm-edge-failures", type=int, default=1,
                    help="extra correlated edge failures in the storm")
    ap.add_argument("--recovery-policy", choices=("stream", "compute",
                                                  "hybrid"),
                    default="stream",
                    help="how failed workers get their state back: stream "
                         "it from neighbor backups (FFTrainer), replay "
                         "compute to rebuild it checkpoint-free, or race "
                         "both per worker")
    args = ap.parse_args()

    from repro.configs import get_arch, reduce_for_smoke
    from repro.core.lccl import edge_key
    from repro.optim import AdamWConfig
    from repro.runtime.cluster import (ClusterConfig, FabricConfig,
                                       FaultScript, SimCluster)

    cfg = get_arch(args.arch)
    if args.smoke:
        cfg = reduce_for_smoke(cfg)
    cfg = dataclasses.replace(cfg, remat_policy="none")

    edge_bw = None
    if args.hotspot_edge is not None:
        edge_bw = {edge_key(*args.hotspot_edge): args.hotspot_bw}

    clu = SimCluster(
        cfg,
        cluster=ClusterConfig(
            dp=args.dp, global_batch=args.global_batch,
            seq_len=args.seq_len, ckpt_dir=Path(args.ckpt_dir),
            full_every=args.full_every,
            hp=AdamWConfig(warmup_steps=5, total_steps=max(args.steps, 10))),
        fabric=FabricConfig(
            link_bw=args.link_bw, topology=args.topology, edge_bw=edge_bw,
            pods=args.pods, dcn_bw=args.dcn_bw,
            dcn_latency=args.edge_latency),
        recovery=args.recovery_policy)

    t0 = time.time()
    for step in range(args.steps):
        if args.inject_failure is not None and step == args.inject_failure:
            if args.storm is not None:
                storm = clu.inject_storm(
                    args.storm, pods=1,
                    edge_failures=args.storm_edge_failures)
                print(f"[failover] storm seed={storm.seed}: darkened pods "
                      f"{list(storm.pods)}, extra dark edges "
                      f"{list(storm.edges)}")
            else:
                print(f"[failover] injecting failure at step {step}")
                clu.inject_failure([1], hardware=args.hardware_failure)
            if any(not w.alive for w in clu.workers):
                rep = clu.recover(
                    FaultScript(hardware=args.hardware_failure))
                print(f"[failover] recovered from {rep.recovered_from} "
                      f"({rep.policy} policy) in {rep.total_time:.1f}s "
                      f"(modeled), rollback="
                      f"{rep.rolled_back_iterations} iterations, "
                      f"state streamed {rep.state_bytes_streamed / 1e6:.1f} "
                      f"MB, replay compute {rep.compute_seconds:.2f}s")
            else:
                # a flat-fabric storm only darkens edges (no pods to kill):
                # training continues, streams route around the damage
                print("[failover] storm killed no workers; training on "
                      "through the degraded fabric")
        loss = clu.step()
        if step % 5 == 0 or step == args.steps - 1:
            print(f"step {clu.iteration:4d} loss {loss:.4f} "
                  f"({(time.time() - t0) / (step + 1):.2f}s/it)")
    print(f"done: {clu.iteration} iterations, "
          f"instant ckpts per worker ~= {clu.workers[0].engine.instant_count}")
    # per-edge view of the fabric the training traffic actually loaded:
    # instant-ckpt hiding (the FCR condition) is now observable edge by edge
    print(f"instant ckpt hidden/exposed iterations: "
          f"{clu.instant_hidden}/{clu.instant_exposed}")
    for e, sch in sorted(clu.topology.links.items()):
        hid = clu.edge_instant_hidden.get(e, 0)
        exp = clu.edge_instant_exposed.get(e, 0)
        print(f"  edge {e[0]}-{e[1]} [{clu.topology.tier(*e)}]: "
              f"bw {sch.bw / 1e9:.1f} GB/s, "
              f"lat {sch.latency * 1e3:.2f} ms, "
              f"state hidden {hid} exposed {exp}, "
              f"TRAIN+STATE transfers {sch.n_finished} pending "
              f"{sch.pending_bytes() / 1e6:.1f} MB")
    # per-tier rollup: where the fabric's surplus capacity actually went
    from repro.core.lccl import PodFabric
    if isinstance(clu.topology, PodFabric):
        for tier in clu.topology.tiers():
            edges = clu.topology.tier_edges(tier)
            moved = sum(clu.topology.edge(*e).n_finished for e in edges)
            print(f"  tier {tier}: {len(edges)} edges, "
                  f"{moved} transfers completed")


if __name__ == "__main__":
    main()
