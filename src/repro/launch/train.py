"""Training CLI.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-0.6b --smoke \\
        --steps 20 --dp 2 --tp 1 [--inject-failure 10]

Runs the full stack: controller-indexed data loading, SPMD train step with
instant checkpointing, the ckpt engine (instant + periodic full), failure
injection and recovery. Smoke scale by default (this container is CPU-only);
--full uses the production config (requires a real TPU slice).
"""
from __future__ import annotations

import argparse
import dataclasses
import time
from pathlib import Path


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--dp", type=int, default=2)
    ap.add_argument("--seq-len", type=int, default=32)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--inject-failure", type=int, default=None,
                    help="step at which to kill a worker (tests failover)")
    ap.add_argument("--hardware-failure", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    ap.add_argument("--full-every", type=int, default=500)
    args = ap.parse_args()

    from repro.configs import get_arch, reduce_for_smoke
    from repro.optim import AdamWConfig
    from repro.runtime.cluster import SimCluster

    cfg = get_arch(args.arch)
    if args.smoke:
        cfg = reduce_for_smoke(cfg)
    cfg = dataclasses.replace(cfg, remat_policy="none")

    clu = SimCluster(
        cfg, dp=args.dp, global_batch=args.global_batch,
        seq_len=args.seq_len, ckpt_dir=Path(args.ckpt_dir),
        full_every=args.full_every,
        hp=AdamWConfig(warmup_steps=5, total_steps=max(args.steps, 10)))

    t0 = time.time()
    for step in range(args.steps):
        if args.inject_failure is not None and step == args.inject_failure:
            print(f"[failover] injecting failure at step {step}")
            clu.inject_failure([1], hardware=args.hardware_failure)
            rep = clu.recover(hardware=args.hardware_failure)
            print(f"[failover] recovered from {rep.recovered_from} in "
                  f"{rep.total_time:.1f}s (modeled), rollback="
                  f"{rep.rolled_back_iterations} iterations")
        loss = clu.step()
        if step % 5 == 0 or step == args.steps - 1:
            print(f"step {clu.iteration:4d} loss {loss:.4f} "
                  f"({(time.time() - t0) / (step + 1):.2f}s/it)")
    print(f"done: {clu.iteration} iterations, "
          f"instant ckpts per worker ~= {clu.workers[0].engine.instant_count}")


if __name__ == "__main__":
    main()
