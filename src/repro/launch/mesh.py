"""Production mesh factories.

``make_production_mesh`` is a FUNCTION (importing this module never touches
jax device state). Single pod: 16x16 = 256 chips ("data", "model"); multi-pod:
2x16x16 = 512 chips ("pod", "data", "model").
"""
from __future__ import annotations

import jax


def make_mesh_compat(shape, axes, devices=None):
    """jax.make_mesh across jax versions: `axis_types` (and the AxisType
    enum) only exist on newer releases — pass them when available."""
    kw = {}
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        kw["axis_types"] = (axis_type.Auto,) * len(axes)
    if devices is not None:
        kw["devices"] = devices
    return jax.make_mesh(shape, axes, **kw)


_make_mesh = make_mesh_compat


def make_production_mesh(*, multi_pod: bool = False):
    import numpy as np
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()[:n]
    if len(devices) < n:
        raise RuntimeError(
            f"production mesh needs {n} devices, found {len(devices)} — "
            "run under XLA_FLAGS=--xla_force_host_platform_device_count=512 "
            "(launch/dryrun.py sets this)")
    return _make_mesh(shape, axes, devices=devices)


def make_host_mesh(data: int = 2, model: int = 2, pod: int = 1):
    """Small mesh over host-platform devices for smoke tests/examples."""
    shape = (pod, data, model) if pod > 1 else (data, model)
    axes = ("pod", "data", "model") if pod > 1 else ("data", "model")
    return _make_mesh(shape, axes)


def make_single_device_mesh():
    return _make_mesh((1, 1), ("data", "model"))
