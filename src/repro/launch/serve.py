"""Serving CLI: prefill a batch of prompts, then greedy-decode.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b --smoke \\
        --batch 4 --prompt-len 16 --gen 16
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args()

    from repro.configs import get_arch, reduce_for_smoke
    from repro.models import build_model

    cfg = get_arch(args.arch)
    if args.smoke:
        cfg = reduce_for_smoke(cfg)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    rng = np.random.default_rng(0)
    max_len = args.prompt_len + args.gen + (cfg.num_patch_tokens or 0)

    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, (args.batch, args.prompt_len)),
        jnp.int32), "max_len": max_len}
    if cfg.num_patch_tokens:
        batch["patch_embeds"] = jnp.zeros(
            (args.batch, cfg.num_patch_tokens, cfg.d_model), jnp.bfloat16)
    if cfg.encoder_layers:
        batch["frames"] = jnp.asarray(
            rng.normal(size=(args.batch, cfg.encoder_seq, cfg.d_model)),
            jnp.bfloat16)

    t0 = time.time()
    logits, cache = model.prefill(params, batch)
    print(f"prefill: {args.batch}x{args.prompt_len} in {time.time()-t0:.2f}s")

    decode = jax.jit(model.decode_step)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    out = [tok]
    t0 = time.time()
    for _ in range(args.gen - 1):
        logits, cache = decode(params, cache, tok)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        out.append(tok)
    jax.block_until_ready(tok)
    dt = time.time() - t0
    seqs = np.stack([np.asarray(t) for t in out], axis=1)
    print(f"decoded {args.gen} tokens/seq in {dt:.2f}s "
          f"({args.batch * args.gen / max(dt, 1e-9):.1f} tok/s)")
    print("first sequence:", seqs[0].tolist())


if __name__ == "__main__":
    main()
