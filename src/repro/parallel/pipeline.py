"""GPipe-style pipeline parallelism over a "pipe" mesh axis via shard_map +
collective_permute.

The assigned production meshes have no pipe axis (DP x TP covers them), but
PP is part of the at-scale parallelism portfolio (paper §2, 3D parallelism),
so the framework ships a composable implementation:

  * stage sharding: the layer-stacked params' leading dim is sharded over
    "pipe"; each shard_map instance owns L/P consecutive layers;
  * schedule: GPipe with M microbatches — a lax.scan over M + P - 1 ticks;
    each tick runs every stage on its current microbatch and ppermutes
    activations to the next stage (bubble fraction = (P-1)/(M+P-1));
  * correctness is validated against the unpipelined forward in
    tests/test_pipeline.py.
"""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map_compat

PyTree = Any


def pipeline_forward(
    layer_fn: Callable[[PyTree, jax.Array], jax.Array],
    stacked_params: PyTree,          # leaves (L, ...), L % pipe == 0
    x: jax.Array,                    # (M, mb, S, D): M microbatches
    mesh: Mesh,
    *,
    axis: str = "pipe",
) -> jax.Array:
    """Runs x through L layers split across the pipe axis, GPipe schedule.
    Returns (M, mb, S, D)."""
    n_stages = mesh.shape[axis]
    m = x.shape[0]
    perm = [(i, i + 1) for i in range(n_stages - 1)]

    def stage(params, xs):
        # params: (L/P, ...) local layers; xs: (M, mb, S, D) with only stage 0
        # feeding real data; others start with zeros and receive via permute.
        stage_id = jax.lax.axis_index(axis)
        n_ticks = m + n_stages - 1

        def run_layers(h):
            def body(h, p):
                return layer_fn(p, h), None
            h, _ = jax.lax.scan(body, h, params)
            return h

        def tick(carry, t):
            outputs, inflight = carry
            # stage 0 injects microbatch t (if any), others use inflight
            inject = jnp.where(t < m, t, 0)
            h_in = jnp.where(stage_id == 0, xs[inject], inflight)
            h_out = run_layers(h_in)
            # last stage records its finished microbatch (t - (P-1))
            out_idx = t - (n_stages - 1)
            do_store = (stage_id == n_stages - 1) & (out_idx >= 0)
            outputs = jax.lax.cond(
                do_store,
                lambda o: jax.lax.dynamic_update_index_in_dim(
                    o, h_out, jnp.maximum(out_idx, 0), 0),
                lambda o: o, outputs)
            # hand activations to the next stage
            inflight = jax.lax.ppermute(h_out, axis, perm)
            return (outputs, inflight), None

        outputs = jnp.zeros_like(xs)
        inflight = jnp.zeros_like(xs[0])
        (outputs, _), _ = jax.lax.scan(tick, (outputs, inflight),
                                       jnp.arange(n_ticks))
        # replicate the last stage's outputs (masked psum = broadcast)
        outputs = jnp.where(stage_id == n_stages - 1, outputs, 0.0)
        return jax.lax.psum(outputs, axis)

    in_specs = (jax.tree.map(lambda _: P(axis), stacked_params,
                             is_leaf=lambda l: hasattr(l, "shape")),
                P())
    fn = shard_map_compat(stage, mesh, in_specs=in_specs, out_specs=P())
    return fn(stacked_params, x)


def bubble_fraction(n_stages: int, n_microbatches: int) -> float:
    return (n_stages - 1) / (n_microbatches + n_stages - 1)
