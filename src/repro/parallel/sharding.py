"""Sharding rules: DP / TP / EP / SP over the ("pod", "data", "model") mesh.

Rules are name+shape based over the param pytree:

  * TP ("model"):  attention q-heads, kv-heads (when divisible), FFN hidden,
    MoE experts (EP), Mamba2 inner/heads, vocab dim of embeddings.
  * DP ("pod","data"): the batch dim of activations and caches.
  * ZeRO-1 ("data"): optimizer master/m/v leaves get "data" inserted into the
    first still-unsharded, divisible dim (reduce-scatter + all-gather emerge
    from XLA sharding propagation alone).
  * SP: decode KV caches shard the *sequence* dim over "model" (and over
    "data" too when the batch dim can't use it — long_500k batch=1).

Every rule degrades to replication when a dim isn't divisible (e.g. gemma-2b's
8 q-heads on a 16-way model axis) — documented fallback, not an error.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs import ArchConfig

PyTree = Any


def axis_size(mesh: Mesh, name: str) -> int:
    return mesh.shape[name] if name in mesh.axis_names else 1


def batch_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def dp_size(mesh: Mesh) -> int:
    return int(np.prod([axis_size(mesh, a) for a in batch_axes(mesh)]))


def _div(n: int, d: int) -> bool:
    return d > 0 and n % d == 0 and n >= d


# --------------------------------------------------------------------------- #
# Parameter specs
# --------------------------------------------------------------------------- #
def _leaf_spec(name: str, shape: Tuple[int, ...], cfg: ArchConfig,
               tp: int, stacked: bool) -> P:
    """PartitionSpec for one (unstacked) param leaf; `stacked` prepends None."""
    base = shape[1:] if stacked else shape
    h, kh = cfg.num_heads, cfg.num_kv_heads
    hd = cfg.resolved_head_dim

    def spec(*parts):
        out = (None,) + parts if stacked else parts
        return P(*out)

    if name == "w" and len(base) == 2:  # embed / lm_head (V, D)
        return spec("model" if _div(base[0], tp) else None, None)
    if name in ("wq",):
        return spec(None, "model" if _div(h, tp) else None)
    if name in ("wk", "wv"):
        return spec(None, "model" if _div(kh, tp) else None)
    if name == "wo":
        return spec("model" if _div(h, tp) else None, None)
    if name in ("w_gate", "w_up") and len(base) == 3:  # MoE experts (E, D, F)
        return spec("model" if _div(base[0], tp) else None, None, None)
    if name == "w_down" and len(base) == 3:
        return spec("model" if _div(base[0], tp) else None, None, None)
    if name in ("w_gate", "w_up") and len(base) == 2:  # dense MLP (D, F)
        return spec(None, "model" if _div(base[1], tp) else None)
    if name == "w_down" and len(base) == 2:            # (F, D)
        return spec("model" if _div(base[0], tp) else None, None)
    if name == "router":
        return spec(None, None)
    # --- Mamba2 ---
    if name in ("w_x", "w_z"):  # (D, inner) — inner is head-major
        return spec(None, "model" if _div(cfg.ssm_heads, tp) else None)
    if name == "w_dt":          # (D, H)
        return spec(None, "model" if _div(cfg.ssm_heads, tp) else None)
    if name in ("w_b", "w_c"):  # (D, N) — single SSD group, replicated
        return spec(None, None)
    if name == "conv_x":        # (inner, k)
        return spec("model" if _div(cfg.ssm_heads, tp) else None, None)
    if name in ("conv_b", "conv_c"):
        return spec(None, None)
    if name in ("a_log", "d_skip", "dt_bias"):  # (H,)
        return spec("model" if _div(cfg.ssm_heads, tp) else None)
    if name == "norm":          # (inner,)
        return spec("model" if _div(cfg.ssm_heads, tp) else None)
    if name == "out":           # (inner, D)
        return spec("model" if _div(cfg.ssm_heads, tp) else None, None)
    # norms / small vectors / shared_gate
    return spec(*([None] * len(base)))


_STACKED_ROOTS = ("blocks", "encoder", "decoder")


def param_pspecs(cfg: ArchConfig, specs: PyTree, mesh: Mesh,
                 *, fsdp: bool = False) -> PyTree:
    """TP specs; with fsdp=True every leaf additionally shards its first
    free divisible dim over "data" (ZeRO-3 / fully-sharded storage — XLA
    inserts the per-layer all-gather inside the scan body)."""
    tp = axis_size(mesh, "model")
    dz = axis_size(mesh, "data")

    def rule(path, leaf):
        keys = [getattr(k, "key", getattr(k, "name", None)) for k in path]
        stacked = any(k in _STACKED_ROOTS for k in keys)
        spec = _leaf_spec(keys[-1], leaf.shape, cfg, tp, stacked)
        # embeddings stay TP-only: FSDP-sharding the (V, D) tables makes the
        # logits einsum contract over a "data"-sharded dim and the partitioner
        # replicates the (B, S, V) logits — a ~250 GB/device regression
        # (measured; EXPERIMENTS.md perf log).
        if fsdp and keys[0] not in ("embed", "lm_head"):
            spec = zero_spec(spec, leaf.shape, dz, "data")
        return spec

    return jax.tree_util.tree_map_with_path(rule, specs)


# --------------------------------------------------------------------------- #
# ZeRO-1: optimizer-state sharding over "data"
# --------------------------------------------------------------------------- #
def zero_spec(spec: P, shape: Tuple[int, ...], zero: int,
              axis: str = "data") -> P:
    parts = list(spec) + [None] * (len(shape) - len(spec))
    for p in parts:  # already sharded over `axis` (e.g. FSDP params): no-op
        if p == axis or (isinstance(p, (tuple, list)) and axis in p):
            return P(*parts)
    for i, (p, n) in enumerate(zip(parts, shape)):
        if p is None and _div(n, zero):
            parts[i] = axis
            return P(*parts)
    return P(*parts)  # nothing divisible: stays unsharded on `axis` (tiny leaf)


def zero_pspecs(pspecs: PyTree, specs: PyTree, mesh: Mesh,
                axis: str = "data") -> PyTree:
    z = axis_size(mesh, axis)
    return jax.tree.map(lambda p, s: zero_spec(p, s.shape, z, axis),
                        pspecs, specs)


# --------------------------------------------------------------------------- #
# Input / cache / activation specs
# --------------------------------------------------------------------------- #
def input_pspecs(cfg: ArchConfig, specs: Dict, mesh: Mesh) -> Dict:
    dp = batch_axes(mesh)
    dpn = dp_size(mesh)

    def rule(path, leaf):
        keys = [getattr(k, "key", getattr(k, "name", None)) for k in path]
        if "cache" in keys:
            return _cache_leaf_spec(keys, leaf, cfg, mesh)
        b = leaf.shape[0]
        lead = dp if _div(b, dpn) else None
        return P(lead, *([None] * (len(leaf.shape) - 1)))

    return jax.tree_util.tree_map_with_path(rule, specs)


def _cache_leaf_spec(keys, leaf, cfg: ArchConfig, mesh: Mesh) -> P:
    dp = batch_axes(mesh)
    dpn = dp_size(mesh)
    tp = axis_size(mesh, "model")
    name = keys[-1]
    if name == "index":
        return P()
    if name in ("k", "v", "cross_k", "cross_v"):
        _, b, t, kh, _ = leaf.shape
        b_ax = dp if _div(b, dpn) else None
        # SP: sequence over "model"; if batch idle, use ("data","model")
        if b_ax is None and _div(t, dpn * tp):
            t_ax: Any = tuple(a for a in ("pod", "data", "model")
                              if a in mesh.axis_names)
        elif _div(t, tp):
            t_ax = "model"
        else:
            t_ax = None
        return P(None, b_ax, t_ax, None, None)
    # mamba decode state
    if name == "ssm":            # (L, B, H, N, P)
        _, b, h, _, _ = leaf.shape
        return P(None, dp if _div(b, dpn) else None,
                 "model" if _div(h, tp) else None, None, None)
    if name in ("conv_x",):      # (L, B, k-1, inner)
        _, b, _, inner = leaf.shape
        return P(None, dp if _div(b, dpn) else None, None,
                 "model" if _div(cfg.ssm_heads, tp) else None)
    if name in ("conv_b", "conv_c"):
        _, b, _, _ = leaf.shape
        return P(None, dp if _div(b, dpn) else None, None, None)
    raise ValueError(f"unknown cache leaf {keys}")


def cache_pspecs(cfg: ArchConfig, cache_specs: PyTree, mesh: Mesh) -> PyTree:
    def rule(path, leaf):
        keys = ["cache"] + [getattr(k, "key", getattr(k, "name", None))
                            for k in path]
        return _cache_leaf_spec(keys, leaf, cfg, mesh)

    return jax.tree_util.tree_map_with_path(rule, cache_specs)


def to_named(pspecs: PyTree, mesh: Mesh) -> PyTree:
    return jax.tree.map(lambda p: NamedSharding(mesh, p), pspecs,
                        is_leaf=lambda x: isinstance(x, P))
