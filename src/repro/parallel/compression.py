"""Cross-pod gradient compression (beyond-paper distributed optimization).

The inter-pod (DCN) hop is the scarcest bandwidth in a multi-pod job: a full
bf16 all-reduce of the gradients crosses it every step. Here the cross-pod
stage is made explicit with ``jax.shard_map`` in partial-manual mode (only
"pod" is manual; "data"/"model" stay auto-sharded), quantized to int8 with a
shared per-leaf scale — a 2x payload reduction vs bf16 (4x vs fp32) on the
DCN hop.

Error feedback keeps quantization bias bounded: each device folds its local
quantization residual back into the returned mean (stateless form — the
residual re-enters the same step's optimizer update rather than a carried
buffer, giving an unbiased-in-expectation estimate with bounded deviation,
validated in tests against the exact mean).
"""
from __future__ import annotations

from typing import Any, Callable, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map_compat

PyTree = Any


def _keep_only_axis(spec: P, axis: str) -> P:
    """Partial-manual shard_map specs may mention ONLY the manual axis."""
    parts = []
    for part in spec:
        names = part if isinstance(part, (tuple, list)) else (part,)
        parts.append(axis if axis in names else None)
    return P(*parts)


def quantize_int8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def pod_compressed_value_and_grad(
    loss_fn: Callable,           # params, batch -> (loss, aux)
    mesh: Mesh,
    param_pspecs: PyTree,
    batch_pspecs: PyTree,
    axis: str = "pod",
):
    """Returns fn(params, batch) -> ((loss, aux), grads) where the cross-pod
    gradient reduction is an int8-quantized psum with error feedback."""
    npods = mesh.shape.get(axis, 1)

    def local(params, batch):
        (loss, aux), g = jax.value_and_grad(loss_fn, has_aux=True)(
            params, batch)
        if npods <= 1:
            return (loss, aux), g

        def reduce_one(x):
            xf = x.astype(jnp.float32)
            # shared scale across pods so int8 payloads are commensurable
            s = jax.lax.pmax(jnp.maximum(jnp.max(jnp.abs(xf)), 1e-12), axis) \
                / 127.0
            q = jnp.clip(jnp.round(xf / s), -127, 127)
            mean = jax.lax.psum(q, axis) * s / npods
            resid = xf - q * s                       # local quantization error
            return (mean + resid / npods).astype(x.dtype)

        g = jax.tree.map(reduce_one, g)
        loss = jax.lax.pmean(loss, axis)
        aux = jax.tree.map(lambda a: jax.lax.pmean(a, axis), aux)
        return (loss, aux), g

    is_p = lambda x: isinstance(x, P)
    param_in = jax.tree.map(lambda s: _keep_only_axis(s, axis), param_pspecs,
                            is_leaf=is_p)
    batch_in = jax.tree.map(lambda s: _keep_only_axis(s, axis), batch_pspecs,
                            is_leaf=is_p)
    return shard_map_compat(
        local, mesh,
        in_specs=(param_in, batch_in),
        out_specs=((P(), jax.tree.map(lambda _: P(), {"xent": 0, "aux": 0})),
                   param_in),
        axis_names={axis},
    )


def compressed_bytes_saved(grad_bytes: int, npods: int) -> Tuple[int, int]:
    """(bf16 cross-pod payload, int8 payload) per step per device."""
    if npods <= 1:
        return 0, 0
    return grad_bytes, grad_bytes // 2
