"""Best-effort activation sharding constraints.

Model code is mesh-agnostic: ``constrain`` applies
``jax.lax.with_sharding_constraint`` against the ambient mesh when one is
active and silently no-ops otherwise (single-device smoke tests, kernels).
"""
from __future__ import annotations

import jax
from jax.sharding import PartitionSpec as P

BATCH = ("pod", "data")  # logical batch axes (pod may be absent)


def _mesh_shape():
    """Usable (non-Manual) mesh axes -> sizes in the current trace context."""
    try:
        am = jax.sharding.get_abstract_mesh()
        if am is not None and not am.empty:
            types = getattr(am, "axis_types", None) or ()
            out = {}
            for i, (n, s) in enumerate(zip(am.axis_names, am.axis_sizes)):
                if types and str(types[i]) == "Manual":
                    continue  # inside shard_map: manual axes are off-limits
                out[n] = s
            return out
        from jax._src import mesh as mesh_lib
        m = mesh_lib.thread_resources.env.physical_mesh
        if m.empty:
            return {}
        return {n: s for n, s in zip(m.axis_names, m.devices.shape)}
    except Exception:
        return {}


def constrain(x, *parts):
    """constrain(x, ("pod","data"), "model", None) — axes missing from the
    ambient mesh are dropped; axes that don't divide the dim are dropped;
    no mesh means no-op."""
    try:
        mesh = _mesh_shape()
        if not mesh:
            return x
        fixed = []
        for dim, p in zip(x.shape, parts):
            if p is None:
                fixed.append(None)
                continue
            names = p if isinstance(p, (tuple, list)) else (p,)
            kept, div = [], 1
            for a in names:
                sz = mesh.get(a)
                if sz and dim % (div * sz) == 0:
                    kept.append(a)
                    div *= sz
            fixed.append(tuple(kept) if len(kept) > 1
                         else (kept[0] if kept else None))
        return jax.lax.with_sharding_constraint(x, P(*fixed))
    except Exception:
        return x
